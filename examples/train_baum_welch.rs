//! Baum–Welch parameter estimation (paper §V-C) with the parallel-scan
//! E-step: recover Gilbert–Elliott channel parameters from observations
//! alone, logging the EM objective curve. Everything runs through the
//! unified `Engine` (`Algorithm::BaumWelch` / `Algorithm::SpSeq`).
//!
//!     cargo run --release --example train_baum_welch

use std::time::Instant;

use hmm_scan::engine::{Algorithm, Engine};
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::inference::{BaumWelchOptions, EStepBackend};
use hmm_scan::rng::Xoshiro256StarStar;

fn main() -> hmm_scan::Result<()> {
    let truth = gilbert_elliott(GeParams::default());
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xBEEF);
    let t = 20_000;
    let tr = sample(&truth, t, &mut rng);
    println!("training sequence: T = {t} (GE channel, true params {:?})", GeParams::default());

    // Deliberately wrong initialization.
    let init = gilbert_elliott(GeParams { p0: 0.15, p1: 0.25, p2: 0.2, q0: 0.08, q1: 0.25 });
    let ll_truth = Engine::builder(truth)
        .build()
        .run(Algorithm::SpSeq, &tr.observations)?
        .into_posterior()?
        .log_likelihood();
    let ll_init = Engine::builder(init.clone())
        .build()
        .run(Algorithm::SpSeq, &tr.observations)?
        .into_posterior()?
        .log_likelihood();
    println!("loglik under truth: {ll_truth:.1}; under init: {ll_init:.1}\n");

    for backend in [EStepBackend::Sequential, EStepBackend::ParallelScan] {
        let mut engine = Engine::builder(init.clone())
            .baum_welch_options(BaumWelchOptions {
                max_iters: 25,
                backend,
                ..Default::default()
            })
            .build();
        let t0 = Instant::now();
        let res = engine.run(Algorithm::BaumWelch, &tr.observations)?.into_training()?;
        let elapsed = t0.elapsed();
        println!("E-step backend {backend:?}: {} iterations in {elapsed:?}", res.iterations);
        for (i, ll) in res.loglik_curve.iter().enumerate() {
            if i % 5 == 0 || i + 1 == res.loglik_curve.len() {
                println!("  iter {i:>3}: loglik {ll:.3}");
            }
        }
        let final_ll = *res.loglik_curve.last().unwrap();
        // EM must close most of the gap toward the true-parameter fit.
        let recovered = (final_ll - ll_init) / (ll_truth - ll_init);
        println!("  gap to truth closed: {:.1}%\n", 100.0 * recovered);
        assert!(
            final_ll > ll_init,
            "EM failed to improve ({final_ll} <= {ll_init})"
        );

        // Monotonicity — the EM guarantee.
        for w in res.loglik_curve.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "loglik decreased: {} -> {}", w[0], w[1]);
        }
    }
    println!("done ✓");
    Ok(())
}

//! End-to-end driver on the paper's own workload (§VI): the full
//! three-layer system decoding a Gilbert–Elliott channel.
//!
//! Simulates a noisy channel transmission, then recovers the transmitted
//! bits through every layer of the stack — the native algorithm library,
//! the PJRT core artifacts (when built), and the §V-B sharded plan for a
//! sequence longer than any compiled artifact — verifying that all
//! paths agree and reporting bit-error rates and the headline
//! sequential/parallel timing comparison. Results are recorded in
//! EXPERIMENTS.md.
//!
//!     cargo run --release --example gilbert_elliott

use std::time::Instant;

use hmm_scan::coordinator::{
    Algo, Coordinator, CoordinatorConfig, DecodeRequest, DecodeResult, ExecMode,
};
use hmm_scan::engine::{Algorithm, Engine};
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::rng::Xoshiro256StarStar;
use hmm_scan::scan::ScanOptions;

fn bit(x: u32) -> u32 {
    (x >= 2) as u32
}

fn ber(estimate: &[u32], truth: &[u32]) -> f64 {
    let errs = estimate
        .iter()
        .zip(truth)
        .filter(|(&a, &b)| bit(a) != bit(b))
        .count();
    errs as f64 / truth.len() as f64
}

fn main() -> hmm_scan::Result<()> {
    let hmm = gilbert_elliott(GeParams::default());
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xC0FFEE);
    let t = 100_000; // the paper's largest sequence length
    let tr = sample(&hmm, t, &mut rng);
    let raw_ber = tr
        .observations
        .iter()
        .zip(&tr.states)
        .filter(|(&y, &x)| y != bit(x))
        .count() as f64
        / t as f64;
    println!("Gilbert–Elliott channel, T = {t}");
    println!("raw channel bit-error rate: {raw_ber:.4}\n");

    // --- Native library via the unified engine: sequential vs parallel
    // (the paper's Fig. 3) ---
    let mut engine =
        Engine::builder(hmm.clone()).scan_options(ScanOptions::default()).build();
    let t0 = Instant::now();
    let seq = engine.run(Algorithm::Viterbi, &tr.observations)?.into_map()?;
    let seq_time = t0.elapsed();
    let t0 = Instant::now();
    let par = engine.run(Algorithm::MpPar, &tr.observations)?.into_map()?;
    let par_time = t0.elapsed();
    println!("native Viterbi (seq):      {seq_time:?}  logp {:.3}", seq.log_prob);
    println!("native max-product (par):  {par_time:?}  logp {:.3}", par.log_prob);
    println!("decoded BER (seq): {:.4}", ber(&seq.path, &tr.states));
    println!("decoded BER (par): {:.4}", ber(&par.path, &tr.states));
    assert!((seq.log_prob - par.log_prob).abs() < 1e-6 * seq.log_prob.abs());

    let t0 = Instant::now();
    let smooth_seq = engine.run(Algorithm::SpSeq, &tr.observations)?.into_posterior()?;
    let smooth_seq_time = t0.elapsed();
    let t0 = Instant::now();
    let smooth_par = engine.run(Algorithm::SpPar, &tr.observations)?.into_posterior()?;
    let smooth_par_time = t0.elapsed();
    println!("\nnative smoother (seq):     {smooth_seq_time:?}  loglik {:.3}", smooth_seq.log_likelihood());
    println!("native smoother (par):     {smooth_par_time:?}  loglik {:.3}", smooth_par.log_likelihood());
    let mmap = smooth_par.marginal_map();
    println!("decoded BER (marginal MAP): {:.4}", ber(&mmap, &tr.states));

    // --- Full coordinator stack (PJRT artifacts + sharding) ---
    let config = CoordinatorConfig::default();
    if config.artifacts.is_none() {
        println!("\n(no artifacts built — run `make artifacts` for the PJRT path)");
        return Ok(());
    }
    let coord = Coordinator::new(config)?;
    coord.register_model("ge", hmm.clone());

    // T = 100k exceeds the largest compiled core artifact (T = 8192), so
    // Auto routes through the §V-B temporal sharder.
    let req = DecodeRequest::new(1, "ge", tr.observations.clone(), Algo::Smooth);
    let plan = coord.plan_for(&req)?;
    println!("\ncoordinator plan for T={t}: {}", plan.describe(t));
    let resp = coord.decode(req)?;
    println!("sharded smoother:          {:?}  plan {}", resp.elapsed, resp.plan);
    let DecodeResult::Posterior(post) = &resp.result else { unreachable!() };
    let max_err = post
        .gamma_flat()
        .iter()
        .zip(smooth_seq.gamma_flat())
        .fold(0.0f64, |m, (&a, &b)| m.max((a - b).abs()));
    println!("sharded vs native-seq max |Δγ|: {max_err:.2e}");
    assert!(max_err < 1e-3, "sharded path disagrees: {max_err}");

    // A short request exercises the padded PJRT core-artifact path.
    let short: Vec<u32> = tr.observations[..1000].to_vec();
    let resp = coord.decode(
        DecodeRequest::new(2, "ge", short.clone(), Algo::Map).with_mode(ExecMode::Pjrt),
    )?;
    println!("\npjrt core (T=1000 padded): {:?}  plan {}", resp.elapsed, resp.plan);
    let DecodeResult::Map(est) = &resp.result else { unreachable!() };
    let native = engine.run(Algorithm::Viterbi, &short)?.into_map()?;
    assert!((est.log_prob - native.log_prob).abs() < 1e-2);
    println!("\nall layers agree ✓");
    Ok(())
}

//! §Perf decomposition probe: stage-by-stage timing of the SP-Par
//! smoother's internals (element construction, clones, forward/backward
//! scans) used to find the next bottleneck during the optimization pass
//! (EXPERIMENTS.md §Perf). Deliberately below the `engine` API — this
//! probe times the raw primitives the engine composes.
//!
//!     cargo run --release --example perf_probe2
use hmm_scan::elements::*;
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::rng::Xoshiro256StarStar;
use hmm_scan::scan::*;
use std::time::Instant;

fn main() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut rng = Xoshiro256StarStar::seed_from_u64(1);
    let tr = sample(&hmm, 100_000, &mut rng);
    let ys = &tr.observations;
    let opts = ScanOptions::default();
    let d = 4;
    let op = SpOp { d };

    let t0 = Instant::now();
    let elems = sp_element_chain(&hmm, ys);
    println!("element chain: {:?}", t0.elapsed());

    let t0 = Instant::now();
    let mut fwd = elems.clone();
    println!("clone: {:?}", t0.elapsed());

    let t0 = Instant::now();
    run_scan(&op, &mut fwd, opts);
    println!("fwd scan (chunked, {} threads): {:?}", opts.threads, t0.elapsed());

    let t0 = Instant::now();
    let mut bwd = elems[1..].to_vec();
    bwd.push(sp_terminal(d));
    println!("bwd build: {:?}", t0.elapsed());

    let t0 = Instant::now();
    run_scan_rev(&op, &mut bwd, opts);
    println!("bwd scan: {:?}", t0.elapsed());

    let t0 = Instant::now();
    let mut fwd2 = elems.clone();
    run_scan(&op, &mut fwd2, ScanOptions { threads: 1, ..opts });
    println!("fwd scan 1 thread: {:?}", t0.elapsed());
    std::hint::black_box((&fwd, &bwd));
}

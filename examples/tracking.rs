//! Constant-velocity target tracking on the Kalman tier: simulate a
//! noisy 2-D trajectory, then recover it with the classical filter
//! (`KfSeq`), the parallel-scan filter (`KfPar`), and the parallel-scan
//! smoother (`KsPar`) — the Gaussian analogue of `quickstart.rs`.
//!
//!     cargo run --release --example tracking

use hmm_scan::engine::Algorithm;
use hmm_scan::kalman::{KalmanEngine, Lgssm};
use hmm_scan::rng::Xoshiro256StarStar;

/// One standard-normal draw (Box–Muller; half the pair is discarded —
/// throughput is irrelevant here).
fn gauss(rng: &mut Xoshiro256StarStar) -> f64 {
    let u1 = rng.next_f64().max(1e-12);
    let u2 = rng.next_f64();
    (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
}

fn main() -> hmm_scan::Result<()> {
    // 4 states [px, py, vx, vy], 2 observations [px, py].
    let dt = 0.1;
    let (q, r) = (0.8, 0.5);
    let model = Lgssm::constant_velocity(dt, q, r);

    // Simulate a gently curving ground-truth trajectory and observe its
    // position through N(0, r·I) measurement noise.
    let t_len = 400usize;
    let mut rng = Xoshiro256StarStar::seed_from_u64(42);
    let mut truth = Vec::with_capacity(t_len); // (px, py) per step
    let mut obs = Vec::with_capacity(2 * t_len); // row-major [T, 2]
    let (mut px, mut py, mut vx, mut vy) = (0.0f64, 0.0f64, 1.5f64, 0.4f64);
    for k in 0..t_len {
        // Small deterministic turn plus white-noise acceleration.
        let turn = 0.4 * (k as f64 * dt * 0.5).sin();
        vx += dt * (turn + q.sqrt() * gauss(&mut rng));
        vy += dt * (-turn + q.sqrt() * gauss(&mut rng));
        px += dt * vx;
        py += dt * vy;
        truth.push((px, py));
        obs.push(px + r.sqrt() * gauss(&mut rng));
        obs.push(py + r.sqrt() * gauss(&mut rng));
    }

    // One engine serves all four Gaussian algorithms; parallel variants
    // reuse its scratch workspace across calls.
    let mut engine = KalmanEngine::new(model);
    let kf_seq = engine.run(Algorithm::KfSeq, &obs)?;
    let kf_par = engine.run(Algorithm::KfPar, &obs)?;
    let ks_par = engine.run(Algorithm::KsPar, &obs)?;

    // The classical and parallel-scan filters compute the same posterior
    // up to floating-point associativity (the paper's premise, carried
    // over to the Gaussian family of arXiv:1905.13002).
    let (ls, lp) = (kf_seq.log_likelihood(), kf_par.log_likelihood());
    println!("log p(y) = {ls:.9} (KF-Seq) / {lp:.9} (KF-Par)");
    let rel = ((ls - lp) / ls.abs().max(1.0)).abs();
    assert!(rel < 1e-9, "seq/par filters disagree: rel err {rel:e}");

    // Each posterior row is [mean (4), covariance (4x4, row-major)];
    // the position estimate is the first two mean entries.
    let rmse = |post: &hmm_scan::inference::Posterior| -> f64 {
        let mut acc = 0.0;
        for (k, &(tx, ty)) in truth.iter().enumerate() {
            let row = post.gamma(k);
            acc += (row[0] - tx).powi(2) + (row[1] - ty).powi(2);
        }
        (acc / t_len as f64).sqrt()
    };
    let raw = {
        let mut acc = 0.0;
        for (k, &(tx, ty)) in truth.iter().enumerate() {
            acc += (obs[2 * k] - tx).powi(2) + (obs[2 * k + 1] - ty).powi(2);
        }
        (acc / t_len as f64).sqrt()
    };
    println!("\nposition RMSE vs ground truth over T = {t_len}:");
    println!("  raw observations   {raw:8.4}");
    println!("  filtered  (KF-Par) {:8.4}", rmse(&kf_par));
    println!("  smoothed  (KS-Par) {:8.4}", rmse(&ks_par));

    // Tail of the track: smoothing tightens the filter's estimates
    // everywhere except the final step, where they coincide.
    println!("\n   k     truth         filtered       smoothed");
    for k in (t_len - 5)..t_len {
        let (tx, ty) = truth[k];
        let f = kf_par.gamma(k);
        let s = ks_par.gamma(k);
        println!(
            "{k:>4}  ({tx:6.2},{ty:6.2})  ({:6.2},{:6.2})  ({:6.2},{:6.2})",
            f[0], f[1], s[0], s[1]
        );
    }
    let last_f = kf_par.gamma(t_len - 1);
    let last_s = ks_par.gamma(t_len - 1);
    assert!((last_f[0] - last_s[0]).abs() < 1e-6);
    Ok(())
}

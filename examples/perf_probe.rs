//! §Perf probe: end-to-end timings of every native method at the
//! paper's largest T (10⁵), dispatched through the unified `Engine`
//! (so repeated runs exercise the workspace-reuse hot path). The
//! before/after iteration log built from this probe is recorded in
//! EXPERIMENTS.md §Perf.
//!
//!     cargo run --release --example perf_probe
use hmm_scan::engine::{Algorithm, Engine};
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::scan::ScanOptions;
use hmm_scan::rng::Xoshiro256StarStar;
use std::time::Instant;

fn main() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut rng = Xoshiro256StarStar::seed_from_u64(1);
    let tr = sample(&hmm, 100_000, &mut rng);
    let ys = &tr.observations;
    let mut engine =
        Engine::builder(hmm).scan_options(ScanOptions::default()).build();
    for alg in [
        Algorithm::SpSeq,
        Algorithm::SpPar,
        Algorithm::BsPar,
        Algorithm::MpSeq,
        Algorithm::MpPar,
        Algorithm::Viterbi,
    ] {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            std::hint::black_box(engine.run(alg, ys).unwrap());
            best = best.min(t0.elapsed().as_secs_f64());
        }
        println!("{}: {:.1}ms", alg.name(), best * 1e3);
    }
}

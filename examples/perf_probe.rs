//! §Perf probe: end-to-end timings of every native method at the
//! paper's largest T (10⁵). The before/after iteration log built from
//! this probe is recorded in EXPERIMENTS.md §Perf.
//!
//!     cargo run --release --example perf_probe
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::inference::*;
use hmm_scan::scan::ScanOptions;
use hmm_scan::rng::Xoshiro256StarStar;
use std::time::Instant;
fn main() {
    let hmm = gilbert_elliott(GeParams::default());
    let mut rng = Xoshiro256StarStar::seed_from_u64(1);
    let tr = sample(&hmm, 100_000, &mut rng);
    let ys = &tr.observations;
    let opts = ScanOptions::default();
    for (name, f) in [
        ("sp_seq", Box::new(|| { sp_seq(&hmm, ys).unwrap().log_likelihood() }) as Box<dyn Fn() -> f64>),
        ("sp_par", Box::new(|| { sp_par(&hmm, ys, opts).unwrap().log_likelihood() })),
        ("bs_par", Box::new(|| { bs_par(&hmm, ys, opts).unwrap().log_likelihood() })),
        ("mp_seq", Box::new(|| { mp_seq(&hmm, ys).unwrap().log_prob })),
        ("mp_par", Box::new(|| { mp_par(&hmm, ys, opts).unwrap().log_prob })),
        ("viterbi", Box::new(|| { viterbi(&hmm, ys).unwrap().log_prob })),
    ] {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            std::hint::black_box(f());
            best = best.min(t0.elapsed().as_secs_f64());
        }
        println!("{name}: {:.1}ms", best*1e3);
    }
}

//! CpG-island detection — a classic bioinformatics HMM application
//! (one of the domains the paper's introduction motivates).
//!
//! A two-regime HMM over the DNA alphabet {A, C, G, T}: inside CpG
//! islands C/G are enriched; outside, A/T dominate. We synthesize a
//! genome with known island boundaries, then segment it through the
//! unified `Engine` — the parallel smoother and the parallel max-product
//! MAP estimator — and score boundary recovery.
//!
//!     cargo run --release --example cpg_islands

use hmm_scan::engine::{Algorithm, Engine};
use hmm_scan::hmm::Hmm;
use hmm_scan::linalg::Mat;
use hmm_scan::rng::Xoshiro256StarStar;
use hmm_scan::scan::ScanOptions;

const ISLAND: usize = 0;
const SEA: usize = 1;

fn model() -> hmm_scan::Result<Hmm> {
    // Sticky regimes: islands ~1k bases, seas ~10k bases.
    let pi = Mat::from_vec(2, 2, vec![0.999, 0.001, 0.0001, 0.9999]);
    // Emissions over A, C, G, T.
    let obs = Mat::from_vec(
        2,
        4,
        vec![
            0.15, 0.35, 0.35, 0.15, // island: CG-rich
            0.30, 0.20, 0.20, 0.30, // sea: AT-rich
        ],
    );
    Hmm::new(pi, obs, vec![0.1, 0.9])
}

fn main() -> hmm_scan::Result<()> {
    let hmm = model()?;
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xD2A);

    // Synthesize a 50kb genome from the generative model itself.
    let t = 50_000;
    let tr = hmm_scan::hmm::sample(&hmm, t, &mut rng);
    let true_islands: usize = tr.states.iter().filter(|&&x| x == ISLAND as u32).count();
    println!("synthetic genome: {t} bases, {true_islands} island bases");

    // Posterior segmentation (smoothing) and MAP segmentation, both
    // through one engine.
    let mut engine =
        Engine::builder(hmm).scan_options(ScanOptions::default()).build();
    let post = engine.run(Algorithm::SpPar, &tr.observations)?.into_posterior()?;
    let map = engine.run(Algorithm::MpPar, &tr.observations)?.into_map()?;

    // Confusion statistics for the MAP segmentation.
    let (mut tp, mut fp, mut fnn, mut tn) = (0usize, 0usize, 0usize, 0usize);
    for (&truth, &est) in tr.states.iter().zip(&map.path) {
        match (truth == ISLAND as u32, est == ISLAND as u32) {
            (true, true) => tp += 1,
            (false, true) => fp += 1,
            (true, false) => fnn += 1,
            (false, false) => tn += 1,
        }
    }
    let precision = tp as f64 / (tp + fp).max(1) as f64;
    let recall = tp as f64 / (tp + fnn).max(1) as f64;
    println!("\nMAP segmentation:");
    println!("  precision {precision:.3}  recall {recall:.3}  (tp {tp} fp {fp} fn {fnn} tn {tn})");
    assert!(precision > 0.6 && recall > 0.4, "segmentation degenerated");

    // Island calls from the posterior: P(island) > 0.5.
    let post_calls: Vec<u32> = (0..t)
        .map(|k| if post.gamma(k)[ISLAND] > 0.5 { ISLAND as u32 } else { SEA as u32 })
        .collect();
    let agree = post_calls
        .iter()
        .zip(&map.path)
        .filter(|(a, b)| a == b)
        .count() as f64
        / t as f64;
    println!("\nposterior-threshold vs MAP agreement: {agree:.4}");

    // Report the called island segments (merged runs) — the artifact a
    // genomicist would consume.
    let mut segments: Vec<(usize, usize)> = Vec::new();
    let mut start = None;
    for (k, &s) in map.path.iter().enumerate() {
        match (s == ISLAND as u32, start) {
            (true, None) => start = Some(k),
            (false, Some(s0)) => {
                segments.push((s0, k));
                start = None;
            }
            _ => {}
        }
    }
    if let Some(s0) = start {
        segments.push((s0, t));
    }
    println!("\ncalled {} island segments; first 10:", segments.len());
    for (s, e) in segments.iter().take(10) {
        println!("  [{s:>6}, {e:>6})  len {}", e - s);
    }
    println!("\nlog p(y) = {:.3}, MAP log p* = {:.3}", post.log_likelihood(), map.log_prob);
    Ok(())
}

//! Quickstart: define an HMM, build an inference `Engine`, run smoothing
//! and MAP inference, and compare the sequential and parallel-scan
//! schedules — one entry point for every algorithm.
//!
//!     cargo run --release --example quickstart

use hmm_scan::engine::{Algorithm, Engine};
use hmm_scan::hmm::Hmm;
use hmm_scan::linalg::Mat;
use hmm_scan::scan::ScanOptions;

fn main() -> hmm_scan::Result<()> {
    // A 2-state weather model: states {Sunny, Rainy}, observations
    // {Dry, Damp, Wet}.
    let hmm = Hmm::new(
        Mat::from_vec(2, 2, vec![0.8, 0.2, 0.4, 0.6]), // transitions
        Mat::from_vec(2, 3, vec![0.62, 0.28, 0.10, 0.15, 0.38, 0.47]), // emissions
        vec![0.7, 0.3],                                // prior
    )?;

    // One engine serves every algorithm; repeated calls reuse its
    // scratch workspace.
    let mut engine = Engine::builder(hmm).scan_options(ScanOptions::default()).build();

    // A week of observations: Dry, Dry, Damp, Wet, Wet, Damp, Dry.
    let ys = vec![0u32, 0, 1, 2, 2, 1, 0];

    // Smoothing marginals p(x_k | y_{1:T}) — classical and parallel-scan
    // engines are algebraically equivalent (the paper's premise).
    let seq = engine.run(Algorithm::SpSeq, &ys)?.into_posterior()?;
    let par = engine.run(Algorithm::SpPar, &ys)?.into_posterior()?;
    println!("log p(y) = {:.6} (seq) / {:.6} (par)", seq.log_likelihood(), par.log_likelihood());
    println!("\nday  p(Sunny)  p(Rainy)");
    for (k, _) in ys.iter().enumerate() {
        println!("{k:>3}  {:>8.4}  {:>8.4}", par.gamma(k)[0], par.gamma(k)[1]);
    }

    // MAP (Viterbi) path via the classical algorithm and via the
    // parallel max-product scans (Algorithm 5).
    let vit = engine.run(Algorithm::Viterbi, &ys)?.into_map()?;
    let mpp = engine.run(Algorithm::MpPar, &ys)?.into_map()?;
    let names = ["Sunny", "Rainy"];
    println!("\nViterbi path:     {:?}", vit.path.iter().map(|&s| names[s as usize]).collect::<Vec<_>>());
    println!("Max-product path: {:?}", mpp.path.iter().map(|&s| names[s as usize]).collect::<Vec<_>>());
    println!("log p* = {:.6} (viterbi) / {:.6} (mp-par)", vit.log_prob, mpp.log_prob);
    assert!((vit.log_prob - mpp.log_prob).abs() < 1e-9);

    // Batched entry point: many sequences in one call, fanned out over
    // the thread pool with one workspace per worker.
    let batch = vec![ys.clone(), vec![2, 2, 2, 1, 0], vec![0, 0]];
    let results = engine.run_batch(Algorithm::SpPar, &batch);
    println!("\nbatched log-likelihoods:");
    for (i, r) in results.iter().enumerate() {
        let post = r.as_ref().unwrap().as_posterior().unwrap();
        println!("  seq {i} (T={}): {:.6}", post.len(), post.log_likelihood());
    }
    Ok(())
}

//! Quickstart: define an HMM, run smoothing and MAP inference, compare
//! the sequential and parallel-scan engines.
//!
//!     cargo run --release --example quickstart

use hmm_scan::hmm::Hmm;
use hmm_scan::inference::{mp_par, sp_par, sp_seq, viterbi};
use hmm_scan::linalg::Mat;
use hmm_scan::scan::ScanOptions;

fn main() -> hmm_scan::Result<()> {
    // A 2-state weather model: states {Sunny, Rainy}, observations
    // {Dry, Damp, Wet}.
    let hmm = Hmm::new(
        Mat::from_vec(2, 2, vec![0.8, 0.2, 0.4, 0.6]), // transitions
        Mat::from_vec(2, 3, vec![0.62, 0.28, 0.10, 0.15, 0.38, 0.47]), // emissions
        vec![0.7, 0.3],                                // prior
    )?;

    // A week of observations: Dry, Dry, Damp, Wet, Wet, Damp, Dry.
    let ys = vec![0u32, 0, 1, 2, 2, 1, 0];

    // Smoothing marginals p(x_k | y_{1:T}) — classical and parallel-scan
    // engines are algebraically equivalent (the paper's premise).
    let seq = sp_seq(&hmm, &ys)?;
    let par = sp_par(&hmm, &ys, ScanOptions::default())?;
    println!("log p(y) = {:.6} (seq) / {:.6} (par)", seq.log_likelihood(), par.log_likelihood());
    println!("\nday  p(Sunny)  p(Rainy)");
    for (k, _) in ys.iter().enumerate() {
        println!("{k:>3}  {:>8.4}  {:>8.4}", par.gamma(k)[0], par.gamma(k)[1]);
    }

    // MAP (Viterbi) path via the classical algorithm and via the
    // parallel max-product scans (Algorithm 5).
    let vit = viterbi(&hmm, &ys)?;
    let mpp = mp_par(&hmm, &ys, ScanOptions::default())?;
    let names = ["Sunny", "Rainy"];
    println!("\nViterbi path:     {:?}", vit.path.iter().map(|&s| names[s as usize]).collect::<Vec<_>>());
    println!("Max-product path: {:?}", mpp.path.iter().map(|&s| names[s as usize]).collect::<Vec<_>>());
    println!("log p* = {:.6} (viterbi) / {:.6} (mp-par)", vit.log_prob, mpp.log_prob);
    assert!((vit.log_prob - mpp.log_prob).abs() < 1e-9);
    Ok(())
}

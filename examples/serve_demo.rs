//! Serving demo: the coordinator under a mixed batched load —
//! heterogeneous sequence lengths and algorithms, exercising the router
//! (padded core artifacts, sharded plans, native fallback), the dynamic
//! batcher, and the XLA worker pool; reports latency and throughput.
//! Native plans dispatch through the per-model `engine::Engine` (reused
//! workspaces); PJRT plans through its `XlaBackend`.
//!
//!     cargo run --release --example serve_demo

use std::sync::Arc;
use std::time::Instant;

use hmm_scan::coordinator::{
    Algo, Coordinator, CoordinatorConfig, DecodeRequest,
};
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::rng::Xoshiro256StarStar;

fn main() -> hmm_scan::Result<()> {
    let config = CoordinatorConfig::default();
    let pjrt = config.artifacts.is_some();
    let coord = Arc::new(Coordinator::new(config)?);
    let hmm = gilbert_elliott(GeParams::default());
    coord.register_model("ge", hmm.clone());
    println!(
        "coordinator up ({} mode)",
        if pjrt { "pjrt+native" } else { "native-only" }
    );

    let handle = Arc::clone(&coord).serve();
    let mut rng = Xoshiro256StarStar::seed_from_u64(7);

    // Mixed workload: mostly short/medium requests (hit the padded core
    // artifacts), a few long ones (sharded), mixed algorithms.
    let lengths = [60usize, 100, 120, 900, 1000, 4000, 9000];
    let n = 200;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let t = lengths[i % lengths.len()];
            let tr = sample(&hmm, t, &mut rng);
            let algo = match i % 3 {
                0 => Algo::Smooth,
                1 => Algo::Map,
                _ => Algo::BayesSmooth,
            };
            handle.submit(DecodeRequest::new(i as u64, "ge", tr.observations, algo))
        })
        .collect();

    let mut plans: std::collections::BTreeMap<String, usize> = Default::default();
    let mut failures = 0usize;
    for rx in rxs {
        match rx.recv().expect("server dropped") {
            Ok(resp) => {
                // strip pad detail so plans aggregate
                let key = resp.plan.split(" pad=").next().unwrap().to_string();
                *plans.entry(key).or_default() += 1;
            }
            Err(e) => {
                eprintln!("request failed: {e}");
                failures += 1;
            }
        }
    }
    let wall = t0.elapsed();
    handle.shutdown();

    println!("\nserved {} requests in {wall:?} ({failures} failures)", n);
    println!("throughput: {:.1} req/s", n as f64 / wall.as_secs_f64());
    println!("\nplan distribution:");
    for (plan, count) in &plans {
        println!("  {count:>4}  {plan}");
    }
    let snap = coord.metrics().snapshot();
    println!(
        "\nlatency: p50 {}µs  p99 {}µs  max {}µs",
        snap.p50_us, snap.p99_us, snap.max_us
    );
    println!(
        "batches: {} (mean occupancy {:.2}); sharded blocks executed: {}",
        snap.batches,
        snap.batch_occupancy(),
        snap.sharded_blocks
    );
    assert_eq!(failures, 0);
    Ok(())
}

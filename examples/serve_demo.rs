//! Serving demo: the coordinator under a mixed batched load —
//! heterogeneous sequence lengths and algorithms, exercising the router
//! (padded core artifacts, sharded plans, native fallback), the dynamic
//! batcher, and the XLA worker pool; reports latency and throughput.
//! Native plans dispatch through the per-model `engine::Engine` (reused
//! workspaces); PJRT plans through its `XlaBackend`.
//!
//! A second phase drives the *streaming* verbs end-to-end: a client
//! opens sessions, appends observation chunks as they "arrive" (each
//! append returning the filtering marginal plus a fixed-lag smoothing
//! window), and closes for the exact posterior — the shutdown summary
//! reports per-append latency and the suffix-rescan width histogram.
//!
//! A third phase exercises the *durable session store*: a disk-backed
//! coordinator with a small resident watermark serves 4× more open
//! sessions than fit in RAM (evict → transparent restore on append,
//! with the spills and log compactions running on the background
//! housekeeping worker and append fsyncs batched by group commit),
//! reports residency via `StreamVerb::Stat`, is dropped mid-flight
//! ("crash"), and a fresh coordinator recovers every session from the
//! append-ahead logs' *metadata* (frame headers, not bodies) — with
//! closes bit-identical to clean engine runs.
//!
//! A fourth phase drives the coordinator over the *network layer*: a
//! `net::NetServer` on loopback, a `net::NetClient` running decodes and
//! a full streaming lifecycle over TCP, with every response asserted
//! **bit-identical** to the same request issued in-process — then a
//! graceful drain.
//!
//!     cargo run --release --example serve_demo

use std::sync::Arc;
use std::time::{Duration, Instant};

use hmm_scan::coordinator::{
    Algo, Coordinator, CoordinatorConfig, DecodeRequest, DecodeResult,
    StreamReply, StreamRequest,
};
use hmm_scan::engine::{Algorithm, Engine, SessionOptions, DEFAULT_SESSION_BLOCK};
use hmm_scan::hmm::{gilbert_elliott, sample, GeParams};
use hmm_scan::net::{NetClient, NetServer, NetServerConfig};
use hmm_scan::rng::Xoshiro256StarStar;
use hmm_scan::scan::ScanOptions;

fn main() -> hmm_scan::Result<()> {
    let config = CoordinatorConfig::default();
    let pjrt = config.artifacts.is_some();
    let coord = Arc::new(Coordinator::new(config)?);
    let hmm = gilbert_elliott(GeParams::default());
    coord.register_model("ge", hmm.clone());
    println!(
        "coordinator up ({} mode)",
        if pjrt { "pjrt+native" } else { "native-only" }
    );

    let handle = Arc::clone(&coord).serve();
    let mut rng = Xoshiro256StarStar::seed_from_u64(7);

    // Mixed workload: mostly short/medium requests (hit the padded core
    // artifacts), a few long ones (sharded), mixed algorithms.
    let lengths = [60usize, 100, 120, 900, 1000, 4000, 9000];
    let n = 200;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let t = lengths[i % lengths.len()];
            let tr = sample(&hmm, t, &mut rng);
            let algo = match i % 3 {
                0 => Algo::Smooth,
                1 => Algo::Map,
                _ => Algo::BayesSmooth,
            };
            handle.submit(DecodeRequest::new(i as u64, "ge", tr.observations, algo))
        })
        .collect();

    let mut plans: std::collections::BTreeMap<String, usize> = Default::default();
    let mut failures = 0usize;
    for rx in rxs {
        match rx.recv().expect("server dropped") {
            Ok(resp) => {
                // strip pad detail so plans aggregate
                let key = resp.plan.split(" pad=").next().unwrap().to_string();
                *plans.entry(key).or_default() += 1;
            }
            Err(e) => {
                eprintln!("request failed: {e}");
                failures += 1;
            }
        }
    }
    let wall = t0.elapsed();

    // ---- streaming phase: open → N appends → close, per session ------
    let sessions = 4usize;
    let appends_per_session = 25usize;
    let lag = 32usize;
    let t1 = Instant::now();
    let mut stream_failures = 0usize;
    for sid in 0..sessions {
        let opened = handle
            .submit_stream(StreamRequest::open(1000 + sid as u64, "ge", lag))
            .recv()
            .expect("server dropped")?;
        let StreamReply::Opened { session } = opened.reply else {
            panic!("expected Opened, got {:?}", opened.reply)
        };
        let mut running_loglik = f64::NAN;
        for a in 0..appends_per_session {
            // Chunky arrivals: 1..=40 observations per append.
            let k = 1 + (sid * 7 + a * 13) % 40;
            let chunk = sample(&hmm, k, &mut rng).observations;
            let resp = handle
                .submit_stream(StreamRequest::append(a as u64, session, chunk))
                .recv()
                .expect("server dropped");
            match resp {
                Ok(r) => {
                    if let StreamReply::Appended { filtered, window, .. } = r.reply {
                        running_loglik = filtered.log_likelihood;
                        let win = window.expect("lag > 0");
                        assert_eq!(
                            win.start + win.posterior.len(),
                            filtered.step,
                            "window must end at the stream head"
                        );
                    }
                }
                Err(e) => {
                    eprintln!("append failed: {e}");
                    stream_failures += 1;
                }
            }
        }
        let closed = handle
            .submit_stream(StreamRequest::close(2000 + sid as u64, session))
            .recv()
            .expect("server dropped")?;
        if let StreamReply::Closed { posterior, .. } = closed.reply {
            // The exact posterior agrees with the running filter at T.
            assert!(
                (posterior.log_likelihood() - running_loglik).abs()
                    < 1e-6 * (1.0 + running_loglik.abs()),
                "close/filter log-likelihood mismatch"
            );
        }
    }
    let stream_wall = t1.elapsed();
    handle.shutdown();

    println!("\nserved {} requests in {wall:?} ({failures} failures)", n);
    println!("throughput: {:.1} req/s", n as f64 / wall.as_secs_f64());
    println!("\nplan distribution:");
    for (plan, count) in &plans {
        println!("  {count:>4}  {plan}");
    }
    let snap = coord.metrics().snapshot();
    println!(
        "\nlatency: p50 {}µs  p99 {}µs  max {}µs",
        snap.p50_us, snap.p99_us, snap.max_us
    );
    println!(
        "batches: {} (mean occupancy {:.2}); sharded blocks executed: {}",
        snap.batches,
        snap.batch_occupancy(),
        snap.sharded_blocks
    );
    println!(
        "\nstreaming: {} sessions ({} closed), {} appends ({:.1} obs/append) in {stream_wall:?}",
        snap.sessions_opened,
        snap.sessions_closed,
        snap.appends,
        snap.append_occupancy(),
    );
    println!(
        "append latency: p50 {}µs  p99 {}µs  max {}µs",
        snap.append_p50_us, snap.append_p99_us, snap.append_max_us
    );
    println!("suffix-rescan width histogram (fixed-lag {lag}):");
    for (bucket, count) in &snap.suffix_width_hist {
        println!("  ≤{bucket:>6}  {count:>5}");
    }
    assert_eq!(failures, 0);
    assert_eq!(stream_failures, 0);
    assert_eq!(snap.sessions_closed, sessions as u64);

    // ---- durability phase: evict → restore → crash → recover ---------
    let store_dir = std::env::temp_dir()
        .join(format!("hmm-scan-serve-demo-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let durable_config = || CoordinatorConfig {
        resident_watermark: 8,
        session_store: Some(store_dir.clone()),
        checkpoint_every: 512,
        ..CoordinatorConfig::native_only()
    };
    let open_n = 32usize; // 4× the watermark stays concurrently open
    let t2 = Instant::now();
    let mut ledger: Vec<(u64, Vec<u32>)> = Vec::new();
    {
        let coord = Coordinator::new(durable_config())?;
        coord.register_model("ge", hmm.clone());
        for i in 0..open_n {
            let resp =
                coord.stream(StreamRequest::open(5000 + i as u64, "ge", 0))?;
            let StreamReply::Opened { session } = resp.reply else {
                panic!("expected Opened, got {:?}", resp.reply)
            };
            ledger.push((session, Vec::new()));
        }
        // Round-robin appends: every session's turn finds it evicted,
        // and the append restores it transparently. Quiescing between
        // rounds makes the worker's spills observable before the next
        // round's appends (which then deterministically restore).
        for round in 0..4usize {
            for (session, ys) in ledger.iter_mut() {
                let k = 5 + (*session as usize + round) % 24;
                let chunk = sample(&hmm, k, &mut rng).observations;
                coord.stream(StreamRequest::append(1, *session, chunk.clone()))?;
                ys.extend_from_slice(&chunk);
            }
            coord.quiesce_housekeeping();
        }
        // Barrier: the spills run on the housekeeping worker — drain it
        // before reading the residency gauges.
        coord.quiesce_housekeeping();
        let probe = ledger[0].0;
        let resp = coord.stream(StreamRequest::stat(2, probe))?;
        if let StreamReply::Stats {
            len, resident, open_sessions, resident_sessions, ..
        } = resp.reply
        {
            println!(
                "\ndurable store at {}:\n  session {probe}: len={len} \
                 resident={resident}; {open_sessions} open / \
                 {resident_sessions} resident (watermark 8, \
                 ~{} resident KiB)",
                store_dir.display(),
                coord.resident_bytes() / 1024,
            );
        }
        let snap = coord.metrics().snapshot();
        println!(
            "  spills: {}  restores: {}  (restore p50 {}µs  p99 {}µs)",
            snap.spills, snap.restores, snap.restore_p50_us, snap.restore_p99_us
        );
        println!(
            "  housekeeping: {} tasks run, queue depth {}; group commit: \
             {} sync batches ({:.2} appends/sync)",
            snap.hk_completed,
            snap.hk_queue_depth,
            snap.sync_batches,
            snap.sync_batch_occupancy(),
        );
        assert!(snap.spills > 0 && snap.restores > 0, "eviction never engaged");
        assert!(snap.hk_completed > 0, "housekeeping worker never ran");
        // "Crash": drop the coordinator without closing a single session.
    }

    let coord = Coordinator::new(durable_config())?;
    coord.register_model("ge", hmm.clone());
    let recovered = coord.recover_sessions()?;
    let snap = coord.metrics().snapshot();
    println!(
        "  after crash: recovered {recovered}/{open_n} sessions in {}µs \
         (metadata-only scan — log bodies stay on disk until first touch)",
        snap.recovery_scan_us
    );
    assert_eq!(recovered, open_n);

    // Every recovered session keeps serving: append once more, close,
    // and spot-check the posterior against a clean one-shot engine run.
    let mut verified = 0usize;
    for (session, ys) in ledger.iter_mut() {
        let chunk = sample(&hmm, 7, &mut rng).observations;
        coord.stream(StreamRequest::append(3, *session, chunk.clone()))?;
        ys.extend_from_slice(&chunk);
        let resp = coord.stream(StreamRequest::close(4, *session))?;
        let StreamReply::Closed { posterior, .. } = resp.reply else {
            panic!("expected Closed, got {:?}", resp.reply)
        };
        if verified < 4 {
            let mut engine = Engine::builder(hmm.clone())
                .scan_options(
                    ScanOptions::default().with_block(DEFAULT_SESSION_BLOCK),
                )
                .build();
            let want = engine.run(Algorithm::SpPar, ys)?.into_posterior()?;
            assert_eq!(posterior, want, "recovered session diverged");
            verified += 1;
        }
    }
    let snap = coord.metrics().snapshot();
    println!(
        "  {} sessions closed after recovery ({verified} verified \
         bit-identical to clean runs), {} restores, in {:?}",
        open_n,
        snap.restores,
        t2.elapsed()
    );
    let _ = std::fs::remove_dir_all(&store_dir);

    // ---- network phase: the coordinator over TCP loopback ------------
    let net_coord = Arc::new(Coordinator::new(CoordinatorConfig::native_only())?);
    net_coord.register_model("ge", hmm.clone());
    let server = NetServer::start(
        Arc::clone(&net_coord),
        "127.0.0.1:0",
        NetServerConfig::default(),
    )?;
    let addr = server.local_addr();
    println!("\nnetwork layer up on {addr} (wire protocol v{})",
             hmm_scan::net::WIRE_VERSION);
    let t3 = Instant::now();
    let mut client = NetClient::connect(addr.to_string())?;
    client.ping()?;

    // Decodes over the wire, bit-identical to in-process.
    let mut wire_ok = 0usize;
    for i in 0..24usize {
        let t = [120usize, 900, 4000][i % 3];
        let ys = sample(&hmm, t, &mut rng).observations;
        let algo = if i % 2 == 0 { Algo::Smooth } else { Algo::Map };
        let remote = client.decode(&DecodeRequest::new(i as u64, "ge", ys.clone(), algo))?;
        let local = net_coord.decode(DecodeRequest::new(i as u64, "ge", ys, algo))?;
        let identical = match (&remote.result, &local.result) {
            (DecodeResult::Posterior(a), DecodeResult::Posterior(b)) => a == b,
            (DecodeResult::Map(a), DecodeResult::Map(b)) => a == b,
            _ => false,
        };
        assert!(identical, "wire decode diverged from in-process");
        wire_ok += 1;
    }

    // A streaming lifecycle over the wire, mirrored in-process.
    let remote_sid = client.open("ge", SessionOptions::default(), 32)?;
    let opened = net_coord.stream(StreamRequest::open(0, "ge", 32))?;
    let StreamReply::Opened { session: local_sid } = opened.reply else {
        panic!("expected Opened")
    };
    for round in 0..12usize {
        let k = 1 + (round * 17) % 40;
        let chunk = sample(&hmm, k, &mut rng).observations;
        let remote = client.append(remote_sid, &chunk)?;
        let local =
            net_coord.stream(StreamRequest::append(0, local_sid, chunk))?;
        let (
            StreamReply::Appended { filtered: rf, window: rw, .. },
            StreamReply::Appended { filtered: lf, window: lw, .. },
        ) = (remote, local.reply)
        else {
            panic!("expected Appended")
        };
        assert_eq!(rf, lf, "wire filtered diverged");
        assert_eq!(
            rw.map(|w| w.posterior),
            lw.map(|w| w.posterior),
            "wire lag window diverged"
        );
    }
    let remote_posterior = client.close(remote_sid)?;
    let closed = net_coord.stream(StreamRequest::close(0, local_sid))?;
    let StreamReply::Closed { posterior: local_posterior, .. } = closed.reply
    else {
        panic!("expected Closed")
    };
    assert_eq!(remote_posterior, local_posterior, "wire posterior diverged");

    drop(client);
    let graceful = server.shutdown(Duration::from_secs(5));
    let snap = net_coord.metrics().snapshot();
    println!(
        "  {wire_ok} wire decodes + 1 streaming session verified \
         bit-identical to in-process results in {:?}",
        t3.elapsed()
    );
    println!(
        "  conns: {} opened / {} refused; drain: {}",
        snap.conns_opened,
        snap.conns_refused,
        if graceful { "graceful" } else { "forced" },
    );
    for v in &snap.wire_verbs {
        println!(
            "  wire {:<7} n={:<5} p50 {}µs  p99 {}µs  max {}µs",
            v.verb, v.count, v.p50_us, v.p99_us, v.max_us
        );
    }
    assert!(graceful, "loopback drain must be graceful");
    Ok(())
}

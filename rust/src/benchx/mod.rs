//! Benchmark harness (criterion is unavailable offline — DESIGN.md §1).
//!
//! Warmup + repeated timed runs with robust statistics (median + MAD),
//! adaptive repetition targeting a time budget, and table-friendly
//! reporting. Used by `cargo bench` targets and the figure generators.
//!
//! Benches that publish machine-readable results (`bench-net` and
//! `bench-cluster` → `BENCH_net.json`) share one report file through
//! [`merge_bench_json`], each owning a named section.

use std::path::Path;
use std::time::{Duration, Instant};

use crate::jsonx::Json;

/// Bench configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Unmeasured warmup iterations.
    pub warmup_iters: usize,
    /// Minimum measured iterations, budget notwithstanding.
    pub min_iters: usize,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
    /// Stop once total measured time exceeds this budget.
    pub time_budget: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 100,
            time_budget: Duration::from_millis(500),
        }
    }
}

impl BenchConfig {
    /// Faster settings for expensive (multi-second) benchmarks.
    pub fn heavy() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            time_budget: Duration::from_secs(2),
        }
    }
}

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Row label.
    pub name: String,
    /// Iterations measured.
    pub iters: usize,
    /// Median iteration time.
    pub median: Duration,
    /// Median absolute deviation — robust spread estimate.
    pub mad: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Slowest iteration.
    pub max: Duration,
}

impl Measurement {
    /// The median as seconds (plot axes).
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Measure a closure. The closure's return value is black-boxed so the
/// optimizer cannot elide the work.
pub fn bench<F, R>(name: &str, config: BenchConfig, mut f: F) -> Measurement
where
    F: FnMut() -> R,
{
    for _ in 0..config.warmup_iters {
        black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::new();
    let budget_start = Instant::now();
    while samples.len() < config.min_iters
        || (samples.len() < config.max_iters
            && budget_start.elapsed() < config.time_budget)
    {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    summarize(name, &mut samples)
}

fn summarize(name: &str, samples: &mut [Duration]) -> Measurement {
    samples.sort_unstable();
    let median = samples[samples.len() / 2];
    let mut deviations: Vec<Duration> = samples
        .iter()
        .map(|&s| if s > median { s - median } else { median - s })
        .collect();
    deviations.sort_unstable();
    Measurement {
        name: name.to_string(),
        iters: samples.len(),
        median,
        mad: deviations[deviations.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// Optimization barrier (std::hint::black_box re-export point so callers
/// don't need the hint feature path spelled everywhere).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Merge one bench's rows into a shared machine-readable report file.
///
/// The file is a single JSON object mapping section names to row
/// arrays (e.g. `{"net": [...], "cluster": [...]}`). The existing file
/// is read and re-used when it parses; the caller's `section` is
/// replaced wholesale with `rows`, every other section is preserved.
/// An unreadable or malformed file is replaced rather than erroring —
/// a bench must never fail because a previous run was interrupted
/// mid-write.
pub fn merge_bench_json(
    path: &Path,
    section: &str,
    rows: Vec<Json>,
) -> std::io::Result<()> {
    let mut doc = match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Obj(obj)) => obj,
            _ => Default::default(),
        },
        Err(_) => Default::default(),
    };
    doc.insert(section.to_string(), Json::Arr(rows));
    std::fs::write(path, Json::Obj(doc).to_string_pretty())
}

/// Render measurements as an aligned text table.
pub fn format_table(rows: &[Measurement]) -> String {
    let mut out = String::new();
    let name_w = rows.iter().map(|r| r.name.len()).max().unwrap_or(4).max(4);
    out.push_str(&format!(
        "{:<name_w$}  {:>10}  {:>10}  {:>10}  {:>6}\n",
        "name", "median", "mad", "min", "iters"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<name_w$}  {:>10}  {:>10}  {:>10}  {:>6}\n",
            r.name,
            fmt_duration(r.median),
            fmt_duration(r.mad),
            fmt_duration(r.min),
            r.iters
        ));
    }
    out
}

/// Human-scale duration formatting (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_work() {
        let m = bench(
            "spin",
            BenchConfig {
                warmup_iters: 1,
                min_iters: 5,
                max_iters: 10,
                time_budget: Duration::from_millis(50),
            },
            || {
                let mut acc = 0u64;
                for i in 0..10_000 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            },
        );
        assert!(m.iters >= 5);
        assert!(m.median.as_nanos() > 0);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn respects_max_iters() {
        let m = bench(
            "tiny",
            BenchConfig {
                warmup_iters: 0,
                min_iters: 1,
                max_iters: 7,
                time_budget: Duration::from_secs(10),
            },
            || 1 + 1,
        );
        assert!(m.iters <= 7);
    }

    #[test]
    fn merge_preserves_other_sections() {
        use std::collections::BTreeMap;
        let dir = crate::store::testutil::tempdir("benchjson");
        let path = dir.join("BENCH_net.json");
        let row = |n: f64| {
            let mut obj = BTreeMap::new();
            obj.insert("x".to_string(), Json::Num(n));
            Json::Obj(obj)
        };
        merge_bench_json(&path, "net", vec![row(1.0)]).unwrap();
        merge_bench_json(&path, "cluster", vec![row(2.0)]).unwrap();
        // Re-running one bench replaces only its own section.
        merge_bench_json(&path, "net", vec![row(3.0)]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("net").as_arr().unwrap()[0].get("x").as_f64(), Some(3.0));
        assert_eq!(doc.get("cluster").as_arr().unwrap()[0].get("x").as_f64(), Some(2.0));
        // A corrupt file is replaced, not an error.
        std::fs::write(&path, "{truncated").unwrap();
        merge_bench_json(&path, "net", vec![row(4.0)]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("net").as_arr().unwrap()[0].get("x").as_f64(), Some(4.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000s");
        let rows = vec![summarize("x", &mut [Duration::from_millis(1)])];
        let table = format_table(&rows);
        assert!(table.contains("median"));
        assert!(table.contains('x'));
    }
}

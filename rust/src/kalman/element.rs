//! The affine-Gaussian scan elements and operators of arXiv:1905.13002.
//!
//! * [`KfElement`] / [`KfOp`] — the filtering element `(A, b, C, η, J)`
//!   (paper Lemma 7): conditionally on the previous state,
//!   `p(x_k | y_{1:k}, x_{k-1}) ∝ N(x_k; A·x_{k-1} + b, C)` with an
//!   information-form likelihood correction `(η, J)` for the evidence.
//!   The inclusive prefix product of the per-step elements yields the
//!   *filtered* posterior at every step: mean `b`, covariance `C`.
//! * [`KsElement`] / [`KsOp`] — the smoothing element `(E, g, L)`
//!   (paper Lemma 9): `p(x_k | y_{1:k}, x_{k+1}) = N(x_k; E·x_{k+1} +
//!   g, L)`. The inclusive *suffix* product (via
//!   [`crate::scan::run_scan_rev`]) yields the smoothed posterior:
//!   because the last element carries `E = 0`, every suffix collapses
//!   to `E = 0`, `g` = smoothed mean, `L` = smoothed covariance.
//! * [`kf_element_protos`] — the observation-independent parts of the
//!   steady-state element, precomputed once per model so streaming
//!   sessions can append elements one observation at a time,
//!   bit-identical to the one-shot [`kf_element_chain`] (the same
//!   contract `elements::sp_element_protos` gives the HMM sessions).
//!
//! Numerical notes (DESIGN.md §8): the combine's only inversion is of
//! `G = I + C_a·J_b`, which is nonsingular whenever `C` and `J` are PSD
//! (its eigenvalues are ≥ 1); it goes through the guarded
//! [`crate::linalg::Lu`] anyway so the combine is total on garbage
//! input. One factorization serves all five outputs — the `G⁻ᵀ`
//! applications reuse it via transpose solves. Every covariance /
//! information output is re-symmetrized.

use super::{add_assign, symmetrize, Lgssm};
use crate::linalg::{Lu, Mat};
use crate::scan::{AssocOp, ElementBuf};
use crate::semiring::Prob;

/// The filtering element `(A, b, C, η, J)` — all blocks n×n or length n.
#[derive(Debug, Clone, PartialEq)]
pub struct KfElement {
    /// Linear term of the conditional mean.
    pub a: Mat,
    /// Offset of the conditional mean (the filtered mean, at a prefix).
    pub b: Vec<f64>,
    /// Conditional covariance (the filtered covariance, at a prefix).
    pub c: Mat,
    /// Information vector of the evidence correction.
    pub eta: Vec<f64>,
    /// Information matrix of the evidence correction.
    pub j: Mat,
}

impl ElementBuf for KfElement {
    fn shape_key(&self) -> (usize, usize) {
        (self.a.rows(), self.a.cols())
    }

    fn overwrite_from(&mut self, src: &Self) {
        self.a.data_mut().copy_from_slice(src.a.data());
        self.b.copy_from_slice(&src.b);
        self.c.data_mut().copy_from_slice(src.c.data());
        self.eta.copy_from_slice(&src.eta);
        self.j.data_mut().copy_from_slice(src.j.data());
    }
}

/// The filtering combine of paper Lemma 8.
#[derive(Debug, Clone, Copy)]
pub struct KfOp {
    /// State dimension n.
    pub n: usize,
}

impl AssocOp<KfElement> for KfOp {
    fn identity(&self) -> KfElement {
        KfElement {
            a: Mat::identity::<Prob>(self.n),
            b: vec![0.0; self.n],
            c: Mat::zeros(self.n, self.n),
            eta: vec![0.0; self.n],
            j: Mat::zeros(self.n, self.n),
        }
    }

    fn combine(&self, x: &KfElement, y: &KfElement) -> KfElement {
        let n = self.n;
        // G = I + C_x·J_y — one LU factorization serves every output
        // below (G⁻¹ via plain solves, G⁻ᵀ via transpose solves).
        let mut g = x.c.matmul::<Prob>(&y.j);
        for i in 0..n {
            g[(i, i)] += 1.0;
        }
        let lu = Lu::factor(&g);

        // A = A_y·G⁻¹·A_x
        let ginv_ax = lu.solve_mat(&x.a);
        let a = y.a.matmul::<Prob>(&ginv_ax);

        // b = A_y·G⁻¹·(b_x + C_x·η_y) + b_y
        let mut v = x.c.matvec::<Prob>(&y.eta);
        for i in 0..n {
            v[i] += x.b[i];
        }
        let s = lu.solve_vec(&v);
        let mut b = y.a.matvec::<Prob>(&s);
        for i in 0..n {
            b[i] += y.b[i];
        }

        // C = A_y·G⁻¹·C_x·A_yᵀ + C_y   (symmetrized)
        let ginv_cx = lu.solve_mat(&x.c);
        let mut c = y
            .a
            .matmul::<Prob>(&ginv_cx)
            .matmul::<Prob>(&y.a.transpose());
        add_assign(&mut c, &y.c);
        symmetrize(&mut c);

        // η = A_xᵀ·G⁻ᵀ·(η_y − J_y·b_x) + η_x
        let mut w = y.j.matvec::<Prob>(&x.b);
        for i in 0..n {
            w[i] = y.eta[i] - w[i];
        }
        let u = lu.solve_transpose_vec(&w);
        let xat = x.a.transpose();
        let mut eta = xat.matvec::<Prob>(&u);
        for i in 0..n {
            eta[i] += x.eta[i];
        }

        // J = A_xᵀ·G⁻ᵀ·J_y·A_x + J_x   (symmetrized)
        let jyax = y.j.matmul::<Prob>(&x.a);
        let gt = lu.solve_transpose_mat(&jyax);
        let mut j = xat.matmul::<Prob>(&gt);
        add_assign(&mut j, &x.j);
        symmetrize(&mut j);

        KfElement { a, b, c, eta, j }
    }
}

/// The smoothing element `(E, g, L)` — E and L are n×n, g has length n.
#[derive(Debug, Clone, PartialEq)]
pub struct KsElement {
    /// Linear term of the backward conditional mean.
    pub e: Mat,
    /// Offset of the backward conditional mean (the smoothed mean, at a
    /// suffix).
    pub g: Vec<f64>,
    /// Backward conditional covariance (the smoothed covariance, at a
    /// suffix).
    pub l: Mat,
}

impl ElementBuf for KsElement {
    fn shape_key(&self) -> (usize, usize) {
        (self.e.rows(), self.e.cols())
    }

    fn overwrite_from(&mut self, src: &Self) {
        self.e.data_mut().copy_from_slice(src.e.data());
        self.g.copy_from_slice(&src.g);
        self.l.data_mut().copy_from_slice(src.l.data());
    }
}

/// The smoothing combine of paper Lemma 10 (x earlier, y later):
/// `(E_x·E_y, E_x·g_y + g_x, E_x·L_y·E_xᵀ + L_x)`.
#[derive(Debug, Clone, Copy)]
pub struct KsOp {
    /// State dimension n.
    pub n: usize,
}

impl AssocOp<KsElement> for KsOp {
    fn identity(&self) -> KsElement {
        KsElement {
            e: Mat::identity::<Prob>(self.n),
            g: vec![0.0; self.n],
            l: Mat::zeros(self.n, self.n),
        }
    }

    fn combine(&self, x: &KsElement, y: &KsElement) -> KsElement {
        let n = self.n;
        let e = x.e.matmul::<Prob>(&y.e);
        let mut g = x.e.matvec::<Prob>(&y.g);
        for i in 0..n {
            g[i] += x.g[i];
        }
        let mut l = x
            .e
            .matmul::<Prob>(&y.l)
            .matmul::<Prob>(&x.e.transpose());
        add_assign(&mut l, &x.l);
        symmetrize(&mut l);
        KsElement { e, g, l }
    }
}

/// The observation-independent parts of the steady-state (k ≥ 2)
/// filtering element, precomputed once per model: with
/// `S = H·Q·Hᵀ + R` and `K = Q·Hᵀ·S⁻¹`,
///
/// ```text
///   Φ  = (I − K·H)·A          (the element's A)
///   C̃  = (I − K·H)·Q          (the element's C)
///   J  = Aᵀ·Hᵀ·S⁻¹·H·A        (the element's J)
///   b  = K·y_k                 per observation
///   η  = W·y_k,  W = Aᵀ·Hᵀ·S⁻¹ per observation
/// ```
#[derive(Debug, Clone)]
pub struct KfProtos {
    /// Φ = (I − K·H)·A.
    pub phi: Mat,
    /// C̃ = (I − K·H)·Q, symmetrized.
    pub ctil: Mat,
    /// J = Aᵀ·Hᵀ·S⁻¹·H·A, symmetrized.
    pub j: Mat,
    /// Kalman gain K = Q·Hᵀ·S⁻¹ (n×m).
    pub gain: Mat,
    /// W = Aᵀ·Hᵀ·S⁻¹ (n×m).
    pub w: Mat,
}

/// Precompute the per-step prototypes for `model`.
pub fn kf_element_protos(model: &Lgssm) -> KfProtos {
    let (a, q, h) = (model.a(), model.q(), model.h());
    let n = model.state_dim();
    // S = H·Q·Hᵀ + R, symmetrized.
    let mut s = h.matmul::<Prob>(q).matmul::<Prob>(&h.transpose());
    add_assign(&mut s, model.r());
    symmetrize(&mut s);
    let lu_s = Lu::factor(&s);
    // K = Q·Hᵀ·S⁻¹: Kᵀ = S⁻ᵀ·H·Qᵀ solved against the factorization.
    let hqt = h.matmul::<Prob>(&q.transpose());
    let gain = lu_s.solve_transpose_mat(&hqt).transpose();
    // I − K·H.
    let mut ikh = gain.matmul::<Prob>(h);
    for r in 0..n {
        for c in 0..n {
            ikh[(r, c)] = if r == c { 1.0 - ikh[(r, c)] } else { -ikh[(r, c)] };
        }
    }
    let phi = ikh.matmul::<Prob>(a);
    let mut ctil = ikh.matmul::<Prob>(q);
    symmetrize(&mut ctil);
    // V = S⁻¹·H·A (m×n); J = (H·A)ᵀ·V; W = Aᵀ·Hᵀ·S⁻¹ = Vᵀ (S symmetric
    // by construction above, so the plain solve is the right inverse).
    let ha = h.matmul::<Prob>(a);
    let v = lu_s.solve_mat(&ha);
    let mut j = ha.transpose().matmul::<Prob>(&v);
    symmetrize(&mut j);
    let w = v.transpose();
    KfProtos { phi, ctil, j, gain, w }
}

/// The k = 1 element, which absorbs the prior: one dynamics step from
/// `(m0, P0)`, then a Joseph-form measurement update with `y`. Its
/// `A = 0` erases the (nonexistent) dependence on `x_0`, and `(η, J) =
/// (0, 0)` because the prior carries no extra evidence.
pub fn kf_prior_element(model: &Lgssm, y: &[f64]) -> KfElement {
    let n = model.state_dim();
    let h = model.h();
    // One dynamics step from the prior.
    let (m1, p1) = super::predict_moments(model, model.prior_mean(), model.prior_cov());
    // S1 = H·P1⁻·Hᵀ + R, symmetrized.
    let mut s1 = h.matmul::<Prob>(&p1).matmul::<Prob>(&h.transpose());
    add_assign(&mut s1, model.r());
    symmetrize(&mut s1);
    let lu1 = Lu::factor(&s1);
    // K1 = P1⁻·Hᵀ·S1⁻¹ = (S1⁻¹·H·P1⁻)ᵀ (both factors symmetric).
    let k1 = lu1.solve_mat(&h.matmul::<Prob>(&p1)).transpose();
    // Filtered mean m1⁻ + K1·(y − H·m1⁻).
    let hm = h.matvec::<Prob>(&m1);
    let innov: Vec<f64> = y.iter().zip(&hm).map(|(yi, hi)| yi - hi).collect();
    let mut b = k1.matvec::<Prob>(&innov);
    for i in 0..n {
        b[i] += m1[i];
    }
    // Joseph form: (I−K1·H)·P1⁻·(I−K1·H)ᵀ + K1·R·K1ᵀ, symmetrized.
    let mut ikh = k1.matmul::<Prob>(h);
    for r in 0..n {
        for c in 0..n {
            ikh[(r, c)] = if r == c { 1.0 - ikh[(r, c)] } else { -ikh[(r, c)] };
        }
    }
    let mut c = ikh.matmul::<Prob>(&p1).matmul::<Prob>(&ikh.transpose());
    let krk = k1
        .matmul::<Prob>(model.r())
        .matmul::<Prob>(&k1.transpose());
    add_assign(&mut c, &krk);
    symmetrize(&mut c);
    KfElement {
        a: Mat::zeros(n, n),
        b,
        c,
        eta: vec![0.0; n],
        j: Mat::zeros(n, n),
    }
}

/// The steady-state (k ≥ 2) element for observation `y`.
pub fn kf_step_element(protos: &KfProtos, y: &[f64]) -> KfElement {
    KfElement {
        a: protos.phi.clone(),
        b: protos.gain.matvec::<Prob>(y),
        c: protos.ctil.clone(),
        eta: protos.w.matvec::<Prob>(y),
        j: protos.j.clone(),
    }
}

/// Build the full element chain for a flat observation sequence
/// (`obs.len()` must be a multiple of the observation dimension) into
/// `out`, reusing its capacity. Streaming sessions build element-by-
/// element through the same [`kf_prior_element`] / [`kf_step_element`]
/// calls, so the chains are bit-identical.
pub fn kf_element_chain_into(model: &Lgssm, obs: &[f64], out: &mut Vec<KfElement>) {
    let m = model.obs_dim();
    assert_eq!(obs.len() % m, 0, "flat observation length must be T·m");
    out.clear();
    let protos = kf_element_protos(model);
    for (k, y) in obs.chunks_exact(m).enumerate() {
        out.push(if k == 0 {
            kf_prior_element(model, y)
        } else {
            kf_step_element(&protos, y)
        });
    }
}

/// Allocating wrapper over [`kf_element_chain_into`].
pub fn kf_element_chain(model: &Lgssm, obs: &[f64]) -> Vec<KfElement> {
    let mut out = Vec::new();
    kf_element_chain_into(model, obs, &mut out);
    out
}

/// Build the smoothing element chain from the *scanned* forward chain
/// (each `fwd[k]` already the inclusive prefix, i.e. carrying the
/// filtered mean/covariance in `b`/`c`). The last element is
/// `(0, m_T, P_T)`; interior elements follow paper Lemma 9 with
/// `E_k = P_k·Aᵀ·(A·P_k·Aᵀ + Q)⁻¹`.
pub fn ks_element_chain_into(model: &Lgssm, fwd: &[KfElement], out: &mut Vec<KsElement>) {
    let n = model.state_dim();
    let a = model.a();
    out.clear();
    let t = fwd.len();
    for (k, f) in fwd.iter().enumerate() {
        if k + 1 == t {
            out.push(KsElement { e: Mat::zeros(n, n), g: f.b.clone(), l: f.c.clone() });
            break;
        }
        let (pm, ppred) = super::predict_moments(model, &f.b, &f.c);
        let lu = Lu::factor(&ppred);
        // E = P·Aᵀ·Ppred⁻¹ = (Ppred⁻¹·A·P)ᵀ (both factors symmetric).
        let e = lu.solve_mat(&a.matmul::<Prob>(&f.c)).transpose();
        // g = m − E·(A·m) = m − E·pm.
        let epm = e.matvec::<Prob>(&pm);
        let g: Vec<f64> = f.b.iter().zip(&epm).map(|(mi, ei)| mi - ei).collect();
        // L = P − E·Ppred·Eᵀ, symmetrized.
        let mut l = f.c.clone();
        let cor = e.matmul::<Prob>(&ppred).matmul::<Prob>(&e.transpose());
        for (x, y) in l.data_mut().iter_mut().zip(cor.data()) {
            *x -= y;
        }
        symmetrize(&mut l);
        out.push(KsElement { e, g, l });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptestx::Runner;
    use crate::rng::Xoshiro256StarStar;

    fn rand_obs(r: &mut Xoshiro256StarStar, t: usize, m: usize) -> Vec<f64> {
        (0..t * m).map(|_| r.uniform(-5.0, 5.0)).collect()
    }

    fn elems_close(a: &KfElement, b: &KfElement, tol: f64) -> bool {
        let pairs = [
            (a.a.data(), b.a.data()),
            (&a.b[..], &b.b[..]),
            (a.c.data(), b.c.data()),
            (&a.eta[..], &b.eta[..]),
            (a.j.data(), b.j.data()),
        ];
        pairs.iter().all(|(x, y)| {
            x.iter()
                .zip(y.iter())
                .all(|(u, v)| (u - v).abs() <= tol * (1.0 + u.abs().max(v.abs())))
        })
    }

    #[test]
    fn kf_combine_is_associative() {
        let model = Lgssm::constant_velocity(0.1, 1.0, 0.5);
        let op = KfOp { n: model.state_dim() };
        let mut runner = Runner::new("kalman-kf-assoc");
        runner.run(40, |r| {
            let obs = rand_obs(r, 3, model.obs_dim());
            let es = kf_element_chain(&model, &obs);
            let left = op.combine(&op.combine(&es[0], &es[1]), &es[2]);
            let right = op.combine(&es[0], &op.combine(&es[1], &es[2]));
            assert!(elems_close(&left, &right, 1e-9), "associativity violated");
        });
    }

    #[test]
    fn kf_identity_is_neutral() {
        let model = Lgssm::constant_velocity(0.05, 2.0, 0.25);
        let op = KfOp { n: model.state_dim() };
        let mut runner = Runner::new("kalman-kf-identity");
        runner.run(40, |r| {
            let obs = rand_obs(r, 2, model.obs_dim());
            let es = kf_element_chain(&model, &obs);
            for e in &es {
                assert!(elems_close(&op.combine(&op.identity(), e), e, 1e-12));
                assert!(elems_close(&op.combine(e, &op.identity()), e, 1e-12));
            }
        });
    }

    #[test]
    fn ks_identity_is_neutral_and_op_associative() {
        let n = 3;
        let op = KsOp { n };
        let mut runner = Runner::new("kalman-ks-laws");
        runner.run(40, |r| {
            let rand_elem = |r: &mut Xoshiro256StarStar| {
                let e = Mat::from_vec(n, n, (0..n * n).map(|_| r.uniform(-1.0, 1.0)).collect());
                let g: Vec<f64> = (0..n).map(|_| r.uniform(-1.0, 1.0)).collect();
                let mut l = Mat::from_vec(n, n, (0..n * n).map(|_| r.uniform(0.0, 1.0)).collect());
                super::super::symmetrize(&mut l);
                KsElement { e, g, l }
            };
            let (a, b, c) = (rand_elem(r), rand_elem(r), rand_elem(r));
            let left = op.combine(&op.combine(&a, &b), &c);
            let right = op.combine(&a, &op.combine(&b, &c));
            let close = |x: &KsElement, y: &KsElement, tol: f64| {
                x.e.data()
                    .iter()
                    .zip(y.e.data())
                    .chain(x.g.iter().zip(y.g.iter()))
                    .chain(x.l.data().iter().zip(y.l.data()))
                    .all(|(u, v)| (u - v).abs() <= tol * (1.0 + u.abs().max(v.abs())))
            };
            assert!(close(&left, &right, 1e-10));
            assert!(close(&op.combine(&op.identity(), &a), &a, 1e-12));
            assert!(close(&op.combine(&a, &op.identity()), &a, 1e-12));
        });
    }

    #[test]
    fn combine_is_total_on_garbage() {
        // The scan contract: combine must not panic, whatever the input.
        let op = KfOp { n: 2 };
        let junk = KfElement {
            a: Mat::filled(2, 2, f64::NAN),
            b: vec![f64::INFINITY; 2],
            c: Mat::filled(2, 2, -1.0),
            eta: vec![f64::NEG_INFINITY; 2],
            j: Mat::filled(2, 2, f64::INFINITY),
        };
        let _ = op.combine(&junk, &junk);
        let _ = op.combine(&op.identity(), &junk);
        let _ = op.combine(&junk, &op.identity());
    }
}

//! The Kalman-tier engine — one entry point for the four
//! linear-Gaussian algorithms, with workspace reuse and batched runs.
//!
//! [`KalmanEngine`] is the Gaussian sibling of [`crate::engine::Engine`]:
//! it owns the model, the scan schedule, and a reusable
//! [`KalmanWorkspace`] so repeated calls on a serving hot path overwrite
//! the per-call element buffers in place instead of reallocating them.
//! The discrete engine rejects Gaussian algorithms with a typed error
//! and points callers here; this engine does the mirror-image reject for
//! discrete algorithms.

use std::sync::Arc;

use crate::engine::{
    Algorithm, Session, SessionOptions, DEFAULT_SESSION_BLOCK,
};
use crate::error::{Error, Result};
use crate::inference::Posterior;
use crate::jsonx::Json;
use crate::scan::ScanOptions;

use super::filters::{kf_par, kf_seq, ks_par, ks_seq, KalmanWorkspace};
use super::{words_to_obs, Lgssm};

/// The unified entry point for linear-Gaussian inference.
///
/// ```no_run
/// use hmm_scan::engine::Algorithm;
/// use hmm_scan::kalman::{KalmanEngine, Lgssm};
///
/// let mut engine = KalmanEngine::new(Lgssm::constant_velocity(0.1, 1.0, 0.5));
/// let post = engine.run(Algorithm::KsPar, &[1.0, 2.0, 1.1, 2.2]).unwrap();
/// println!("log p(y) = {}", post.log_likelihood());
/// ```
#[derive(Debug, Clone)]
pub struct KalmanEngine {
    model: Arc<Lgssm>,
    scan: ScanOptions,
    ws: KalmanWorkspace,
}

impl KalmanEngine {
    /// An engine over `model` with default scan options.
    pub fn new(model: Lgssm) -> Self {
        Self::from_arc(Arc::new(model))
    }

    /// An engine over an already-shared model (the coordinator keeps one
    /// `Arc<Lgssm>` per registered model across many sessions).
    pub fn from_arc(model: Arc<Lgssm>) -> Self {
        Self { model, scan: ScanOptions::default(), ws: KalmanWorkspace::default() }
    }

    /// Replace the threading/schedule options (builder-style).
    pub fn with_scan_options(mut self, scan: ScanOptions) -> Self {
        self.scan = scan;
        self
    }

    /// The model this engine runs on.
    pub fn model(&self) -> &Lgssm {
        &self.model
    }

    /// The engine's threading/schedule options.
    pub fn scan_options(&self) -> ScanOptions {
        self.scan
    }

    /// Run one Gaussian algorithm on one observation sequence.
    ///
    /// `obs` is row-major `[T, obs_dim]` (length must be a multiple of
    /// the model's observation dimension, every value finite). Discrete
    /// algorithms are rejected with a typed error pointing at
    /// [`crate::engine::Engine`]. `&mut self` because the parallel
    /// methods reuse the engine's scratch workspace; results are
    /// identical to the free functions in [`super::filters`].
    pub fn run(&mut self, alg: Algorithm, obs: &[f64]) -> Result<Posterior> {
        self.check_observations(obs)?;
        run_one(&self.model, alg, obs, self.scan, &mut self.ws)
    }

    /// Run on a wire-encoded observation stream (the u32 word encoding
    /// produced by [`super::obs_to_words`] — what sessions carry over
    /// TCP). Decodes and delegates to [`KalmanEngine::run`].
    pub fn run_words(&mut self, alg: Algorithm, words: &[u32]) -> Result<Posterior> {
        let obs = words_to_obs(words)?;
        self.run(alg, &obs)
    }

    /// Run one algorithm over many sequences, fanned out over
    /// `exec::parallel_for_chunks` with one scratch workspace per worker.
    ///
    /// Mirrors [`crate::engine::Engine::run_batch`]: the thread budget is
    /// split across the batch dimension first, each of the
    /// min(n, threads) workers runs its sequences with ⌊threads / n⌋
    /// scan threads, so the total never oversubscribes the machine.
    /// Results preserve input order with per-sequence errors per slot.
    pub fn run_batch(
        &self,
        alg: Algorithm,
        seqs: &[Vec<f64>],
    ) -> Vec<Result<Posterior>> {
        let n = seqs.len();
        if n == 0 {
            return Vec::new();
        }
        let threads = self.scan.threads.max(1);
        let per_seq_threads = (threads / n).max(1);
        let per_seq_scan = if per_seq_threads == 1 {
            ScanOptions { threads: 1, min_parallel_work: usize::MAX, ..self.scan }
        } else {
            ScanOptions { threads: per_seq_threads, ..self.scan }
        };

        let mut out: Vec<Option<Result<Posterior>>> = Vec::new();
        out.resize_with(n, || None);
        {
            let slots = crate::exec::SharedSliceMut::new(&mut out);
            let model = &self.model;
            crate::exec::parallel_for_chunks(n, threads, |_, lo, hi| {
                let mut ws = KalmanWorkspace::default();
                for i in lo..hi {
                    let r = check_observations_of(model, &seqs[i]).and_then(|()| {
                        run_one(model, alg, &seqs[i], per_seq_scan, &mut ws)
                    });
                    // SAFETY: slot i is written by exactly one chunk
                    // (chunks partition 0..n).
                    unsafe { slots.write(i, Some(r)) };
                }
            });
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(Error::coordinator("batch slot lost"))))
            .collect()
    }

    /// Open a streaming Kalman session
    /// ([`crate::engine::SessionKind::Kalman`]) against
    /// this engine's model and scan options — the Gaussian counterpart
    /// of [`crate::engine::Engine::open_session`]. The session ingests
    /// *word-encoded* observations ([`super::obs_to_words`]) so it rides
    /// the same u32 append channel as the discrete families; its
    /// `finish` is bit-identical to [`KalmanEngine::run`] with
    /// [`Algorithm::KsPar`] under the session's pinned scan options.
    /// `opts.kind` and `opts.track_map` are ignored (the family is
    /// implied; there is no Gaussian MAP track).
    pub fn open_session(&self, opts: SessionOptions) -> Session {
        let block = opts
            .block
            .or(self.scan.block)
            .unwrap_or(DEFAULT_SESSION_BLOCK)
            .max(1);
        Session::open_kalman(Arc::clone(&self.model), self.scan, block)
    }

    /// Restore a Kalman session from a [`Session::snapshot`] — the
    /// Gaussian counterpart of
    /// [`crate::engine::Engine::resume_session`]. Snapshots of discrete
    /// sessions are rejected with a typed error.
    pub fn resume_session(&self, snap: &Json) -> Result<Session> {
        Session::resume_kalman(Arc::clone(&self.model), self.scan, snap)
    }

    fn check_observations(&self, obs: &[f64]) -> Result<()> {
        check_observations_of(&self.model, obs)
    }
}

/// Validate a row-major `[T, obs_dim]` observation slice against `model`.
fn check_observations_of(model: &Lgssm, obs: &[f64]) -> Result<()> {
    let m = model.obs_dim();
    if obs.len() % m != 0 {
        return Err(Error::invalid_request(format!(
            "observation stream length {} is not a multiple of obs_dim {m}",
            obs.len()
        )));
    }
    if let Some(v) = obs.iter().find(|v| !v.is_finite()) {
        return Err(Error::invalid_request(format!(
            "non-finite observation value {v}"
        )));
    }
    Ok(())
}

/// Dispatch one validated request to the algorithm library.
fn run_one(
    model: &Lgssm,
    alg: Algorithm,
    obs: &[f64],
    scan: ScanOptions,
    ws: &mut KalmanWorkspace,
) -> Result<Posterior> {
    match alg {
        Algorithm::KfSeq => Ok(kf_seq(model, obs)),
        Algorithm::KfPar => Ok(kf_par(model, obs, scan, ws)),
        Algorithm::KsSeq => Ok(ks_seq(model, obs)),
        Algorithm::KsPar => Ok(ks_par(model, obs, scan, ws)),
        other => Err(Error::invalid_request(format!(
            "{} runs on discrete HMMs — use engine::Engine, not the \
             Kalman engine",
            other.name()
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kalman::filters::tests_support::tracking_obs;

    fn model() -> Lgssm {
        Lgssm::constant_velocity(0.1, 0.8, 0.5)
    }

    #[test]
    fn engine_matches_free_functions_for_all_four_algorithms() {
        let m = model();
        let obs = tracking_obs(&m, 200, 7);
        let mut engine = KalmanEngine::new(model());
        for alg in [
            Algorithm::KfSeq,
            Algorithm::KfPar,
            Algorithm::KsSeq,
            Algorithm::KsPar,
        ] {
            let got = engine.run(alg, &obs).unwrap();
            let scan = engine.scan_options();
            let mut ws = KalmanWorkspace::default();
            let want = match alg {
                Algorithm::KfSeq => kf_seq(&m, &obs),
                Algorithm::KfPar => kf_par(&m, &obs, scan, &mut ws),
                Algorithm::KsSeq => ks_seq(&m, &obs),
                Algorithm::KsPar => ks_par(&m, &obs, scan, &mut ws),
                _ => unreachable!(),
            };
            assert_eq!(got.gamma_flat(), want.gamma_flat(), "{}", alg.name());
            assert_eq!(
                got.log_likelihood().to_bits(),
                want.log_likelihood().to_bits(),
                "{}",
                alg.name()
            );
        }
    }

    #[test]
    fn engine_rejects_discrete_algorithms_and_bad_streams() {
        let mut engine = KalmanEngine::new(model());
        assert!(engine.run(Algorithm::SpPar, &[1.0, 2.0]).is_err());
        assert!(engine.run(Algorithm::Viterbi, &[]).is_err());
        // Torn row (obs_dim is 2).
        assert!(engine.run(Algorithm::KfSeq, &[1.0]).is_err());
        // Non-finite value.
        assert!(engine.run(Algorithm::KfSeq, &[1.0, f64::NAN]).is_err());
    }

    #[test]
    fn run_words_round_trips_the_wire_codec() {
        let m = model();
        let obs = tracking_obs(&m, 64, 3);
        let words = crate::kalman::obs_to_words(&obs);
        let mut engine = KalmanEngine::new(model());
        let via_words = engine.run_words(Algorithm::KsPar, &words).unwrap();
        let direct = engine.run(Algorithm::KsPar, &obs).unwrap();
        assert_eq!(via_words.gamma_flat(), direct.gamma_flat());
    }

    #[test]
    fn run_batch_matches_single_runs_in_order() {
        let m = model();
        let seqs: Vec<Vec<f64>> = (0..6)
            .map(|i| tracking_obs(&m, 40 + 17 * i, i as u64))
            .collect();
        let engine = KalmanEngine::new(model());
        let batch = engine.run_batch(Algorithm::KfPar, &seqs);
        assert_eq!(batch.len(), seqs.len());
        let mut solo = KalmanEngine::new(model());
        for (i, r) in batch.iter().enumerate() {
            let got = r.as_ref().unwrap();
            let want = solo.run(Algorithm::KfPar, &seqs[i]).unwrap();
            assert_eq!(got.gamma_flat(), want.gamma_flat(), "slot {i}");
        }
        // Per-slot errors: a torn row in one sequence must not poison
        // its neighbours.
        let mut bad = seqs.clone();
        bad[2].pop();
        let mixed = engine.run_batch(Algorithm::KfPar, &bad);
        assert!(mixed[2].is_err());
        assert!(mixed[0].is_ok() && mixed[5].is_ok());
    }
}

//! Sequential references and scan-based parallel cores for the Kalman
//! tier.
//!
//! * [`kf_seq`] — the classical Kalman filter (predict / Joseph-form
//!   update), the reference KF-Par must match.
//! * [`ks_seq`] — the classical Rauch–Tung–Striebel smoother, the
//!   reference KS-Par must match.
//! * [`kf_par`] / [`ks_par`] — element chain + [`crate::scan::run_scan`]
//!   (and [`crate::scan::run_scan_rev`] for the smoothing pass).
//! * [`loglik_from_forward`] — the shared marginal-likelihood post-pass
//!   over scanned forward elements; one-shot parallel runs and
//!   streaming `Session::finish` both call it, which is what makes
//!   their log-likelihoods bit-identical.
//!
//! Posterior packing: state dimension n becomes a [`Posterior`] with
//! `d = n + n²`; row k is `[mean | covariance row-major]`. Filtering
//! algorithms pack filtered moments, smoothing algorithms smoothed
//! moments; `loglik` is the filter marginal likelihood either way.

use super::element::{
    kf_element_chain_into, ks_element_chain_into, KfElement, KfOp, KsElement, KsOp,
};
use super::{add_assign, symmetrize, Lgssm};
use crate::inference::Posterior;
use crate::linalg::{Lu, Mat};
use crate::scan::{run_scan, run_scan_rev, ScanOptions};
use crate::semiring::Prob;

/// Reusable scratch for the parallel Kalman cores (element chains and
/// the smoothing buffer) — the Gaussian sibling of
/// [`crate::inference::Workspace`].
#[derive(Debug, Clone, Default)]
pub struct KalmanWorkspace {
    pub(crate) fwd: Vec<KfElement>,
    pub(crate) bwd: Vec<KsElement>,
}

/// One dynamics step of the moments: `(A·mean, A·cov·Aᵀ + Q)`, the
/// covariance symmetrized.
pub(crate) fn predict_moments(model: &Lgssm, mean: &[f64], cov: &Mat) -> (Vec<f64>, Mat) {
    let a = model.a();
    let pm = a.matvec::<Prob>(mean);
    let mut pc = a.matmul::<Prob>(cov).matmul::<Prob>(&a.transpose());
    add_assign(&mut pc, model.q());
    symmetrize(&mut pc);
    (pm, pc)
}

/// Factor the innovation covariance `S = H·P⁻·Hᵀ + R` (symmetrized).
fn innovation_lu(model: &Lgssm, pred_cov: &Mat) -> Lu {
    let h = model.h();
    let mut s = h.matmul::<Prob>(pred_cov).matmul::<Prob>(&h.transpose());
    add_assign(&mut s, model.r());
    symmetrize(&mut s);
    Lu::factor(&s)
}

/// One observation's contribution to the filter marginal log-likelihood,
/// from the *predicted* moments: `log N(y; H·m⁻, H·P⁻·Hᵀ + R)`.
pub(crate) fn step_loglik(model: &Lgssm, pred_mean: &[f64], pred_cov: &Mat, y: &[f64]) -> f64 {
    let m = model.obs_dim();
    let lu = innovation_lu(model, pred_cov);
    let hm = model.h().matvec::<Prob>(pred_mean);
    let innov: Vec<f64> = y.iter().zip(&hm).map(|(yi, hi)| yi - hi).collect();
    let alpha = lu.solve_vec(&innov);
    let quad: f64 = innov.iter().zip(&alpha).map(|(v, a)| v * a).sum();
    let ln_2pi = (2.0 * std::f64::consts::PI).ln();
    -0.5 * (m as f64 * ln_2pi + lu.ln_abs_det() + quad)
}

/// The filter marginal log-likelihood recomputed from *scanned* forward
/// elements (`fwd[k]` carries the filtered moments in `b`/`c`). The
/// one-shot parallel cores and streaming `Session::finish` share this
/// exact pass, so their log-likelihoods are bit-identical given
/// identical forward chains.
pub fn loglik_from_forward(model: &Lgssm, obs: &[f64], fwd: &[KfElement]) -> f64 {
    let m = model.obs_dim();
    let mut ll = 0.0;
    let mut prev_mean: &[f64] = model.prior_mean();
    let mut prev_cov: &Mat = model.prior_cov();
    for (k, y) in obs.chunks_exact(m).enumerate() {
        let (pm, pc) = predict_moments(model, prev_mean, prev_cov);
        ll += step_loglik(model, &pm, &pc, y);
        prev_mean = &fwd[k].b;
        prev_cov = &fwd[k].c;
    }
    ll
}

/// Joseph-form measurement update of a predicted covariance with gain
/// `K`: `(I−K·H)·P⁻·(I−K·H)ᵀ + K·R·Kᵀ`, symmetrized. Algebraically
/// equal to `(I−K·H)·P⁻` but keeps the result PSD under rounding.
fn joseph_cov(model: &Lgssm, pred_cov: &Mat, k: &Mat) -> Mat {
    let n = model.state_dim();
    let mut ikh = k.matmul::<Prob>(model.h());
    for r in 0..n {
        for c in 0..n {
            ikh[(r, c)] = if r == c { 1.0 - ikh[(r, c)] } else { -ikh[(r, c)] };
        }
    }
    let mut cov = ikh.matmul::<Prob>(pred_cov).matmul::<Prob>(&ikh.transpose());
    let krk = k.matmul::<Prob>(model.r()).matmul::<Prob>(&k.transpose());
    add_assign(&mut cov, &krk);
    symmetrize(&mut cov);
    cov
}

fn pack_row(gamma: &mut Vec<f64>, mean: &[f64], cov: &Mat) {
    gamma.extend_from_slice(mean);
    gamma.extend_from_slice(cov.data());
}

/// Classical sequential Kalman filter (KF-Seq). Returns the filtered
/// moments per step (`d = n + n²`, rows `[mean | cov]`) and the filter
/// marginal log-likelihood.
pub fn kf_seq(model: &Lgssm, obs: &[f64]) -> Posterior {
    let n = model.state_dim();
    let m = model.obs_dim();
    assert_eq!(obs.len() % m, 0, "flat observation length must be T·m");
    let d = n + n * n;
    let t = obs.len() / m;
    let mut gamma = Vec::with_capacity(t * d);
    let mut mean = model.prior_mean().to_vec();
    let mut cov = model.prior_cov().clone();
    let mut ll = 0.0;
    let h = model.h();
    for y in obs.chunks_exact(m) {
        let (pm, pc) = predict_moments(model, &mean, &cov);
        ll += step_loglik(model, &pm, &pc, y);
        let lu = innovation_lu(model, &pc);
        // K = P⁻·Hᵀ·S⁻¹ = (S⁻¹·H·P⁻)ᵀ (both factors symmetric).
        let k = lu.solve_mat(&h.matmul::<Prob>(&pc)).transpose();
        let hm = h.matvec::<Prob>(&pm);
        let innov: Vec<f64> = y.iter().zip(&hm).map(|(yi, hi)| yi - hi).collect();
        mean = k.matvec::<Prob>(&innov);
        for i in 0..n {
            mean[i] += pm[i];
        }
        cov = joseph_cov(model, &pc, &k);
        pack_row(&mut gamma, &mean, &cov);
    }
    Posterior::new(d, gamma, ll)
}

/// Classical Rauch–Tung–Striebel smoother (KS-Seq): one [`kf_seq`]-style
/// forward pass, then the backward gain recursion
/// `G_k = P_k·Aᵀ·(A·P_k·Aᵀ + Q)⁻¹`.
pub fn ks_seq(model: &Lgssm, obs: &[f64]) -> Posterior {
    let n = model.state_dim();
    let m = model.obs_dim();
    assert_eq!(obs.len() % m, 0, "flat observation length must be T·m");
    let d = n + n * n;
    let t = obs.len() / m;
    // Forward pass, keeping every filtered moment.
    let mut means: Vec<Vec<f64>> = Vec::with_capacity(t);
    let mut covs: Vec<Mat> = Vec::with_capacity(t);
    let mut mean = model.prior_mean().to_vec();
    let mut cov = model.prior_cov().clone();
    let mut ll = 0.0;
    let h = model.h();
    let a = model.a();
    for y in obs.chunks_exact(m) {
        let (pm, pc) = predict_moments(model, &mean, &cov);
        ll += step_loglik(model, &pm, &pc, y);
        let lu = innovation_lu(model, &pc);
        let k = lu.solve_mat(&h.matmul::<Prob>(&pc)).transpose();
        let hm = h.matvec::<Prob>(&pm);
        let innov: Vec<f64> = y.iter().zip(&hm).map(|(yi, hi)| yi - hi).collect();
        mean = k.matvec::<Prob>(&innov);
        for i in 0..n {
            mean[i] += pm[i];
        }
        cov = joseph_cov(model, &pc, &k);
        means.push(mean.clone());
        covs.push(cov.clone());
    }
    // Backward pass, filling rows last-to-first.
    let mut gamma = vec![0.0; t * d];
    if t > 0 {
        let write = |gamma: &mut [f64], k: usize, mean: &[f64], cov: &Mat| {
            gamma[k * d..k * d + n].copy_from_slice(mean);
            gamma[k * d + n..(k + 1) * d].copy_from_slice(cov.data());
        };
        let mut sm = means[t - 1].clone();
        let mut sp = covs[t - 1].clone();
        write(&mut gamma, t - 1, &sm, &sp);
        for k in (0..t - 1).rev() {
            let (pm, pc) = predict_moments(model, &means[k], &covs[k]);
            let lu = Lu::factor(&pc);
            // G = P_k·Aᵀ·Ppred⁻¹ = (Ppred⁻¹·A·P_k)ᵀ.
            let g = lu.solve_mat(&a.matmul::<Prob>(&covs[k])).transpose();
            let diff: Vec<f64> = sm.iter().zip(&pm).map(|(s, p)| s - p).collect();
            let gd = g.matvec::<Prob>(&diff);
            sm = means[k].iter().zip(&gd).map(|(mk, v)| mk + v).collect();
            let mut dcov = sp.clone();
            for (x, y) in dcov.data_mut().iter_mut().zip(pc.data()) {
                *x -= y;
            }
            sp = covs[k].clone();
            let corr = g.matmul::<Prob>(&dcov).matmul::<Prob>(&g.transpose());
            add_assign(&mut sp, &corr);
            symmetrize(&mut sp);
            write(&mut gamma, k, &sm, &sp);
        }
    }
    Posterior::new(d, gamma, ll)
}

/// Parallel Kalman filter (KF-Par): element chain + prefix scan.
pub fn kf_par(
    model: &Lgssm,
    obs: &[f64],
    opts: ScanOptions,
    ws: &mut KalmanWorkspace,
) -> Posterior {
    let n = model.state_dim();
    kf_element_chain_into(model, obs, &mut ws.fwd);
    run_scan(&KfOp { n }, &mut ws.fwd, opts);
    let ll = loglik_from_forward(model, obs, &ws.fwd);
    let d = n + n * n;
    let mut gamma = Vec::with_capacity(ws.fwd.len() * d);
    for e in &ws.fwd {
        pack_row(&mut gamma, &e.b, &e.c);
    }
    Posterior::new(d, gamma, ll)
}

/// Parallel Kalman (RTS) smoother (KS-Par): forward prefix scan, then
/// smoothing elements combined by a suffix scan.
pub fn ks_par(
    model: &Lgssm,
    obs: &[f64],
    opts: ScanOptions,
    ws: &mut KalmanWorkspace,
) -> Posterior {
    let n = model.state_dim();
    kf_element_chain_into(model, obs, &mut ws.fwd);
    run_scan(&KfOp { n }, &mut ws.fwd, opts);
    // Split borrows: the smoothing pass reads `fwd` and writes `bwd`.
    let KalmanWorkspace { fwd, bwd } = ws;
    ks_from_forward(model, obs, fwd, opts, bwd)
}

/// The smoothing tail shared by one-shot [`ks_par`] and streaming
/// `Session::finish`: build the smoothing chain from scanned forward
/// elements, suffix-scan it, and pack the posterior with the
/// [`loglik_from_forward`] post-pass. Given bit-identical forward
/// chains, the outputs are bit-identical — that is the session
/// `finish`-equals-one-shot property.
pub fn ks_from_forward(
    model: &Lgssm,
    obs: &[f64],
    fwd: &[KfElement],
    opts: ScanOptions,
    bwd: &mut Vec<KsElement>,
) -> Posterior {
    let n = model.state_dim();
    ks_element_chain_into(model, fwd, bwd);
    run_scan_rev(&KsOp { n }, bwd, opts);
    let ll = loglik_from_forward(model, obs, fwd);
    let d = n + n * n;
    let mut gamma = Vec::with_capacity(bwd.len() * d);
    for e in bwd.iter() {
        pack_row(&mut gamma, &e.g, &e.l);
    }
    Posterior::new(d, gamma, ll)
}

/// Deterministic observation generators shared by the Kalman test
/// modules (filters, engine, sessions).
#[cfg(test)]
pub(crate) mod tests_support {
    use super::Lgssm;
    use crate::rng::Xoshiro256StarStar;

    /// A bounded wandering trajectory plus noise — any finite
    /// observation sequence is valid input for the equivalence
    /// properties, so this only needs to be deterministic per seed.
    pub(crate) fn tracking_obs(model: &Lgssm, t: usize, seed: u64) -> Vec<f64> {
        let mut r = Xoshiro256StarStar::seed_from_u64(seed);
        let m = model.obs_dim();
        let mut obs = Vec::with_capacity(t * m);
        let mut pos = vec![0.0; m];
        for _ in 0..t {
            for p in pos.iter_mut() {
                *p += r.uniform(-0.5, 0.5);
                obs.push(*p + r.uniform(-0.2, 0.2));
            }
        }
        obs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptestx::Runner;
    use crate::rng::Xoshiro256StarStar;

    fn tracking_obs(r: &mut Xoshiro256StarStar, model: &Lgssm, t: usize) -> Vec<f64> {
        super::tests_support::tracking_obs(model, t, r.next_u64())
    }

    // Tolerance rationale (satellite of the bit-exact HMM tests in
    // `inference::tests::par_equals_seq_on_ge_long`): the HMM par/seq
    // pairs are *bit-identical* because their combines are plain
    // semiring matmuls whose operands are identical under any
    // association. The Gaussian combines are not — the parallel
    // association routes different matrices through the G = I + C·J
    // solves than the sequential update order does, so KF-Par/KS-Par
    // agree with KF-Seq/KS-Seq only up to floating-point
    // reassociation. Empirically the relative error is ~1e-10 at
    // T = 4096 for well-conditioned models; 1e-6 leaves margin for
    // FMA/codegen differences across platforms while still catching
    // any real algebra bug (which shows up at O(1)).
    const KALMAN_TOL: f64 = 1e-6;

    fn rel_err(a: f64, b: f64) -> f64 {
        (a - b).abs() / (1.0 + a.abs().max(b.abs()))
    }

    fn max_rel_err(a: &Posterior, b: &Posterior) -> f64 {
        assert_eq!(a.num_states(), b.num_states());
        assert_eq!(a.gamma_flat().len(), b.gamma_flat().len());
        a.gamma_flat()
            .iter()
            .zip(b.gamma_flat())
            .map(|(x, y)| rel_err(*x, *y))
            .fold(rel_err(a.log_likelihood(), b.log_likelihood()), f64::max)
    }

    fn par_opts() -> ScanOptions {
        ScanOptions { threads: 4, min_parallel_work: 8, ..ScanOptions::default() }
    }

    #[test]
    fn kf_par_equals_kf_seq_within_tolerance() {
        let model = Lgssm::constant_velocity(0.1, 1.0, 0.5);
        let mut runner = Runner::new("kalman-kf-equivalence");
        let mut ws = KalmanWorkspace::default();
        for &t in &[1usize, 100, 1000, 4096] {
            runner.run(1, |r| {
                let obs = tracking_obs(r, &model, t);
                let seq = kf_seq(&model, &obs);
                let par = kf_par(&model, &obs, par_opts(), &mut ws);
                let err = max_rel_err(&seq, &par);
                assert!(err < KALMAN_TOL, "T={t}: max rel err {err:e}");
            });
        }
    }

    #[test]
    fn ks_par_equals_ks_seq_within_tolerance() {
        let model = Lgssm::constant_velocity(0.1, 1.0, 0.5);
        let mut runner = Runner::new("kalman-ks-equivalence");
        let mut ws = KalmanWorkspace::default();
        for &t in &[1usize, 100, 1000, 4096] {
            runner.run(1, |r| {
                let obs = tracking_obs(r, &model, t);
                let seq = ks_seq(&model, &obs);
                let par = ks_par(&model, &obs, par_opts(), &mut ws);
                let err = max_rel_err(&seq, &par);
                assert!(err < KALMAN_TOL, "T={t}: max rel err {err:e}");
            });
        }
    }

    #[test]
    fn smoother_agrees_with_filter_at_the_last_step() {
        // The smoothed marginal at T equals the filtered marginal at T —
        // true for both the sequential and the parallel formulations.
        let model = Lgssm::constant_velocity(0.2, 0.5, 1.0);
        let mut runner = Runner::new("kalman-smoother-final-step");
        let mut ws = KalmanWorkspace::default();
        runner.run(10, |r| {
            let t = 1 + (r.next_u64() % 64) as usize;
            let obs = tracking_obs(r, &model, t);
            let filt = kf_seq(&model, &obs);
            let smooth = ks_par(&model, &obs, ScanOptions::serial(), &mut ws);
            for (x, y) in filt.gamma(t - 1).iter().zip(smooth.gamma(t - 1)) {
                assert!(rel_err(*x, *y) < KALMAN_TOL);
            }
        });
    }

    #[test]
    fn serial_and_threaded_scans_agree() {
        // Same engine family, different schedules: chunked-serial vs
        // chunked-threaded vs Blelloch all reassociate, so tolerance
        // comparison again.
        use crate::scan::ScanEngine;
        let model = Lgssm::constant_velocity(0.1, 1.0, 0.5);
        let mut runner = Runner::new("kalman-schedule-agreement");
        let mut ws = KalmanWorkspace::default();
        let mut ws2 = KalmanWorkspace::default();
        runner.run(5, |r| {
            let obs = tracking_obs(r, &model, 257);
            let serial = ks_par(&model, &obs, ScanOptions::serial(), &mut ws);
            let threaded = ks_par(&model, &obs, par_opts(), &mut ws2);
            let blelloch = ks_par(
                &model,
                &obs,
                par_opts().with_engine(ScanEngine::Blelloch),
                &mut ws2,
            );
            assert!(max_rel_err(&serial, &threaded) < KALMAN_TOL);
            assert!(max_rel_err(&serial, &blelloch) < KALMAN_TOL);
        });
    }

    #[test]
    fn empty_sequence_is_a_valid_degenerate_posterior() {
        let model = Lgssm::constant_velocity(0.1, 1.0, 0.5);
        let mut ws = KalmanWorkspace::default();
        let p = kf_par(&model, &[], ScanOptions::serial(), &mut ws);
        assert!(p.is_empty());
        assert_eq!(p.log_likelihood(), 0.0);
    }
}

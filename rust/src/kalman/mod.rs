//! Parallel Kalman filtering & smoothing — the affine-Gaussian inference
//! tier of *Temporal Parallelization of Bayesian Smoothers* (Särkkä &
//! García-Fernández, arXiv:1905.13002), running on the same scan stack
//! as the discrete-HMM algorithms.
//!
//! The recipe is the paper's general one: define associative elements
//! and an operator, then any parallel prefix-sum computes the filter.
//! For a linear-Gaussian state-space model ([`Lgssm`]) the filtering
//! element is the five-tuple `(A, b, C, η, J)` of [`KfElement`] — an
//! affine-Gaussian conditional plus an information-form likelihood
//! correction — and the smoothing element is the `(E, g, L)` triple of
//! [`KsElement`]. Both get [`crate::scan::AssocOp`] impls, so
//! `seq_scan`, the Blelloch tree, the chunked scan, and the streaming
//! [`crate::scan::CheckpointedScan`] all drive them unchanged.
//!
//! Numerical hardening (DESIGN.md §8): every combine symmetrizes its
//! covariance/information outputs, the sequential reference filter uses
//! the Joseph-form covariance update, and all solves go through the
//! guarded [`crate::linalg::Lu`] factorization so a combine is *total* —
//! a scan must never panic mid-tree, even on garbage input.
//!
//! Contents:
//! * [`Lgssm`] — the model (A, Q, H, R, prior), validated like
//!   [`crate::hmm::Hmm`].
//! * [`element`] — elements, operators, per-step prototypes, chain
//!   builders (mirroring `elements::sp_element_chain` & friends).
//! * [`kf_seq`] / [`ks_seq`] — classical Kalman filter and RTS smoother,
//!   the sequential references for equivalence testing.
//! * [`kf_par`] / [`ks_par`] — the scan-based parallel filter/smoother.
//! * [`KalmanEngine`] — `engine::Engine`'s sibling for Gaussian models:
//!   one-shot runs, batches, and streaming [`crate::engine::Session`]s
//!   (`SessionKind::Kalman`).
//! * [`obs_to_words`] / [`words_to_obs`] — the exact f64 ↔ u32-word
//!   codec that lets Gaussian observations ride the existing u32 append
//!   channel (wire, store, router) bit-exactly.

pub mod element;
mod engine;
mod filters;

pub use element::{
    kf_element_chain, kf_element_chain_into, kf_element_protos, kf_prior_element,
    kf_step_element, ks_element_chain_into, KfElement, KfOp, KfProtos, KsElement, KsOp,
};
pub use engine::KalmanEngine;
pub use filters::{
    kf_par, kf_seq, ks_from_forward, ks_par, ks_seq, loglik_from_forward, KalmanWorkspace,
};
pub(crate) use filters::{predict_moments, step_loglik};
#[cfg(test)]
pub(crate) use filters::tests_support;

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// A linear-Gaussian state-space model:
///
/// ```text
///   x_k = A·x_{k-1} + q_k,   q_k ~ N(0, Q)
///   y_k = H·x_k     + r_k,   r_k ~ N(0, R)
///   x_0 ~ N(m0, P0)          (prior; the first observation is y_1,
///                             taken after one dynamics step)
/// ```
///
/// Validation mirrors [`crate::hmm::Hmm::new`]: shapes are checked, all
/// entries must be finite, and the covariance inputs (Q, R, P0) must be
/// symmetric. Positive-definiteness is *not* checked (too expensive to
/// verify exactly); the guarded solves keep inference total either way.
#[derive(Debug, Clone, PartialEq)]
pub struct Lgssm {
    a: Mat,
    q: Mat,
    h: Mat,
    r: Mat,
    m0: Vec<f64>,
    p0: Mat,
}

/// Relative symmetry tolerance for covariance inputs.
const SYM_TOL: f64 = 1e-9;

fn check_symmetric(m: &Mat, what: &str) -> Result<()> {
    let scale = 1.0 + m.max_abs();
    for i in 0..m.rows() {
        for j in i + 1..m.cols() {
            if (m[(i, j)] - m[(j, i)]).abs() > SYM_TOL * scale {
                return Err(Error::invalid_model(format!(
                    "{what} is not symmetric at ({i}, {j})"
                )));
            }
        }
    }
    Ok(())
}

fn check_finite(data: &[f64], what: &str) -> Result<()> {
    if data.iter().any(|v| !v.is_finite()) {
        return Err(Error::invalid_model(format!("{what} has a non-finite entry")));
    }
    Ok(())
}

impl Lgssm {
    /// Build and validate a model. `a`/`q` are n×n, `h` is m×n, `r` is
    /// m×m, `m0` has length n, `p0` is n×n.
    pub fn new(a: Mat, q: Mat, h: Mat, r: Mat, m0: Vec<f64>, p0: Mat) -> Result<Self> {
        let n = a.rows();
        let m = h.rows();
        if n == 0 {
            return Err(Error::invalid_model("state dimension must be positive"));
        }
        if m == 0 {
            return Err(Error::invalid_model("observation dimension must be positive"));
        }
        if a.cols() != n {
            return Err(Error::invalid_model("transition matrix A must be square"));
        }
        if (q.rows(), q.cols()) != (n, n) {
            return Err(Error::invalid_model("process noise Q must be n×n"));
        }
        if h.cols() != n {
            return Err(Error::invalid_model("observation matrix H must be m×n"));
        }
        if (r.rows(), r.cols()) != (m, m) {
            return Err(Error::invalid_model("observation noise R must be m×m"));
        }
        if m0.len() != n {
            return Err(Error::invalid_model("prior mean must have length n"));
        }
        if (p0.rows(), p0.cols()) != (n, n) {
            return Err(Error::invalid_model("prior covariance P0 must be n×n"));
        }
        check_finite(a.data(), "transition matrix A")?;
        check_finite(q.data(), "process noise Q")?;
        check_finite(h.data(), "observation matrix H")?;
        check_finite(r.data(), "observation noise R")?;
        check_finite(&m0, "prior mean m0")?;
        check_finite(p0.data(), "prior covariance P0")?;
        check_symmetric(&q, "process noise Q")?;
        check_symmetric(&r, "observation noise R")?;
        check_symmetric(&p0, "prior covariance P0")?;
        Ok(Self { a, q, h, r, m0, p0 })
    }

    /// State dimension n.
    pub fn state_dim(&self) -> usize {
        self.a.rows()
    }

    /// Observation dimension m.
    pub fn obs_dim(&self) -> usize {
        self.h.rows()
    }

    /// u32 words per time step on the append channel (2 per f64 — see
    /// [`obs_to_words`]).
    pub fn words_per_step(&self) -> usize {
        2 * self.obs_dim()
    }

    /// Transition matrix A (n×n).
    pub fn a(&self) -> &Mat {
        &self.a
    }

    /// Process noise covariance Q (n×n).
    pub fn q(&self) -> &Mat {
        &self.q
    }

    /// Observation matrix H (m×n).
    pub fn h(&self) -> &Mat {
        &self.h
    }

    /// Observation noise covariance R (m×m).
    pub fn r(&self) -> &Mat {
        &self.r
    }

    /// Prior mean m0 (length n).
    pub fn prior_mean(&self) -> &[f64] {
        &self.m0
    }

    /// Prior covariance P0 (n×n).
    pub fn prior_cov(&self) -> &Mat {
        &self.p0
    }

    /// The classic constant-velocity tracking model: 4 states
    /// `[px, py, vx, vy]`, 2 observations `[px, py]`, discretized
    /// white-noise-acceleration process noise with spectral density
    /// `q`, isotropic measurement noise with variance `r`, and a
    /// diffuse-ish prior at the origin.
    pub fn constant_velocity(dt: f64, q: f64, r: f64) -> Self {
        assert!(dt > 0.0 && q > 0.0 && r > 0.0, "dt, q, r must be positive");
        #[rustfmt::skip]
        let a = Mat::from_vec(4, 4, vec![
            1.0, 0.0,  dt, 0.0,
            0.0, 1.0, 0.0,  dt,
            0.0, 0.0, 1.0, 0.0,
            0.0, 0.0, 0.0, 1.0,
        ]);
        let (d3, d2) = (dt * dt * dt / 3.0, dt * dt / 2.0);
        #[rustfmt::skip]
        let qm = Mat::from_vec(4, 4, vec![
            q * d3, 0.0,    q * d2, 0.0,
            0.0,    q * d3, 0.0,    q * d2,
            q * d2, 0.0,    q * dt, 0.0,
            0.0,    q * d2, 0.0,    q * dt,
        ]);
        #[rustfmt::skip]
        let h = Mat::from_vec(2, 4, vec![
            1.0, 0.0, 0.0, 0.0,
            0.0, 1.0, 0.0, 0.0,
        ]);
        let mut rm = Mat::zeros(2, 2);
        rm[(0, 0)] = r;
        rm[(1, 1)] = r;
        let mut p0 = Mat::zeros(4, 4);
        for i in 0..4 {
            p0[(i, i)] = 10.0;
        }
        Self::new(a, qm, h, rm, vec![0.0; 4], p0).expect("constant-velocity model is valid")
    }
}

/// FNV-1a fingerprint of an [`Lgssm`] — the Gaussian sibling of
/// [`crate::store::model_fingerprint`], used by crash recovery to refuse
/// snapshot summaries from a model re-registered under the same name.
/// A leading tag keeps the Gaussian and discrete fingerprint domains
/// disjoint even for coincidentally equal parameter bytes.
pub fn lgssm_fingerprint(model: &Lgssm) -> u64 {
    let mut h = crate::rng::fnv1a_64(crate::rng::FNV1A_OFFSET, b"lgssm");
    let mut eat = |v: f64| {
        h = crate::rng::fnv1a_64(h, &v.to_bits().to_le_bytes());
    };
    eat(model.state_dim() as f64);
    eat(model.obs_dim() as f64);
    for part in [&model.a, &model.q, &model.h, &model.r, &model.p0] {
        for &v in part.data() {
            eat(v);
        }
    }
    for &v in &model.m0 {
        eat(v);
    }
    h
}

/// Encode f64 observations as u32 words for the append channel: each
/// value becomes two words, high 32 bits of `to_bits()` first. The
/// codec is exact for every bit pattern (NaN payloads included), so
/// Gaussian observations ride the existing wire/store/router u32
/// channel without any lossy conversion.
pub fn obs_to_words(obs: &[f64]) -> Vec<u32> {
    let mut out = Vec::with_capacity(obs.len() * 2);
    for &v in obs {
        let bits = v.to_bits();
        out.push((bits >> 32) as u32);
        out.push(bits as u32);
    }
    out
}

/// Decode the word stream of [`obs_to_words`] back to f64s. The word
/// count must be even (a torn half-value cannot be decoded).
pub fn words_to_obs(words: &[u32]) -> Result<Vec<f64>> {
    if words.len() % 2 != 0 {
        return Err(Error::invalid_request(
            "observation word stream has a torn f64 (odd word count)",
        ));
    }
    Ok(words
        .chunks_exact(2)
        .map(|w| f64::from_bits(((w[0] as u64) << 32) | w[1] as u64))
        .collect())
}

/// Symmetrize in place: `m ← (m + mᵀ)/2`. Covariance and information
/// matrices drift off symmetry under floating-point combines; every
/// operator re-projects so the drift cannot compound across a scan.
pub(crate) fn symmetrize(m: &mut Mat) {
    for i in 0..m.rows() {
        for j in i + 1..m.cols() {
            let v = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = v;
            m[(j, i)] = v;
        }
    }
}

/// `a ← a + b` entrywise.
pub(crate) fn add_assign(a: &mut Mat, b: &Mat) {
    debug_assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptestx::Runner;

    fn valid_model() -> Lgssm {
        Lgssm::constant_velocity(0.1, 1.0, 0.5)
    }

    #[test]
    fn constant_velocity_shapes() {
        let m = valid_model();
        assert_eq!(m.state_dim(), 4);
        assert_eq!(m.obs_dim(), 2);
        assert_eq!(m.words_per_step(), 4);
    }

    #[test]
    fn validation_rejects_bad_shapes_and_values() {
        let m = valid_model();
        // Non-square A.
        assert!(Lgssm::new(
            Mat::zeros(4, 3),
            m.q().clone(),
            m.h().clone(),
            m.r().clone(),
            m.prior_mean().to_vec(),
            m.prior_cov().clone(),
        )
        .is_err());
        // Asymmetric Q.
        let mut q = m.q().clone();
        q[(0, 1)] += 1.0;
        assert!(Lgssm::new(
            m.a().clone(),
            q,
            m.h().clone(),
            m.r().clone(),
            m.prior_mean().to_vec(),
            m.prior_cov().clone(),
        )
        .is_err());
        // Non-finite entry.
        let mut a = m.a().clone();
        a[(0, 0)] = f64::NAN;
        assert!(Lgssm::new(
            a,
            m.q().clone(),
            m.h().clone(),
            m.r().clone(),
            m.prior_mean().to_vec(),
            m.prior_cov().clone(),
        )
        .is_err());
        // Wrong prior length.
        assert!(Lgssm::new(
            m.a().clone(),
            m.q().clone(),
            m.h().clone(),
            m.r().clone(),
            vec![0.0; 3],
            m.prior_cov().clone(),
        )
        .is_err());
    }

    #[test]
    fn word_codec_is_bit_exact_for_any_bits() {
        let mut runner = Runner::new("kalman-word-codec");
        runner.run(100, |r| {
            let vals: Vec<f64> = (0..8).map(|_| f64::from_bits(r.next_u64())).collect();
            let words = obs_to_words(&vals);
            assert_eq!(words.len(), vals.len() * 2);
            let back = words_to_obs(&words).unwrap();
            for (a, b) in vals.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
        // Torn stream is rejected, not mis-decoded.
        assert!(words_to_obs(&[1]).is_err());
    }

    #[test]
    fn fingerprint_distinguishes_models() {
        let a = valid_model();
        let b = Lgssm::constant_velocity(0.1, 1.0, 0.50001);
        assert_ne!(lgssm_fingerprint(&a), lgssm_fingerprint(&b));
        assert_eq!(lgssm_fingerprint(&a), lgssm_fingerprint(&a.clone()));
    }
}

//! Exact jsonx serialization for the scan element types.
//!
//! This is the block-summary interchange behind `engine::Session`
//! snapshot/resume (and the future eviction-to-disk path): a session can
//! export its `CheckpointedScan` summaries, drop them, and restore
//! without refolding. The round-trip is *bit-exact* for finite f64
//! values — jsonx prints integers exactly and non-integers via Rust's
//! shortest round-trip `Display` — which the restore contract relies on
//! (restored scans must keep producing bit-identical results). All our
//! element payloads are finite by construction ([`TINY`](super::TINY)
//! floors, [`NEG_INF`](super::NEG_INF) = -1e30 stand-in).

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::jsonx::Json;
use crate::linalg::Mat;

use super::{BsElement, MpElement, SpElement};

/// Matrix → `{"rows": R, "cols": C, "data": [..]}` (row-major).
pub fn mat_to_json(m: &Mat) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("rows".to_string(), Json::Num(m.rows() as f64));
    obj.insert("cols".to_string(), Json::Num(m.cols() as f64));
    obj.insert(
        "data".to_string(),
        Json::Arr(m.data().iter().map(|&v| Json::Num(v)).collect()),
    );
    Json::Obj(obj)
}

/// Inverse of [`mat_to_json`].
pub fn mat_from_json(v: &Json) -> Result<Mat> {
    let rows = v
        .get("rows")
        .as_usize()
        .ok_or_else(|| Error::invalid_request("matrix json: missing 'rows'"))?;
    let cols = v
        .get("cols")
        .as_usize()
        .ok_or_else(|| Error::invalid_request("matrix json: missing 'cols'"))?;
    let data = f64_vec_from_json(v.get("data"), "matrix json: 'data'")?;
    if rows.checked_mul(cols) != Some(data.len()) {
        return Err(Error::invalid_request(format!(
            "matrix json: {} values for {rows}x{cols}",
            data.len()
        )));
    }
    Ok(Mat::from_vec(rows, cols, data))
}

/// Sum-product element → `{"mat": .., "log_scale": ..}`.
pub fn sp_element_to_json(e: &SpElement) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("mat".to_string(), mat_to_json(&e.mat));
    obj.insert("log_scale".to_string(), Json::Num(e.log_scale));
    Json::Obj(obj)
}

/// Inverse of [`sp_element_to_json`].
pub fn sp_element_from_json(v: &Json) -> Result<SpElement> {
    let mat = mat_from_json(v.get("mat"))?;
    let log_scale = v
        .get("log_scale")
        .as_f64()
        .ok_or_else(|| Error::invalid_request("sp element json: 'log_scale'"))?;
    Ok(SpElement { mat, log_scale })
}

/// Max-product element → `{"mat": ..}`.
pub fn mp_element_to_json(e: &MpElement) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("mat".to_string(), mat_to_json(&e.mat));
    Json::Obj(obj)
}

/// Inverse of [`mp_element_to_json`].
pub fn mp_element_from_json(v: &Json) -> Result<MpElement> {
    Ok(MpElement { mat: mat_from_json(v.get("mat"))? })
}

/// Bayesian filtering element → `{"f": .., "g": [..], "log_scale": ..}`.
pub fn bs_element_to_json(e: &BsElement) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("f".to_string(), mat_to_json(&e.f));
    obj.insert(
        "g".to_string(),
        Json::Arr(e.g.iter().map(|&v| Json::Num(v)).collect()),
    );
    obj.insert("log_scale".to_string(), Json::Num(e.log_scale));
    Json::Obj(obj)
}

/// Inverse of [`bs_element_to_json`].
pub fn bs_element_from_json(v: &Json) -> Result<BsElement> {
    let f = mat_from_json(v.get("f"))?;
    let g = f64_vec_from_json(v.get("g"), "bs element json: 'g'")?;
    let log_scale = v
        .get("log_scale")
        .as_f64()
        .ok_or_else(|| Error::invalid_request("bs element json: 'log_scale'"))?;
    Ok(BsElement { f, g, log_scale })
}

/// Reject a deserialized sum-product element whose matrix does not
/// match a D-state model — snapshot restore and the session store both
/// gate on this before the element reaches a scan.
pub fn check_sp_shape(e: &SpElement, d: usize) -> Result<()> {
    if e.mat.rows() != d || e.mat.cols() != d {
        return Err(Error::invalid_request(format!(
            "serialized element: {}x{} matrix for a {d}-state model",
            e.mat.rows(),
            e.mat.cols()
        )));
    }
    Ok(())
}

/// [`check_sp_shape`] for the Bayesian-filtering element family.
pub fn check_bs_shape(e: &BsElement, d: usize) -> Result<()> {
    if e.f.rows() != d || e.f.cols() != d || e.g.len() != d {
        return Err(Error::invalid_request(format!(
            "serialized bs element: {}x{} f / {}-long g for a {d}-state model",
            e.f.rows(),
            e.f.cols(),
            e.g.len()
        )));
    }
    Ok(())
}

fn f64_vec_from_json(v: &Json, what: &str) -> Result<Vec<f64>> {
    v.as_arr()
        .ok_or_else(|| Error::invalid_request(format!("{what} not an array")))?
        .iter()
        .map(|x| {
            x.as_f64()
                .ok_or_else(|| Error::invalid_request(format!("{what}: non-number")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::{
        bs_element_chain, mp_element_chain, sp_element_chain, NEG_INF,
    };
    use crate::hmm::{gilbert_elliott, GeParams};

    #[test]
    fn element_round_trips_are_bit_exact() {
        let h = gilbert_elliott(GeParams::default());
        let ys = vec![0u32, 1, 1, 0, 1, 0];
        for e in sp_element_chain(&h, &ys) {
            let text = sp_element_to_json(&e).to_string_compact();
            let back = sp_element_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, e);
        }
        for e in mp_element_chain(&h, &ys) {
            let text = mp_element_to_json(&e).to_string_compact();
            let back = mp_element_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, e);
        }
        for e in bs_element_chain(&h, &ys) {
            let text = bs_element_to_json(&e).to_string_compact();
            let back = bs_element_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, e);
        }
    }

    #[test]
    fn awkward_f64_values_survive() {
        // Denormal-adjacent scales, NEG_INF sentinels, exact integers —
        // the values the element algebra actually produces.
        let vals = [
            0.1 + 0.2, // classic non-representable decimal
            NEG_INF,
            -123456.789e-7,
            1.0,
            f64::MIN_POSITIVE,
            (0.3f64).ln(),
        ];
        let m = Mat::from_vec(2, 3, vals.to_vec());
        let back =
            mat_from_json(&Json::parse(&mat_to_json(&m).to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.data(), m.data());
        assert_eq!((back.rows(), back.cols()), (2, 3));
    }

    #[test]
    fn malformed_json_is_rejected() {
        assert!(mat_from_json(&Json::Null).is_err());
        assert!(sp_element_from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(mp_element_from_json(&Json::parse("{\"mat\": 3}").unwrap()).is_err());
        let bad = Json::parse(r#"{"rows": 2, "cols": 2, "data": [1, 2, 3]}"#).unwrap();
        assert!(mat_from_json(&bad).is_err());
        // rows × cols overflowing usize is a typed error, not a panic.
        let huge = Json::parse(
            r#"{"rows": 4294967296, "cols": 4294967296, "data": []}"#,
        )
        .unwrap();
        assert!(mat_from_json(&huge).is_err());
    }
}

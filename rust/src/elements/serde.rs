//! Exact jsonx serialization for the scan element types.
//!
//! This is the block-summary interchange behind `engine::Session`
//! snapshot/resume (and the eviction-to-disk path): a session can
//! export its `CheckpointedScan` summaries, drop them, and restore
//! without refolding. The round-trip is *bit-exact*: numeric payloads
//! are written as **hex-f64** strings — 16 lowercase hex characters per
//! value, the big-endian `f64::to_bits` pattern — which both halves
//! (≈ 2× smaller logs) and exactifies the encoding for *every* bit
//! pattern, not just the finite values jsonx's shortest round-trip
//! decimal already preserved. Readers accept both forms: a number array
//! (the legacy decimal encoding of store-format v2 / snapshot v1) and a
//! hex string, so old records stay readable forever.
//!
//! Observation sequences get the same treatment via [`obs_to_json`]: a
//! bit-packed hex payload `{"n": count, "w": bits-per-symbol, "x":
//! "hex"}` (1/2/4/8/16/32 bits per symbol, chosen from the largest
//! symbol), ~4× smaller than the decimal array for binary alphabets.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};
use crate::jsonx::Json;
use crate::linalg::Mat;

use super::{BsElement, MpElement, SpElement};
use crate::kalman::KfElement;

/// Pack f64 values as fixed-width hex: 16 lowercase hex characters per
/// value (the big-endian `to_bits` pattern). Bit-exact for every value,
/// including non-finite ones.
pub fn f64s_to_hex(vals: &[f64]) -> String {
    let mut s = String::with_capacity(vals.len() * 16);
    for v in vals {
        let _ = write!(s, "{:016x}", v.to_bits());
    }
    s
}

/// Inverse of [`f64s_to_hex`]; typed error on any malformed payload.
pub fn f64s_from_hex(s: &str) -> Result<Vec<f64>> {
    let bytes = s.as_bytes();
    if bytes.len() % 16 != 0 {
        return Err(Error::invalid_request(format!(
            "hex f64 payload: length {} is not a multiple of 16",
            bytes.len()
        )));
    }
    bytes
        .chunks(16)
        .map(|chunk| {
            std::str::from_utf8(chunk)
                .ok()
                .and_then(|t| u64::from_str_radix(t, 16).ok())
                .map(f64::from_bits)
                .ok_or_else(|| {
                    Error::invalid_request("hex f64 payload: non-hex characters")
                })
        })
        .collect()
}

/// Bits per symbol the packed observation encoding uses for a maximum
/// symbol value: the smallest of 1/2/4/8/16/32 that fits.
fn obs_bits(max: u32) -> usize {
    let need = (32 - max.leading_zeros()).max(1) as usize;
    need.next_power_of_two()
}

/// Observation sequence → bit-packed hex object `{"n": count, "w":
/// bits-per-symbol, "x": "hex"}`. Symbols are packed big-endian within
/// each hex character (sub-nibble widths) or as fixed-width hex numbers
/// (≥ 4 bits). [`obs_from_json`] is the inverse; it also accepts the
/// legacy plain number array.
pub fn obs_to_json(ys: &[u32]) -> Json {
    let bits = obs_bits(ys.iter().copied().max().unwrap_or(0));
    let mut s = String::with_capacity(ys.len() * bits / 4 + 1);
    if bits >= 4 {
        let width = bits / 4;
        for &y in ys {
            let _ = write!(s, "{y:0width$x}");
        }
    } else {
        let per = 4 / bits;
        for chunk in ys.chunks(per) {
            let mut nib = 0u32;
            for (i, &y) in chunk.iter().enumerate() {
                nib |= y << (4 - bits * (i + 1));
            }
            let _ = write!(s, "{nib:x}");
        }
    }
    let mut obj = BTreeMap::new();
    obj.insert("n".to_string(), Json::Num(ys.len() as f64));
    obj.insert("w".to_string(), Json::Num(bits as f64));
    obj.insert("x".to_string(), Json::Str(s));
    Json::Obj(obj)
}

/// Parse an observation sequence: either the packed hex object written
/// by [`obs_to_json`] or the legacy plain number array. Typed errors on
/// anything malformed — never a panic.
pub fn obs_from_json(v: &Json) -> Result<Vec<u32>> {
    match v {
        Json::Arr(a) => a
            .iter()
            .map(|x| {
                x.as_usize().and_then(|u| u32::try_from(u).ok()).ok_or_else(
                    || Error::invalid_request("observations: bad symbol"),
                )
            })
            .collect(),
        Json::Obj(_) => {
            let n = v.get("n").as_usize().ok_or_else(|| {
                Error::invalid_request("packed observations: missing 'n'")
            })?;
            let bits = v.get("w").as_usize().ok_or_else(|| {
                Error::invalid_request("packed observations: missing 'w'")
            })?;
            if !matches!(bits, 1 | 2 | 4 | 8 | 16 | 32) {
                return Err(Error::invalid_request(format!(
                    "packed observations: unsupported width {bits}"
                )));
            }
            let hex = v.get("x").as_str().ok_or_else(|| {
                Error::invalid_request("packed observations: missing 'x'")
            })?;
            let want_chars = (n * bits).div_ceil(4);
            if hex.len() != want_chars {
                return Err(Error::invalid_request(format!(
                    "packed observations: {} hex chars for {n} symbols at \
                     {bits} bits (expected {want_chars})",
                    hex.len()
                )));
            }
            let mut out = Vec::with_capacity(n);
            if bits >= 4 {
                let width = bits / 4;
                for chunk in hex.as_bytes().chunks(width) {
                    let t = std::str::from_utf8(chunk).ok();
                    let y = t
                        .and_then(|t| u32::from_str_radix(t, 16).ok())
                        .ok_or_else(|| {
                            Error::invalid_request(
                                "packed observations: non-hex characters",
                            )
                        })?;
                    out.push(y);
                }
            } else {
                let per = 4 / bits;
                let mask = (1u32 << bits) - 1;
                'chars: for c in hex.chars() {
                    let nib = c.to_digit(16).ok_or_else(|| {
                        Error::invalid_request(
                            "packed observations: non-hex characters",
                        )
                    })?;
                    for i in 0..per {
                        if out.len() == n {
                            break 'chars;
                        }
                        out.push((nib >> (4 - bits * (i + 1))) & mask);
                    }
                }
            }
            out.truncate(n);
            Ok(out)
        }
        _ => Err(Error::invalid_request(
            "observations: expected an array or a packed hex object",
        )),
    }
}

/// Observation count of a serialized sequence (either encoding) without
/// materializing the symbols — what `StoredSession::len` and the store's
/// checkpoint headers read.
pub fn obs_len_from_json(v: &Json) -> Option<usize> {
    match v {
        Json::Arr(a) => Some(a.len()),
        Json::Obj(_) => v.get("n").as_usize(),
        _ => None,
    }
}

/// Recursively rewrite every packed payload in `v` into the legacy
/// decimal encoding: hex-f64 strings under `data`/`g` keys become number
/// arrays, and packed observation objects become symbol arrays. This is
/// the v2-era compatibility *writer* — tests use it to prove old decimal
/// records stay readable, and the log-size bench uses it as the
/// uncompressed baseline.
pub fn to_decimal_json(v: &Json) -> Json {
    match v {
        Json::Obj(o) => {
            if o.contains_key("n") && o.contains_key("w") && o.contains_key("x") {
                if let Ok(ys) = obs_from_json(v) {
                    return Json::Arr(
                        ys.into_iter().map(|y| Json::Num(y as f64)).collect(),
                    );
                }
            }
            Json::Obj(
                o.iter()
                    .map(|(k, val)| {
                        let new = match (k.as_str(), val) {
                            ("data" | "g", Json::Str(s)) => match f64s_from_hex(s)
                            {
                                Ok(vals) => Json::Arr(
                                    vals.into_iter().map(Json::Num).collect(),
                                ),
                                Err(_) => to_decimal_json(val),
                            },
                            _ => to_decimal_json(val),
                        };
                        (k.clone(), new)
                    })
                    .collect(),
            )
        }
        Json::Arr(a) => Json::Arr(a.iter().map(to_decimal_json).collect()),
        other => other.clone(),
    }
}

/// Matrix → `{"rows": R, "cols": C, "data": "<hex-f64>"}` (row-major
/// packed hex; see [`f64s_to_hex`]). [`mat_from_json`] also accepts the
/// legacy decimal `"data": [..]` array.
pub fn mat_to_json(m: &Mat) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("rows".to_string(), Json::Num(m.rows() as f64));
    obj.insert("cols".to_string(), Json::Num(m.cols() as f64));
    obj.insert("data".to_string(), Json::Str(f64s_to_hex(m.data())));
    Json::Obj(obj)
}

/// Inverse of [`mat_to_json`].
pub fn mat_from_json(v: &Json) -> Result<Mat> {
    let rows = v
        .get("rows")
        .as_usize()
        .ok_or_else(|| Error::invalid_request("matrix json: missing 'rows'"))?;
    let cols = v
        .get("cols")
        .as_usize()
        .ok_or_else(|| Error::invalid_request("matrix json: missing 'cols'"))?;
    let data = f64_vec_from_json(v.get("data"), "matrix json: 'data'")?;
    if rows.checked_mul(cols) != Some(data.len()) {
        return Err(Error::invalid_request(format!(
            "matrix json: {} values for {rows}x{cols}",
            data.len()
        )));
    }
    Ok(Mat::from_vec(rows, cols, data))
}

/// Sum-product element → `{"mat": .., "log_scale": ..}`.
pub fn sp_element_to_json(e: &SpElement) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("mat".to_string(), mat_to_json(&e.mat));
    obj.insert("log_scale".to_string(), Json::Num(e.log_scale));
    Json::Obj(obj)
}

/// Inverse of [`sp_element_to_json`].
pub fn sp_element_from_json(v: &Json) -> Result<SpElement> {
    let mat = mat_from_json(v.get("mat"))?;
    let log_scale = v
        .get("log_scale")
        .as_f64()
        .ok_or_else(|| Error::invalid_request("sp element json: 'log_scale'"))?;
    Ok(SpElement { mat, log_scale })
}

/// Max-product element → `{"mat": ..}`.
pub fn mp_element_to_json(e: &MpElement) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("mat".to_string(), mat_to_json(&e.mat));
    Json::Obj(obj)
}

/// Inverse of [`mp_element_to_json`].
pub fn mp_element_from_json(v: &Json) -> Result<MpElement> {
    Ok(MpElement { mat: mat_from_json(v.get("mat"))? })
}

/// Bayesian filtering element → `{"f": .., "g": "<hex-f64>",
/// "log_scale": ..}` (the reader also accepts a legacy decimal `g`
/// array).
pub fn bs_element_to_json(e: &BsElement) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("f".to_string(), mat_to_json(&e.f));
    obj.insert("g".to_string(), Json::Str(f64s_to_hex(&e.g)));
    obj.insert("log_scale".to_string(), Json::Num(e.log_scale));
    Json::Obj(obj)
}

/// Inverse of [`bs_element_to_json`].
pub fn bs_element_from_json(v: &Json) -> Result<BsElement> {
    let f = mat_from_json(v.get("f"))?;
    let g = f64_vec_from_json(v.get("g"), "bs element json: 'g'")?;
    let log_scale = v
        .get("log_scale")
        .as_f64()
        .ok_or_else(|| Error::invalid_request("bs element json: 'log_scale'"))?;
    Ok(BsElement { f, g, log_scale })
}

/// Kalman filtering element → `{"a": .., "b": "<hex-f64>", "c": ..,
/// "eta": "<hex-f64>", "j": ..}`. The Gaussian payloads carry means,
/// covariances, and information blocks whose entries are routinely
/// negative and can drift non-finite on hostile input — the hex-f64
/// encoding is bit-exact for all of them, and the reader accepts the
/// decimal fallbacks like every other element family.
pub fn kf_element_to_json(e: &KfElement) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("a".to_string(), mat_to_json(&e.a));
    obj.insert("b".to_string(), Json::Str(f64s_to_hex(&e.b)));
    obj.insert("c".to_string(), mat_to_json(&e.c));
    obj.insert("eta".to_string(), Json::Str(f64s_to_hex(&e.eta)));
    obj.insert("j".to_string(), mat_to_json(&e.j));
    Json::Obj(obj)
}

/// Inverse of [`kf_element_to_json`].
pub fn kf_element_from_json(v: &Json) -> Result<KfElement> {
    let a = mat_from_json(v.get("a"))?;
    let b = f64_vec_from_json(v.get("b"), "kf element json: 'b'")?;
    let c = mat_from_json(v.get("c"))?;
    let eta = f64_vec_from_json(v.get("eta"), "kf element json: 'eta'")?;
    let j = mat_from_json(v.get("j"))?;
    Ok(KfElement { a, b, c, eta, j })
}

/// Reject a deserialized sum-product element whose matrix does not
/// match a D-state model — snapshot restore and the session store both
/// gate on this before the element reaches a scan.
pub fn check_sp_shape(e: &SpElement, d: usize) -> Result<()> {
    if e.mat.rows() != d || e.mat.cols() != d {
        return Err(Error::invalid_request(format!(
            "serialized element: {}x{} matrix for a {d}-state model",
            e.mat.rows(),
            e.mat.cols()
        )));
    }
    Ok(())
}

/// [`check_sp_shape`] for the Kalman element family: every block must
/// match an n-state linear-Gaussian model.
pub fn check_kf_shape(e: &KfElement, n: usize) -> Result<()> {
    let square = |m: &Mat| m.rows() == n && m.cols() == n;
    if !square(&e.a)
        || !square(&e.c)
        || !square(&e.j)
        || e.b.len() != n
        || e.eta.len() != n
    {
        return Err(Error::invalid_request(format!(
            "serialized kf element: blocks ({}x{} A, {}-long b, {}x{} C, \
             {}-long eta, {}x{} J) for an n={n} model",
            e.a.rows(),
            e.a.cols(),
            e.b.len(),
            e.c.rows(),
            e.c.cols(),
            e.eta.len(),
            e.j.rows(),
            e.j.cols()
        )));
    }
    Ok(())
}

/// [`check_sp_shape`] for the Bayesian-filtering element family.
pub fn check_bs_shape(e: &BsElement, d: usize) -> Result<()> {
    if e.f.rows() != d || e.f.cols() != d || e.g.len() != d {
        return Err(Error::invalid_request(format!(
            "serialized bs element: {}x{} f / {}-long g for a {d}-state model",
            e.f.rows(),
            e.f.cols(),
            e.g.len()
        )));
    }
    Ok(())
}

/// Parse an f64 vector from either encoding: a hex-f64 string (the
/// packed form every writer emits now) or the legacy decimal array.
fn f64_vec_from_json(v: &Json, what: &str) -> Result<Vec<f64>> {
    match v {
        Json::Str(s) => f64s_from_hex(s)
            .map_err(|_| Error::invalid_request(format!("{what}: bad hex"))),
        Json::Arr(a) => a
            .iter()
            .map(|x| {
                x.as_f64().ok_or_else(|| {
                    Error::invalid_request(format!("{what}: non-number"))
                })
            })
            .collect(),
        _ => Err(Error::invalid_request(format!(
            "{what}: expected a hex string or an array"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::{
        bs_element_chain, mp_element_chain, sp_element_chain, NEG_INF,
    };
    use crate::hmm::{gilbert_elliott, GeParams};

    #[test]
    fn element_round_trips_are_bit_exact() {
        let h = gilbert_elliott(GeParams::default());
        let ys = vec![0u32, 1, 1, 0, 1, 0];
        for e in sp_element_chain(&h, &ys) {
            let text = sp_element_to_json(&e).to_string_compact();
            let back = sp_element_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, e);
        }
        for e in mp_element_chain(&h, &ys) {
            let text = mp_element_to_json(&e).to_string_compact();
            let back = mp_element_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, e);
        }
        for e in bs_element_chain(&h, &ys) {
            let text = bs_element_to_json(&e).to_string_compact();
            let back = bs_element_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, e);
        }
    }

    #[test]
    fn awkward_f64_values_survive() {
        // Denormal-adjacent scales, NEG_INF sentinels, exact integers —
        // the values the element algebra actually produces.
        let vals = [
            0.1 + 0.2, // classic non-representable decimal
            NEG_INF,
            -123456.789e-7,
            1.0,
            f64::MIN_POSITIVE,
            (0.3f64).ln(),
        ];
        let m = Mat::from_vec(2, 3, vals.to_vec());
        let back =
            mat_from_json(&Json::parse(&mat_to_json(&m).to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.data(), m.data());
        assert_eq!((back.rows(), back.cols()), (2, 3));
    }

    #[test]
    fn hex_f64_round_trip_any_bits() {
        let vals = [
            0.0,
            -0.0,
            1.0,
            0.1 + 0.2,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::NEG_INFINITY,
            f64::NAN,
            NEG_INF,
        ];
        let hex = f64s_to_hex(&vals);
        assert_eq!(hex.len(), vals.len() * 16);
        let back = f64s_from_hex(&hex).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit pattern must survive");
        }
        assert!(f64s_from_hex("0123").is_err(), "length not multiple of 16");
        assert!(f64s_from_hex("zzzzzzzzzzzzzzzz").is_err(), "non-hex chars");
    }

    #[test]
    fn obs_packing_round_trips_every_width() {
        // Alphabets forcing 1, 2, 4, 8, 16 and 32 bit symbols.
        for max in [1u32, 3, 11, 200, 40_000, u32::MAX] {
            for n in [0usize, 1, 2, 3, 7, 64, 101] {
                let ys: Vec<u32> = (0..n)
                    .map(|k| {
                        (k as u32).wrapping_mul(2_654_435_761) % max.max(1)
                    })
                    .collect();
                let ys = if n > 0 {
                    // Force the max symbol to appear so the width is hit.
                    let mut ys = ys;
                    ys[0] = max;
                    ys
                } else {
                    ys
                };
                let packed = obs_to_json(&ys);
                assert_eq!(obs_len_from_json(&packed), Some(n));
                let back = obs_from_json(&packed).unwrap();
                assert_eq!(back, ys, "max={max} n={n}");
                // The legacy decimal array still parses to the same.
                let legacy = to_decimal_json(&packed);
                assert!(matches!(legacy, Json::Arr(_)));
                assert_eq!(obs_from_json(&legacy).unwrap(), ys);
            }
        }
        // Binary sequences pack ~4× denser than "0,1," decimal arrays.
        let ys: Vec<u32> = (0..1024).map(|k| k % 2).collect();
        let packed = obs_to_json(&ys).to_string_compact();
        let legacy = to_decimal_json(&obs_to_json(&ys)).to_string_compact();
        assert!(
            packed.len() * 3 < legacy.len(),
            "packed {} !<< legacy {}",
            packed.len(),
            legacy.len()
        );
    }

    #[test]
    fn malformed_packed_obs_are_rejected() {
        for bad in [
            r#"{"n": 4, "w": 3, "x": "ff"}"#,  // unsupported width
            r#"{"n": 4, "w": 1, "x": "ff"}"#,  // wrong hex length
            r#"{"n": 4, "w": 8, "x": "zzzzzzzz"}"#, // non-hex
            r#"{"n": 4, "w": 1}"#,             // missing payload
            r#"{"w": 1, "x": ""}"#,            // missing count
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(obs_from_json(&v).is_err(), "should reject {bad}");
        }
        assert!(obs_from_json(&Json::Num(3.0)).is_err());
        assert!(obs_from_json(&Json::parse("[1, -2]").unwrap()).is_err());
    }

    #[test]
    fn legacy_decimal_elements_still_parse() {
        // A v2-era element record (decimal arrays) reads back bit-exact.
        let h = gilbert_elliott(GeParams::default());
        let ys = vec![0u32, 1, 1, 0, 1];
        for e in sp_element_chain(&h, &ys) {
            let legacy = to_decimal_json(&sp_element_to_json(&e));
            assert!(legacy.get("mat").get("data").as_arr().is_some());
            assert_eq!(sp_element_from_json(&legacy).unwrap(), e);
        }
        for e in bs_element_chain(&h, &ys) {
            let legacy = to_decimal_json(&bs_element_to_json(&e));
            assert!(legacy.get("g").as_arr().is_some());
            assert_eq!(bs_element_from_json(&legacy).unwrap(), e);
        }
        // And the packed form is smaller for full-precision payloads
        // (block summaries after many folds print 17 significant digits
        // in decimal; single-step protos can print shorter).
        let m = Mat::from_vec(
            2,
            2,
            vec![0.1 + 0.2, (0.3f64).ln(), 1.0 / 3.0, 2.0_f64.sqrt()],
        );
        let packed = mat_to_json(&m).to_string_compact();
        let legacy = to_decimal_json(&mat_to_json(&m)).to_string_compact();
        assert!(
            packed.len() < legacy.len(),
            "packed {packed} !< legacy {legacy}"
        );
    }

    #[test]
    fn kf_element_round_trips_hostile_gaussian_payloads() {
        // Audit for the Gaussian payloads: means/information vectors are
        // routinely negative, and covariances can drift negative-definite
        // or non-finite under garbage input — the snapshot encoding must
        // carry all of it bit-exactly (spill → restore must not launder
        // a poisoned session into a healthy-looking one).
        use crate::kalman::{kf_element_chain, Lgssm};
        let model = Lgssm::constant_velocity(0.1, 1.0, 0.5);
        let obs: Vec<f64> = (0..8).map(|k| (k as f64) - 4.0).collect();
        for e in kf_element_chain(&model, &obs) {
            let text = kf_element_to_json(&e).to_string_compact();
            let back = kf_element_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, e);
        }
        let hostile = KfElement {
            a: Mat::from_vec(2, 2, vec![f64::NAN, -0.0, f64::INFINITY, 1e-308]),
            b: vec![f64::NEG_INFINITY, -3.5],
            c: Mat::from_vec(2, 2, vec![-1.0, 0.5, 0.5, -2.0]), // neg-definite
            eta: vec![f64::MIN_POSITIVE, -f64::MAX],
            j: Mat::from_vec(2, 2, vec![0.0, -0.0, f64::NAN, -1e300]),
        };
        let text = kf_element_to_json(&hostile).to_string_compact();
        let back = kf_element_from_json(&Json::parse(&text).unwrap()).unwrap();
        // PartialEq fails on NaN; compare bit patterns instead.
        let bits = |e: &KfElement| -> Vec<u64> {
            e.a.data()
                .iter()
                .chain(&e.b)
                .chain(e.c.data())
                .chain(&e.eta)
                .chain(e.j.data())
                .map(|v| v.to_bits())
                .collect()
        };
        assert_eq!(bits(&back), bits(&hostile));
    }

    #[test]
    fn kf_shape_check_rejects_mismatches() {
        use crate::kalman::{kf_element_chain, Lgssm};
        let model = Lgssm::constant_velocity(0.1, 1.0, 0.5);
        let e = &kf_element_chain(&model, &[1.0, 2.0])[0];
        assert!(check_kf_shape(e, 4).is_ok());
        assert!(check_kf_shape(e, 3).is_err());
        assert!(kf_element_from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn malformed_json_is_rejected() {
        assert!(mat_from_json(&Json::Null).is_err());
        assert!(sp_element_from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(mp_element_from_json(&Json::parse("{\"mat\": 3}").unwrap()).is_err());
        let bad = Json::parse(r#"{"rows": 2, "cols": 2, "data": [1, 2, 3]}"#).unwrap();
        assert!(mat_from_json(&bad).is_err());
        // rows × cols overflowing usize is a typed error, not a panic.
        let huge = Json::parse(
            r#"{"rows": 4294967296, "cols": 4294967296, "data": []}"#,
        )
        .unwrap();
        assert!(mat_from_json(&huge).is_err());
    }
}

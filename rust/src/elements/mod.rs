//! The paper's associative-scan elements and operators.
//!
//! * [`SpElement`] / [`SpOp`] — sum-product element a_{i:j} =
//!   ψ_{i,j}(x_i, x_j) (Definition 3) with the ⊗ combine of Eq. (16),
//!   carried as a max-normalized matrix plus log-scale accumulator
//!   (DESIGN.md §2.2) so T = 10⁵-length products cannot underflow.
//! * [`MpElement`] / [`MpOp`] — max-product element (Definition 5) in
//!   log domain: the ∨ combine of Eq. (42) becomes a max-plus matmul.
//! * [`PathElement`] / [`PathOp`] — the path-based element ã_{i:j} of
//!   Definition 4 (§IV-B), carrying the argmax interior path per state
//!   pair; memory O(D²·len), provided for the paper's memory-vs-time
//!   comparison against the max-product formulation.
//! * [`BsElement`] / [`BsFilterOp`] — the Bayesian-filtering element of
//!   Ref. [30] (discrete analogue): conditional matrix + rescaled
//!   likelihood vector; used by BS-Par.
//! * [`sp_element_chain`] / [`mp_element_chain`] /
//!   [`bs_element_chain`] — build the per-step elements from an [`Hmm`]
//!   and an observation sequence (Definition 3 / Eq. 15). The per-symbol
//!   prototypes ([`sp_element_protos`] / [`mp_element_protos`]) and the
//!   prior elements ([`sp_prior_element`] / [`mp_prior_element`]) are
//!   exposed separately so streaming sessions can append elements one
//!   observation at a time, bit-identical to the one-shot builders.
//! * [`serde`] — exact jsonx round-trip for the element types (the
//!   block-summary serialization behind session snapshot/eviction).

pub mod serde;

use crate::hmm::Hmm;
use crate::linalg::kernels::{batch_matmul_soa, kernels_enabled, SoaBatch};
use crate::linalg::Mat;
use crate::scan::{AssocOp, ElementBuf};
use crate::semiring::{MaxPlus, Prob};

/// Linear-domain floor guarding renormalization against all-zero products.
pub const TINY: f64 = 1e-300;

/// Log-domain stand-in for -∞ that survives repeated addition in f64.
pub const NEG_INF: f64 = -1e30;

// ===========================================================================
// Sum-product element (Definition 3, Eq. 16)
// ===========================================================================

/// a_{i:j} = exp(log_scale) · mat, with mat ≥ 0 max-normalized to 1.
#[derive(Debug, Clone, PartialEq)]
pub struct SpElement {
    /// Max-normalized non-negative potential matrix.
    pub mat: Mat,
    /// Log of the factored-out scale.
    pub log_scale: f64,
}

impl SpElement {
    /// Wrap a raw potential matrix, rescaling it into normal form.
    pub fn from_mat(mut mat: Mat) -> Self {
        let m = mat.max().max(TINY);
        mat.scale(1.0 / m);
        Self { mat, log_scale: m.ln() }
    }

    /// The represented (unscaled) potential matrix — for tests/debugging
    /// only; underflows for long chains by construction.
    pub fn unscaled(&self) -> Mat {
        let mut m = self.mat.clone();
        m.scale(self.log_scale.exp());
        m
    }
}

/// The ⊗ operator of Eq. (16): rescaled matrix product over (+, ×).
#[derive(Debug, Clone, Copy)]
pub struct SpOp {
    /// State-space size D.
    pub d: usize,
}

impl AssocOp<SpElement> for SpOp {
    fn identity(&self) -> SpElement {
        SpElement { mat: Mat::identity::<Prob>(self.d), log_scale: 0.0 }
    }

    fn combine(&self, a: &SpElement, b: &SpElement) -> SpElement {
        let mut mat = a.mat.matmul::<Prob>(&b.mat);
        let m = mat.max().max(TINY);
        mat.scale(1.0 / m);
        SpElement { mat, log_scale: a.log_scale + b.log_scale + m.ln() }
    }

    // Hot-path overrides (§Perf): double-buffered matmul_into — zero
    // allocation per combine instead of one Mat per combine.
    fn fold_step(&self, acc: &mut SpElement, e: &SpElement, scratch: &mut SpElement) {
        crate::linalg::matmul_into::<Prob>(&acc.mat, &e.mat, &mut scratch.mat);
        let m = scratch.mat.max().max(TINY);
        scratch.mat.scale(1.0 / m);
        std::mem::swap(&mut acc.mat, &mut scratch.mat);
        acc.log_scale += e.log_scale + m.ln();
    }

    fn fold(&self, init: SpElement, elems: &[SpElement]) -> SpElement {
        let mut acc = init;
        let mut tmp = Mat::zeros(self.d, self.d);
        for e in elems {
            crate::linalg::matmul_into::<Prob>(&acc.mat, &e.mat, &mut tmp);
            let m = tmp.max().max(TINY);
            tmp.scale(1.0 / m);
            std::mem::swap(&mut acc.mat, &mut tmp);
            acc.log_scale += e.log_scale + m.ln();
        }
        acc
    }

    fn rescan(&self, carry: &SpElement, elems: &mut [SpElement]) {
        let mut acc = carry.clone();
        let mut tmp = Mat::zeros(self.d, self.d);
        for e in elems.iter_mut() {
            crate::linalg::matmul_into::<Prob>(&acc.mat, &e.mat, &mut tmp);
            let m = tmp.max().max(TINY);
            tmp.scale(1.0 / m);
            std::mem::swap(&mut acc.mat, &mut tmp);
            acc.log_scale += e.log_scale + m.ln();
            e.mat.data_mut().copy_from_slice(acc.mat.data());
            e.log_scale = acc.log_scale;
        }
    }

    fn fold_rev(&self, init: SpElement, elems: &[SpElement]) -> SpElement {
        let mut acc = init;
        let mut tmp = Mat::zeros(self.d, self.d);
        for e in elems {
            crate::linalg::matmul_into::<Prob>(&e.mat, &acc.mat, &mut tmp);
            let m = tmp.max().max(TINY);
            tmp.scale(1.0 / m);
            std::mem::swap(&mut acc.mat, &mut tmp);
            acc.log_scale += e.log_scale + m.ln();
        }
        acc
    }

    fn rescan_rev(&self, carry: &SpElement, elems: &mut [SpElement]) {
        let mut acc = carry.clone();
        let mut tmp = Mat::zeros(self.d, self.d);
        for e in elems.iter_mut() {
            crate::linalg::matmul_into::<Prob>(&e.mat, &acc.mat, &mut tmp);
            let m = tmp.max().max(TINY);
            tmp.scale(1.0 / m);
            std::mem::swap(&mut acc.mat, &mut tmp);
            acc.log_scale += e.log_scale + m.ln();
            e.mat.data_mut().copy_from_slice(acc.mat.data());
            e.log_scale = acc.log_scale;
        }
    }

    // Level-batched overrides: pack the whole disjoint pair set of one
    // Blelloch level into the SoA batched kernel — one contiguous pass
    // instead of one matmul per node. Per lane, `batch_matmul_soa` runs
    // the scalar kernel's operation sequence, and the renormalization
    // below is `combine`'s, so both hooks stay bit-identical to the
    // default per-pair loops (asserted in this module's tests).
    fn combine_pairs_up(&self, elems: &mut [SpElement], pairs: &[(usize, usize)]) {
        if pairs.len() < 2 || !kernels_enabled() {
            for &(j, k) in pairs {
                elems[k] = self.combine(&elems[j], &elems[k]);
            }
            return;
        }
        let lanes = pairs.len();
        let mut a = SoaBatch::zeros(self.d, lanes);
        let mut b = SoaBatch::zeros(self.d, lanes);
        for (lane, &(j, k)) in pairs.iter().enumerate() {
            a.set_lane(lane, &elems[j].mat);
            b.set_lane(lane, &elems[k].mat);
        }
        let mut out = SoaBatch::zeros(self.d, lanes);
        batch_matmul_soa::<Prob>(&a, &b, &mut out);
        for (lane, &(j, k)) in pairs.iter().enumerate() {
            out.lane_into(lane, &mut elems[k].mat);
            let m = elems[k].mat.max().max(TINY);
            elems[k].mat.scale(1.0 / m);
            elems[k].log_scale = elems[j].log_scale + elems[k].log_scale + m.ln();
        }
    }

    fn combine_pairs_down(&self, elems: &mut [SpElement], pairs: &[(usize, usize)]) {
        if pairs.len() < 2 || !kernels_enabled() {
            for &(j, k) in pairs {
                let t = elems[j].clone();
                elems[j] = elems[k].clone();
                elems[k] = self.combine(&elems[k], &t);
            }
            return;
        }
        let lanes = pairs.len();
        let mut a = SoaBatch::zeros(self.d, lanes);
        let mut b = SoaBatch::zeros(self.d, lanes);
        for (lane, &(j, k)) in pairs.iter().enumerate() {
            // The down-sweep combine is old-k ⊗ old-j.
            a.set_lane(lane, &elems[k].mat);
            b.set_lane(lane, &elems[j].mat);
        }
        let mut out = SoaBatch::zeros(self.d, lanes);
        batch_matmul_soa::<Prob>(&a, &b, &mut out);
        for (lane, &(j, k)) in pairs.iter().enumerate() {
            // After the swap, elems[j] is old-k (the down-sweep's pass-
            // through) and elems[k] carries old-j's log_scale, so the
            // log-scale sum below is combine(old-k, old-j)'s exactly.
            elems.swap(j, k);
            out.lane_into(lane, &mut elems[k].mat);
            let m = elems[k].mat.max().max(TINY);
            elems[k].mat.scale(1.0 / m);
            elems[k].log_scale = elems[j].log_scale + elems[k].log_scale + m.ln();
        }
    }
}

// ===========================================================================
// Max-product element (Definition 5, Eq. 42) — log domain
// ===========================================================================

/// ā_{i:j} as a log-domain matrix: entry (x_i, x_j) is the log max
/// probability over interior paths.
#[derive(Debug, Clone, PartialEq)]
pub struct MpElement {
    /// Log-domain max-probability matrix.
    pub mat: Mat,
}

/// The ∨ operator of Eq. (42): max-plus matrix product.
#[derive(Debug, Clone, Copy)]
pub struct MpOp {
    /// State-space size D.
    pub d: usize,
}

impl AssocOp<MpElement> for MpOp {
    fn identity(&self) -> MpElement {
        let mut mat = Mat::filled(self.d, self.d, NEG_INF);
        for i in 0..self.d {
            mat[(i, i)] = 0.0;
        }
        MpElement { mat }
    }

    fn combine(&self, a: &MpElement, b: &MpElement) -> MpElement {
        MpElement { mat: a.mat.matmul::<MaxPlus>(&b.mat) }
    }

    // Hot-path overrides (§Perf): see SpOp.
    fn fold_step(&self, acc: &mut MpElement, e: &MpElement, scratch: &mut MpElement) {
        crate::linalg::matmul_into::<MaxPlus>(&acc.mat, &e.mat, &mut scratch.mat);
        std::mem::swap(&mut acc.mat, &mut scratch.mat);
    }

    fn fold(&self, init: MpElement, elems: &[MpElement]) -> MpElement {
        let mut acc = init;
        let mut tmp = Mat::zeros(self.d, self.d);
        for e in elems {
            crate::linalg::matmul_into::<MaxPlus>(&acc.mat, &e.mat, &mut tmp);
            std::mem::swap(&mut acc.mat, &mut tmp);
        }
        acc
    }

    fn rescan(&self, carry: &MpElement, elems: &mut [MpElement]) {
        let mut acc = carry.clone();
        let mut tmp = Mat::zeros(self.d, self.d);
        for e in elems.iter_mut() {
            crate::linalg::matmul_into::<MaxPlus>(&acc.mat, &e.mat, &mut tmp);
            std::mem::swap(&mut acc.mat, &mut tmp);
            e.mat.data_mut().copy_from_slice(acc.mat.data());
        }
    }

    fn fold_rev(&self, init: MpElement, elems: &[MpElement]) -> MpElement {
        let mut acc = init;
        let mut tmp = Mat::zeros(self.d, self.d);
        for e in elems {
            crate::linalg::matmul_into::<MaxPlus>(&e.mat, &acc.mat, &mut tmp);
            std::mem::swap(&mut acc.mat, &mut tmp);
        }
        acc
    }

    fn rescan_rev(&self, carry: &MpElement, elems: &mut [MpElement]) {
        let mut acc = carry.clone();
        let mut tmp = Mat::zeros(self.d, self.d);
        for e in elems.iter_mut() {
            crate::linalg::matmul_into::<MaxPlus>(&e.mat, &acc.mat, &mut tmp);
            std::mem::swap(&mut acc.mat, &mut tmp);
            e.mat.data_mut().copy_from_slice(acc.mat.data());
        }
    }

    // Level-batched overrides — see SpOp; the max-product element has no
    // rescale step, so the lanes come back verbatim.
    fn combine_pairs_up(&self, elems: &mut [MpElement], pairs: &[(usize, usize)]) {
        if pairs.len() < 2 || !kernels_enabled() {
            for &(j, k) in pairs {
                elems[k] = self.combine(&elems[j], &elems[k]);
            }
            return;
        }
        let lanes = pairs.len();
        let mut a = SoaBatch::zeros(self.d, lanes);
        let mut b = SoaBatch::zeros(self.d, lanes);
        for (lane, &(j, k)) in pairs.iter().enumerate() {
            a.set_lane(lane, &elems[j].mat);
            b.set_lane(lane, &elems[k].mat);
        }
        let mut out = SoaBatch::zeros(self.d, lanes);
        batch_matmul_soa::<MaxPlus>(&a, &b, &mut out);
        for (lane, &(_, k)) in pairs.iter().enumerate() {
            out.lane_into(lane, &mut elems[k].mat);
        }
    }

    fn combine_pairs_down(&self, elems: &mut [MpElement], pairs: &[(usize, usize)]) {
        if pairs.len() < 2 || !kernels_enabled() {
            for &(j, k) in pairs {
                let t = elems[j].clone();
                elems[j] = elems[k].clone();
                elems[k] = self.combine(&elems[k], &t);
            }
            return;
        }
        let lanes = pairs.len();
        let mut a = SoaBatch::zeros(self.d, lanes);
        let mut b = SoaBatch::zeros(self.d, lanes);
        for (lane, &(j, k)) in pairs.iter().enumerate() {
            // The down-sweep combine is old-k ⊗ old-j.
            a.set_lane(lane, &elems[k].mat);
            b.set_lane(lane, &elems[j].mat);
        }
        let mut out = SoaBatch::zeros(self.d, lanes);
        batch_matmul_soa::<MaxPlus>(&a, &b, &mut out);
        for (lane, &(j, k)) in pairs.iter().enumerate() {
            elems.swap(j, k);
            out.lane_into(lane, &mut elems[k].mat);
        }
    }
}

// ===========================================================================
// Path-based element (Definition 4, §IV-B)
// ===========================================================================

/// ã_{i:j}: log max probability A_{i:j}(x_i, x_j) *and* the maximizing
/// interior path X̂_{i:j}(x_i, x_j) for every state pair.
///
/// The `paths` matrix stores, for state pair (r, c), the interior states
/// x_{i+1..j-1} of the best path — `paths[r * d + c]` has length
/// `interior_len`. Memory per element is O(D² · len) — the cost the
/// max-product formulation of §IV-C avoids.
#[derive(Debug, Clone, PartialEq)]
pub struct PathElement {
    /// Log-domain max-probability matrix.
    pub mat: Mat,
    /// Best interior path per state pair, row-major.
    pub paths: Vec<Vec<u32>>,
    /// Length of every interior path.
    pub interior_len: usize,
}

impl PathElement {
    /// Leaf element (interior path empty) from a log-domain matrix.
    pub fn leaf(mat: Mat) -> Self {
        let d = mat.rows();
        Self { mat, paths: vec![Vec::new(); d * d], interior_len: 0 }
    }
}

/// The ∨ operator of Eq. (34): combine probabilities like [`MpOp`] and
/// concatenate paths through the maximizing midpoint (Eq. 35).
#[derive(Debug, Clone, Copy)]
pub struct PathOp {
    /// State-space size D.
    pub d: usize,
}

impl AssocOp<PathElement> for PathOp {
    fn identity(&self) -> PathElement {
        let mut mat = Mat::filled(self.d, self.d, NEG_INF);
        for i in 0..self.d {
            mat[(i, i)] = 0.0;
        }
        PathElement { mat, paths: vec![Vec::new(); self.d * self.d], interior_len: 0 }
    }

    fn combine(&self, a: &PathElement, b: &PathElement) -> PathElement {
        let d = self.d;
        let mut mat = Mat::filled(d, d, NEG_INF);
        let mut paths = vec![Vec::new(); d * d];
        // Identity elements have interior_len 0 and diagonal support; the
        // concatenated interior must splice the midpoint only when both
        // sides represent genuine chain segments. We detect the identity
        // by interior_len == 0 *and* an exact identity matrix — cheap and
        // unambiguous for how the scans use it (padding / down-sweep).
        let a_ident = is_log_identity(&a.mat) && a.interior_len == 0;
        let b_ident = is_log_identity(&b.mat) && b.interior_len == 0;
        if a_ident {
            return b.clone();
        }
        if b_ident {
            return a.clone();
        }
        for r in 0..d {
            for c in 0..d {
                // Eq. (35): x̂_j = argmax_j A_{i:j}(r, j) + A_{j:k}(j, c)
                let mut best = NEG_INF * 2.0;
                let mut best_j = 0usize;
                for j in 0..d {
                    let v = a.mat[(r, j)] + b.mat[(j, c)];
                    if v > best {
                        best = v;
                        best_j = j;
                    }
                }
                mat[(r, c)] = best;
                // Eq. (34): X̂ = (X̂_{i:j}(r, ĵ), ĵ, X̂_{j:k}(ĵ, c))
                let mut p =
                    Vec::with_capacity(a.interior_len + 1 + b.interior_len);
                p.extend_from_slice(&a.paths[r * d + best_j]);
                p.push(best_j as u32);
                p.extend_from_slice(&b.paths[best_j * d + c]);
                paths[r * d + c] = p;
            }
        }
        PathElement {
            mat,
            paths,
            interior_len: a.interior_len + 1 + b.interior_len,
        }
    }
}

fn is_log_identity(m: &Mat) -> bool {
    let d = m.rows();
    for r in 0..d {
        for c in 0..d {
            let want = if r == c { 0.0 } else { NEG_INF };
            if m[(r, c)] != want {
                return false;
            }
        }
    }
    true
}

// ===========================================================================
// Bayesian filtering element (Ref. [30], discrete analogue)
// ===========================================================================

/// Filtering element (f, ĝ, γ):
/// f(x_{k-1}, x_k) = p(x_k | y-segment, x_{k-1}) — row-stochastic;
/// ĝ(x_{k-1}) ∝ p(y-segment | x_{k-1}) max-normalized with log scale γ.
#[derive(Debug, Clone, PartialEq)]
pub struct BsElement {
    /// Conditional-filter matrix f, row-stochastic.
    pub f: Mat,
    /// Max-normalized likelihood vector ĝ.
    pub g: Vec<f64>,
    /// Log of ĝ's factored-out scale (γ).
    pub log_scale: f64,
}

/// Combine of filtering elements (the discrete parallel-filter rule).
#[derive(Debug, Clone, Copy)]
pub struct BsFilterOp {
    /// State-space size D.
    pub d: usize,
}

impl AssocOp<BsElement> for BsFilterOp {
    fn identity(&self) -> BsElement {
        BsElement {
            f: Mat::identity::<Prob>(self.d),
            g: vec![1.0; self.d],
            log_scale: 0.0,
        }
    }

    fn combine(&self, a: &BsElement, b: &BsElement) -> BsElement {
        let d = self.d;
        let mut f = Mat::zeros(d, d);
        let mut g = vec![0.0; d];
        for i in 0..d {
            // s_i = Σ_j f1[i,j] ĝ2[j]
            let mut s = 0.0;
            for j in 0..d {
                s += a.f[(i, j)] * b.g[j];
            }
            let s_safe = s.max(TINY);
            for k in 0..d {
                let mut acc = 0.0;
                for j in 0..d {
                    acc += a.f[(i, j)] * b.g[j] * b.f[(j, k)];
                }
                f[(i, k)] = acc / s_safe;
            }
            g[i] = a.g[i] * s;
        }
        let m = g.iter().fold(0.0f64, |m, &v| m.max(v)).max(TINY);
        g.iter_mut().for_each(|v| *v /= m);
        BsElement { f, g, log_scale: a.log_scale + b.log_scale + m.ln() }
    }

    // Allocation-free streaming step (see SpOp::fold_step): identical
    // arithmetic to `combine`, writing into `scratch` and swapping.
    fn fold_step(&self, acc: &mut BsElement, e: &BsElement, scratch: &mut BsElement) {
        let d = self.d;
        for i in 0..d {
            let mut s = 0.0;
            for j in 0..d {
                s += acc.f[(i, j)] * e.g[j];
            }
            let s_safe = s.max(TINY);
            for k in 0..d {
                let mut w = 0.0;
                for j in 0..d {
                    w += acc.f[(i, j)] * e.g[j] * e.f[(j, k)];
                }
                scratch.f[(i, k)] = w / s_safe;
            }
            scratch.g[i] = acc.g[i] * s;
        }
        let m = scratch.g.iter().fold(0.0f64, |m, &v| m.max(v)).max(TINY);
        scratch.g.iter_mut().for_each(|v| *v /= m);
        scratch.log_scale = acc.log_scale + e.log_scale + m.ln();
        std::mem::swap(acc, scratch);
    }
}

// ===========================================================================
// In-place overwrite capability (scan::ElementBuf) — the buffer-reuse
// contract of the workspace copy helpers and the checkpointed suffix
// windows.
// ===========================================================================

impl ElementBuf for SpElement {
    fn shape_key(&self) -> (usize, usize) {
        (self.mat.rows(), self.mat.cols())
    }
    fn overwrite_from(&mut self, src: &Self) {
        self.mat.data_mut().copy_from_slice(src.mat.data());
        self.log_scale = src.log_scale;
    }
}

impl ElementBuf for MpElement {
    fn shape_key(&self) -> (usize, usize) {
        (self.mat.rows(), self.mat.cols())
    }
    fn overwrite_from(&mut self, src: &Self) {
        self.mat.data_mut().copy_from_slice(src.mat.data());
    }
}

impl ElementBuf for BsElement {
    fn shape_key(&self) -> (usize, usize) {
        (self.f.rows(), self.f.cols())
    }
    fn overwrite_from(&mut self, src: &Self) {
        self.f.data_mut().copy_from_slice(src.f.data());
        self.g.copy_from_slice(&src.g);
        self.log_scale = src.log_scale;
    }
}

// ===========================================================================
// Element chain construction (Definition 3 / Eq. 15)
// ===========================================================================

/// Build the sum-product element chain (a_{0:1}, …, a_{T-1:T}).
///
/// elems[0] is the prior element (rows broadcast ψ₁(x₁) = p(x₁)p(y₁|x₁));
/// elems[t] = Π ∘ eₜ for t ≥ 1.
pub fn sp_element_chain(hmm: &Hmm, ys: &[u32]) -> Vec<SpElement> {
    let mut out = Vec::new();
    sp_element_chain_into(hmm, ys, &mut out);
    out
}

/// The per-symbol interior element prototypes: every step t ≥ 1 with
/// symbol y shares the same normalized matrix Π ∘ e_y (§Perf: hoisting
/// them saves a D×D rebuild + emission column allocation per step).
/// Streaming sessions cache this vector once and clone per append.
pub fn sp_element_protos(hmm: &Hmm) -> Vec<SpElement> {
    let d = hmm.num_states();
    let pi = hmm.transition();
    (0..hmm.num_symbols())
        .map(|y| {
            let e = hmm.emission_col(y as u32);
            let mut mat = Mat::zeros(d, d);
            for r in 0..d {
                for c in 0..d {
                    mat[(r, c)] = pi[(r, c)] * e[c];
                }
            }
            SpElement::from_mat(mat)
        })
        .collect()
}

/// The t = 0 element: rows broadcast ψ₁(x₁) = p(x₁)p(y₁|x₁), in normal
/// form — bitwise the first element of [`sp_element_chain`].
pub fn sp_prior_element(hmm: &Hmm, y: u32) -> SpElement {
    let d = hmm.num_states();
    let e = hmm.emission_col(y);
    let mut mat = Mat::zeros(d, d);
    for r in 0..d {
        for c in 0..d {
            mat[(r, c)] = hmm.prior()[c] * e[c];
        }
    }
    SpElement::from_mat(mat)
}

/// [`sp_element_chain`] writing into a reusable buffer: when `out`
/// already holds T same-shape elements (a previous call on the same
/// model family), every D×D matrix is overwritten in place — zero
/// allocation on the serving hot path (the `engine` workspace reuse).
pub fn sp_element_chain_into(hmm: &Hmm, ys: &[u32], out: &mut Vec<SpElement>) {
    let d = hmm.num_states();
    let protos = sp_element_protos(hmm);
    if out.len() != ys.len()
        || out.first().map_or(true, |e| e.mat.rows() != d || e.mat.cols() != d)
    {
        out.clear();
        out.resize(ys.len(), SpElement { mat: Mat::zeros(d, d), log_scale: 0.0 });
    }
    for (t, &y) in ys.iter().enumerate() {
        let dst = &mut out[t];
        if t == 0 {
            let e = hmm.emission_col(y);
            {
                let data = dst.mat.data_mut();
                for r in 0..d {
                    for c in 0..d {
                        data[r * d + c] = hmm.prior()[c] * e[c];
                    }
                }
            }
            // Normal form, exactly as SpElement::from_mat.
            let m = dst.mat.max().max(TINY);
            dst.mat.scale(1.0 / m);
            dst.log_scale = m.ln();
        } else {
            let p = &protos[y as usize];
            dst.mat.data_mut().copy_from_slice(p.mat.data());
            dst.log_scale = p.log_scale;
        }
    }
}

/// The terminal element ψ_{T,T+1} = 1 (all-ones matrix).
pub fn sp_terminal(d: usize) -> SpElement {
    SpElement { mat: Mat::all_one::<Prob>(d, d), log_scale: 0.0 }
}

/// Build the log-domain max-product element chain.
pub fn mp_element_chain(hmm: &Hmm, ys: &[u32]) -> Vec<MpElement> {
    let mut out = Vec::new();
    mp_element_chain_into(hmm, ys, &mut out);
    out
}

/// Per-symbol log-domain interior prototypes (see [`sp_element_protos`]).
pub fn mp_element_protos(hmm: &Hmm) -> Vec<MpElement> {
    let d = hmm.num_states();
    let pi = hmm.transition();
    (0..hmm.num_symbols())
        .map(|y| {
            let e = hmm.emission_col(y as u32);
            let mut mat = Mat::zeros(d, d);
            for r in 0..d {
                for c in 0..d {
                    mat[(r, c)] = safe_ln(pi[(r, c)] * e[c]);
                }
            }
            MpElement { mat }
        })
        .collect()
}

/// The t = 0 log-domain element — bitwise the first element of
/// [`mp_element_chain`].
pub fn mp_prior_element(hmm: &Hmm, y: u32) -> MpElement {
    let d = hmm.num_states();
    let e = hmm.emission_col(y);
    let mut mat = Mat::zeros(d, d);
    for r in 0..d {
        for c in 0..d {
            mat[(r, c)] = safe_ln(hmm.prior()[c] * e[c]);
        }
    }
    MpElement { mat }
}

/// [`mp_element_chain`] writing into a reusable buffer (see
/// [`sp_element_chain_into`] for the reuse contract).
pub fn mp_element_chain_into(hmm: &Hmm, ys: &[u32], out: &mut Vec<MpElement>) {
    let d = hmm.num_states();
    let protos = mp_element_protos(hmm);
    if out.len() != ys.len()
        || out.first().map_or(true, |e| e.mat.rows() != d || e.mat.cols() != d)
    {
        out.clear();
        out.resize(ys.len(), MpElement { mat: Mat::zeros(d, d) });
    }
    for (t, &y) in ys.iter().enumerate() {
        let dst = &mut out[t];
        if t == 0 {
            let e = hmm.emission_col(y);
            let data = dst.mat.data_mut();
            for r in 0..d {
                for c in 0..d {
                    data[r * d + c] = safe_ln(hmm.prior()[c] * e[c]);
                }
            }
        } else {
            dst.mat.data_mut().copy_from_slice(protos[y as usize].mat.data());
        }
    }
}

/// Terminal max-product element: log ψ_{T,T+1} = 0 everywhere.
pub fn mp_terminal(d: usize) -> MpElement {
    MpElement { mat: Mat::zeros(d, d) }
}

/// Build the Bayesian filtering element chain.
pub fn bs_element_chain(hmm: &Hmm, ys: &[u32]) -> Vec<BsElement> {
    let mut out = Vec::new();
    bs_element_chain_into(hmm, ys, &mut out);
    out
}

/// Per-symbol Bayesian-filtering element prototypes for steps t ≥ 1
/// (see [`sp_element_protos`] for the caching rationale) — bitwise the
/// interior elements of [`bs_element_chain`]. Streaming Bayes sessions
/// cache this vector once and clone per append.
pub fn bs_element_protos(hmm: &Hmm) -> Vec<BsElement> {
    let d = hmm.num_states();
    let pi = hmm.transition();
    (0..hmm.num_symbols())
        .map(|y| {
            let e = hmm.emission_col(y as u32);
            let mut f = Mat::zeros(d, d);
            let mut g = vec![0.0; d];
            for i in 0..d {
                let mut s = 0.0;
                for j in 0..d {
                    let w = pi[(i, j)] * e[j];
                    f[(i, j)] = w;
                    s += w;
                }
                let s_safe = s.max(TINY);
                for j in 0..d {
                    f[(i, j)] /= s_safe;
                }
                g[i] = s;
            }
            let m = g.iter().fold(0.0f64, |m, &v| m.max(v)).max(TINY);
            g.iter_mut().for_each(|v| *v /= m);
            BsElement { f, g, log_scale: m.ln() }
        })
        .collect()
}

/// The t = 0 Bayesian filtering element (rows = posterior of x_0,
/// ĝ = p(y_0) constant) — bitwise the first element of
/// [`bs_element_chain`].
pub fn bs_prior_element(hmm: &Hmm, y: u32) -> BsElement {
    let d = hmm.num_states();
    let e = hmm.emission_col(y);
    let mut w: Vec<f64> = (0..d).map(|j| hmm.prior()[j] * e[j]).collect();
    let p_y0: f64 = w.iter().sum();
    let norm = p_y0.max(TINY);
    w.iter_mut().for_each(|v| *v /= norm);
    let mut f = Mat::zeros(d, d);
    for r in 0..d {
        for c in 0..d {
            f[(r, c)] = w[c];
        }
    }
    let mut g = vec![p_y0; d];
    let m = g.iter().fold(0.0f64, |m, &v| m.max(v)).max(TINY);
    g.iter_mut().for_each(|v| *v /= m);
    BsElement { f, g, log_scale: m.ln() }
}

/// [`bs_element_chain`] writing into a reusable buffer (see
/// [`sp_element_chain_into`] for the reuse contract).
pub fn bs_element_chain_into(hmm: &Hmm, ys: &[u32], out: &mut Vec<BsElement>) {
    let d = hmm.num_states();
    if out.len() != ys.len()
        || out.first().map_or(true, |e| {
            e.f.rows() != d || e.f.cols() != d || e.g.len() != d
        })
    {
        out.clear();
        out.resize(
            ys.len(),
            BsElement { f: Mat::zeros(d, d), g: vec![0.0; d], log_scale: 0.0 },
        );
    }
    for (t, &y) in ys.iter().enumerate() {
        let e = hmm.emission_col(y);
        let dst = &mut out[t];
        let f = &mut dst.f;
        let g = &mut dst.g;
        if t == 0 {
            // First element: rows = posterior of x_0; g = p(y_0) constant.
            let mut w: Vec<f64> = (0..d).map(|j| hmm.prior()[j] * e[j]).collect();
            let p_y0: f64 = w.iter().sum();
            let norm = p_y0.max(TINY);
            w.iter_mut().for_each(|v| *v /= norm);
            for r in 0..d {
                for c in 0..d {
                    f[(r, c)] = w[c];
                }
            }
            g.iter_mut().for_each(|v| *v = p_y0);
        } else {
            let pi = hmm.transition();
            for i in 0..d {
                let mut s = 0.0;
                for j in 0..d {
                    let w = pi[(i, j)] * e[j];
                    f[(i, j)] = w;
                    s += w;
                }
                let s_safe = s.max(TINY);
                for j in 0..d {
                    f[(i, j)] /= s_safe;
                }
                g[i] = s;
            }
        }
        let m = g.iter().fold(0.0f64, |m, &v| m.max(v)).max(TINY);
        g.iter_mut().for_each(|v| *v /= m);
        dst.log_scale = m.ln();
    }
}

/// `ln` clamped to the log-domain zero ([`NEG_INF`]) for x ≤ 0.
pub fn safe_ln(x: f64) -> f64 {
    if x > 0.0 {
        x.ln()
    } else {
        NEG_INF
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::{gilbert_elliott, GeParams};
    use crate::proptestx::{gen, Runner};
    use crate::rng::Xoshiro256StarStar;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()))
    }

    fn rand_sp(r: &mut Xoshiro256StarStar, d: usize) -> SpElement {
        let mat = Mat::from_vec(
            d,
            d,
            (0..d * d).map(|_| r.uniform(0.01, 1.0)).collect(),
        );
        let mut e = SpElement::from_mat(mat);
        e.log_scale = r.uniform(-5.0, 5.0);
        e
    }

    fn rand_mp(r: &mut Xoshiro256StarStar, d: usize) -> MpElement {
        MpElement {
            mat: Mat::from_vec(
                d,
                d,
                (0..d * d).map(|_| r.uniform(-8.0, 0.0)).collect(),
            ),
        }
    }

    #[test]
    fn sp_combine_associative_exact_in_represented_space() {
        let mut runner = Runner::new("sp-assoc");
        runner.run(100, |r| {
            let d = 2 + r.below(5) as usize;
            let op = SpOp { d };
            let (a, b, c) = (rand_sp(r, d), rand_sp(r, d), rand_sp(r, d));
            let l = op.combine(&op.combine(&a, &b), &c);
            let rr = op.combine(&a, &op.combine(&b, &c));
            // matrices equal up to normalization, total scale equal
            for (x, y) in l.mat.data().iter().zip(rr.mat.data()) {
                assert!(close(*x, *y));
            }
            assert!(close(l.log_scale, rr.log_scale));
        });
    }

    #[test]
    fn sp_identity_neutral() {
        let mut runner = Runner::new("sp-ident");
        runner.run(50, |r| {
            let d = 2 + r.below(4) as usize;
            let op = SpOp { d };
            let a = rand_sp(r, d);
            for v in [op.combine(&a, &op.identity()), op.combine(&op.identity(), &a)] {
                for (x, y) in v.mat.data().iter().zip(a.mat.data()) {
                    assert!(close(*x, *y));
                }
                assert!(close(v.log_scale, a.log_scale));
            }
        });
    }

    #[test]
    fn sp_no_underflow_over_long_chain() {
        let d = 4;
        let op = SpOp { d };
        let mut e = SpElement::from_mat(Mat::filled(d, d, 1e-8));
        let unit = e.clone();
        for _ in 0..10_000 {
            e = op.combine(&e, &unit);
        }
        assert!(e.mat.data().iter().all(|v| v.is_finite()));
        assert!(e.log_scale.is_finite());
        assert!(e.log_scale < -100_000.0); // ~10⁴ · ln(1e-8·4…) ≪ 0
        assert!(close(e.mat.max(), 1.0));
    }

    #[test]
    fn mp_combine_associative() {
        let mut runner = Runner::new("mp-assoc");
        runner.run(100, |r| {
            let d = 2 + r.below(5) as usize;
            let op = MpOp { d };
            let (a, b, c) = (rand_mp(r, d), rand_mp(r, d), rand_mp(r, d));
            let l = op.combine(&op.combine(&a, &b), &c);
            let rr = op.combine(&a, &op.combine(&b, &c));
            for (x, y) in l.mat.data().iter().zip(rr.mat.data()) {
                assert!(close(*x, *y));
            }
        });
    }

    #[test]
    fn mp_identity_neutral() {
        let d = 3;
        let op = MpOp { d };
        let mut r = Xoshiro256StarStar::seed_from_u64(4);
        let a = rand_mp(&mut r, d);
        assert_eq!(op.combine(&a, &op.identity()).mat, a.mat);
        assert_eq!(op.combine(&op.identity(), &a).mat, a.mat);
    }

    #[test]
    fn path_op_tracks_the_argmax_path() {
        // Combine three leaves and check the assembled path achieves the
        // claimed probability (Theorem 3 consistency).
        let mut runner = Runner::new("path-consistency");
        runner.run(50, |r| {
            let d = 2 + r.below(3) as usize;
            let op = PathOp { d };
            let leaves: Vec<PathElement> = (0..4)
                .map(|_| PathElement::leaf(rand_mp(r, d).mat))
                .collect();
            let combined = op.combine(
                &op.combine(&leaves[0], &leaves[1]),
                &op.combine(&leaves[2], &leaves[3]),
            );
            assert_eq!(combined.interior_len, 3);
            for s in 0..d {
                for e in 0..d {
                    let p = &combined.paths[s * d + e];
                    assert_eq!(p.len(), 3);
                    // score of the stored path
                    let states: Vec<usize> = std::iter::once(s)
                        .chain(p.iter().map(|&v| v as usize))
                        .chain(std::iter::once(e))
                        .collect();
                    let mut score = 0.0;
                    for (w, leaf) in states.windows(2).zip(&leaves) {
                        score += leaf.mat[(w[0], w[1])];
                    }
                    assert!(
                        close(score, combined.mat[(s, e)]),
                        "path score mismatch at ({s},{e})"
                    );
                }
            }
        });
    }

    #[test]
    fn path_op_associative_on_values() {
        let mut runner = Runner::new("path-assoc");
        runner.run(30, |r| {
            let d = 2 + r.below(3) as usize;
            let op = PathOp { d };
            let a = PathElement::leaf(rand_mp(r, d).mat);
            let b = PathElement::leaf(rand_mp(r, d).mat);
            let c = PathElement::leaf(rand_mp(r, d).mat);
            let l = op.combine(&op.combine(&a, &b), &c);
            let rr = op.combine(&a, &op.combine(&b, &c));
            for (x, y) in l.mat.data().iter().zip(rr.mat.data()) {
                assert!(close(*x, *y));
            }
            assert_eq!(l.interior_len, rr.interior_len);
        });
    }

    #[test]
    fn bs_filter_associative() {
        let mut runner = Runner::new("bs-assoc");
        runner.run(100, |r| {
            let d = 2 + r.below(4) as usize;
            let op = BsFilterOp { d };
            let mk = |r: &mut Xoshiro256StarStar| BsElement {
                f: Mat::from_vec(d, d, gen::stochastic_matrix(r, d)),
                g: gen::prob_vector(r, d),
                log_scale: r.uniform(-2.0, 2.0),
            };
            let (a, b, c) = (mk(r), mk(r), mk(r));
            let l = op.combine(&op.combine(&a, &b), &c);
            let rr = op.combine(&a, &op.combine(&b, &c));
            for (x, y) in l.f.data().iter().zip(rr.f.data()) {
                assert!(close(*x, *y), "f mismatch");
            }
            // g vectors equal up to the shared normalization; compare the
            // represented (rescaled) likelihoods instead.
            for i in 0..d {
                let lg = l.log_scale + l.g[i].max(TINY).ln();
                let rg = rr.log_scale + rr.g[i].max(TINY).ln();
                assert!((lg - rg).abs() < 1e-9, "g mismatch");
            }
        });
    }

    #[test]
    fn chain_into_reuse_is_identical() {
        // The reusable-buffer builders must be indistinguishable from the
        // allocating ones across grow / shrink / same-shape-overwrite.
        let h = gilbert_elliott(GeParams::default());
        let ys1 = vec![0u32, 1, 1, 0, 1, 0, 0];
        let ys2 = vec![1u32, 0, 1];
        let ys3 = vec![1u32, 1, 0, 1, 0, 0, 1]; // same length as ys1

        let mut sp_buf = Vec::new();
        sp_element_chain_into(&h, &ys1, &mut sp_buf);
        assert_eq!(sp_buf, sp_element_chain(&h, &ys1));
        sp_element_chain_into(&h, &ys3, &mut sp_buf); // in-place overwrite
        assert_eq!(sp_buf, sp_element_chain(&h, &ys3));
        sp_element_chain_into(&h, &ys2, &mut sp_buf); // shrink
        assert_eq!(sp_buf, sp_element_chain(&h, &ys2));
        sp_element_chain_into(&h, &ys1, &mut sp_buf); // grow
        assert_eq!(sp_buf, sp_element_chain(&h, &ys1));

        let mut mp_buf = Vec::new();
        mp_element_chain_into(&h, &ys1, &mut mp_buf);
        mp_element_chain_into(&h, &ys3, &mut mp_buf);
        assert_eq!(mp_buf, mp_element_chain(&h, &ys3));

        let mut bs_buf = Vec::new();
        bs_element_chain_into(&h, &ys1, &mut bs_buf);
        bs_element_chain_into(&h, &ys3, &mut bs_buf);
        assert_eq!(bs_buf, bs_element_chain(&h, &ys3));
        bs_element_chain_into(&h, &ys2, &mut bs_buf);
        assert_eq!(bs_buf, bs_element_chain(&h, &ys2));
    }

    #[test]
    fn streaming_element_builders_match_chain() {
        // Sessions append prior-element + proto clones; the result must
        // be bitwise the one-shot chain.
        let h = gilbert_elliott(GeParams::default());
        let ys = vec![1u32, 0, 1, 1, 0];
        let sp = sp_element_chain(&h, &ys);
        let protos = sp_element_protos(&h);
        assert_eq!(sp[0], sp_prior_element(&h, ys[0]));
        for (t, &y) in ys.iter().enumerate().skip(1) {
            assert_eq!(sp[t], protos[y as usize], "sp t={t}");
        }
        let mp = mp_element_chain(&h, &ys);
        let mprotos = mp_element_protos(&h);
        assert_eq!(mp[0], mp_prior_element(&h, ys[0]));
        for (t, &y) in ys.iter().enumerate().skip(1) {
            assert_eq!(mp[t], mprotos[y as usize], "mp t={t}");
        }
        let bs = bs_element_chain(&h, &ys);
        let bprotos = bs_element_protos(&h);
        assert_eq!(bs[0], bs_prior_element(&h, ys[0]));
        for (t, &y) in ys.iter().enumerate().skip(1) {
            assert_eq!(bs[t], bprotos[y as usize], "bs t={t}");
        }
    }

    #[test]
    fn fold_step_matches_fold_bitwise() {
        // The scratch-carrying step must be bitwise one step of `fold`
        // for every element family (the checkpoint push contract).
        use crate::scan::AssocOp;
        let mut runner = Runner::new("fold-step");
        runner.run(30, |r| {
            let d = 2 + r.below(4) as usize;

            let sp_op = SpOp { d };
            let (a, b) = (rand_sp(r, d), rand_sp(r, d));
            let want = sp_op.fold(a.clone(), std::slice::from_ref(&b));
            let mut acc = a;
            let mut scratch = sp_op.identity();
            sp_op.fold_step(&mut acc, &b, &mut scratch);
            assert_eq!(acc, want, "sp fold_step");

            let mp_op = MpOp { d };
            let (a, b) = (rand_mp(r, d), rand_mp(r, d));
            let want = mp_op.fold(a.clone(), std::slice::from_ref(&b));
            let mut acc = a;
            let mut scratch = mp_op.identity();
            mp_op.fold_step(&mut acc, &b, &mut scratch);
            assert_eq!(acc, want, "mp fold_step");

            let bs_op = BsFilterOp { d };
            let mk = |r: &mut Xoshiro256StarStar| BsElement {
                f: Mat::from_vec(d, d, gen::stochastic_matrix(r, d)),
                g: gen::prob_vector(r, d),
                log_scale: r.uniform(-2.0, 2.0),
            };
            let (a, b) = (mk(r), mk(r));
            let want = bs_op.fold(a.clone(), std::slice::from_ref(&b));
            let mut acc = a;
            let mut scratch = bs_op.identity();
            bs_op.fold_step(&mut acc, &b, &mut scratch);
            assert_eq!(acc, want, "bs fold_step");
        });
    }

    #[test]
    fn pair_hooks_match_default_loops_bitwise() {
        // The batched SoA pair hooks must be indistinguishable — bit for
        // bit — from the per-pair default loops, for both sweeps, both
        // element families, specialized and generic D.
        use crate::linalg::kernels::{set_kernels_enabled, toggle_guard};
        use crate::proptestx::assert_bits_eq;
        let _guard = toggle_guard();
        let mut runner = Runner::new("pair-hooks");
        runner.run(15, |r| {
            for d in [2usize, 3, 4, 8] {
                let n = 16;
                let pairs: Vec<(usize, usize)> =
                    (0..n / 2).map(|i| (2 * i, 2 * i + 1)).collect();

                let sp_op = SpOp { d };
                let elems: Vec<SpElement> = (0..n).map(|_| rand_sp(r, d)).collect();
                set_kernels_enabled(true);
                let mut up = elems.clone();
                sp_op.combine_pairs_up(&mut up, &pairs);
                let mut down = elems.clone();
                sp_op.combine_pairs_down(&mut down, &pairs);
                set_kernels_enabled(false);
                let mut want_up = elems.clone();
                for &(j, k) in &pairs {
                    want_up[k] = sp_op.combine(&want_up[j], &want_up[k]);
                }
                let mut want_down = elems.clone();
                for &(j, k) in &pairs {
                    let t = want_down[j].clone();
                    want_down[j] = want_down[k].clone();
                    want_down[k] = sp_op.combine(&want_down[k], &t);
                }
                for (g, w) in up.iter().zip(&want_up) {
                    assert_bits_eq("sp up", g.mat.data(), w.mat.data());
                    assert_eq!(g.log_scale.to_bits(), w.log_scale.to_bits());
                }
                for (g, w) in down.iter().zip(&want_down) {
                    assert_bits_eq("sp down", g.mat.data(), w.mat.data());
                    assert_eq!(g.log_scale.to_bits(), w.log_scale.to_bits());
                }

                let mp_op = MpOp { d };
                let melems: Vec<MpElement> = (0..n).map(|_| rand_mp(r, d)).collect();
                set_kernels_enabled(true);
                let mut mup = melems.clone();
                mp_op.combine_pairs_up(&mut mup, &pairs);
                let mut mdown = melems.clone();
                mp_op.combine_pairs_down(&mut mdown, &pairs);
                set_kernels_enabled(false);
                let mut mwant_up = melems.clone();
                for &(j, k) in &pairs {
                    mwant_up[k] = mp_op.combine(&mwant_up[j], &mwant_up[k]);
                }
                let mut mwant_down = melems;
                for &(j, k) in &pairs {
                    let t = mwant_down[j].clone();
                    mwant_down[j] = mwant_down[k].clone();
                    mwant_down[k] = mp_op.combine(&mwant_down[k], &t);
                }
                for (g, w) in mup.iter().zip(&mwant_up) {
                    assert_bits_eq("mp up", g.mat.data(), w.mat.data());
                }
                for (g, w) in mdown.iter().zip(&mwant_down) {
                    assert_bits_eq("mp down", g.mat.data(), w.mat.data());
                }
            }
        });
        set_kernels_enabled(true);
    }

    #[test]
    fn chains_have_expected_shapes() {
        let h = gilbert_elliott(GeParams::default());
        let ys = vec![0, 1, 1, 0, 1];
        let sp = sp_element_chain(&h, &ys);
        assert_eq!(sp.len(), 5);
        // prior element has identical rows
        for c in 0..4 {
            let v = sp[0].mat[(0, c)];
            assert!((1..4).all(|r| sp[0].mat[(r, c)] == v));
        }
        let mp = mp_element_chain(&h, &ys);
        assert_eq!(mp.len(), 5);
        assert!(mp[1].mat.data().iter().all(|&v| v <= 0.0));
        let bs = bs_element_chain(&h, &ys);
        assert_eq!(bs.len(), 5);
        for e in &bs {
            for r in 0..4 {
                let s: f64 = e.f.row(r).iter().sum();
                assert!((s - 1.0).abs() < 1e-9, "f rows stochastic");
            }
        }
    }
}

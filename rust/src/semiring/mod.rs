//! Semiring abstractions underlying the paper's associative operators.
//!
//! The sum-product combine (Eq. 16) is a matrix product over the
//! **probability semiring** (+, ×); the max-product combine (Eq. 42) is a
//! matrix product over the **max-times** semiring — or, in log domain,
//! **max-plus** (tropical). Expressing both as semiring matmuls lets the
//! scan, the linear algebra, and the complexity model (simulator) share
//! one implementation.

/// A commutative-monoid-plus-monoid structure on `f64`.
///
/// Laws (checked by property tests in this module and exercised across
/// `linalg`/`scan`):
///   * `add` is associative & commutative with identity `zero()`
///   * `mul` is associative with identity `one()`
///   * `mul` distributes over `add`
///   * `zero()` annihilates: `mul(zero(), x) = zero()`
pub trait Semiring: Copy + Send + Sync + 'static {
    /// Short stable name (used in test seeds, bench rows, docs).
    const NAME: &'static str;
    /// Additive identity (annihilates under `mul`).
    fn zero() -> f64;
    /// Multiplicative identity.
    fn one() -> f64;
    /// Semiring addition.
    fn add(a: f64, b: f64) -> f64;
    /// Semiring multiplication.
    fn mul(a: f64, b: f64) -> f64;

    /// Shape-specialized square-matmul hook — the stable-Rust dispatch
    /// seam for the kernel tier (no `specialization` feature needed).
    ///
    /// `a`, `b`, `out` are row-major d×d buffers. Return `true` after
    /// writing `out = a ⋆ b`, or `false` (leaving `out` untouched) to
    /// make [`linalg::matmul_into`](crate::linalg::matmul_into) fall
    /// back to the generic kernel. Implementations must be bit-identical
    /// to [`linalg::matmul_into_generic`](crate::linalg::matmul_into_generic)
    /// — the kernel differential harness enforces this for the two
    /// overriding semirings ([`Prob`], [`MaxPlus`]); every other
    /// semiring keeps this default and always takes the generic path.
    #[inline]
    fn specialized_matmul(d: usize, a: &[f64], b: &[f64], out: &mut [f64]) -> bool {
        let _ = (d, a, b, out);
        false
    }
}

/// Ordinary probability semiring (ℝ₊, +, ×).
#[derive(Debug, Clone, Copy, Default)]
pub struct Prob;

impl Semiring for Prob {
    const NAME: &'static str = "prob";
    #[inline]
    fn zero() -> f64 {
        0.0
    }
    #[inline]
    fn one() -> f64 {
        1.0
    }
    #[inline]
    fn add(a: f64, b: f64) -> f64 {
        a + b
    }
    #[inline]
    fn mul(a: f64, b: f64) -> f64 {
        a * b
    }
    #[inline]
    fn specialized_matmul(d: usize, a: &[f64], b: &[f64], out: &mut [f64]) -> bool {
        crate::linalg::kernels::dispatch::<Prob>(d, a, b, out)
    }
}

/// Log-domain probability semiring (log-sum-exp, +). Numerically stable
/// replacement for [`Prob`] at extreme dynamic range.
#[derive(Debug, Clone, Copy, Default)]
pub struct LogProb;

impl Semiring for LogProb {
    const NAME: &'static str = "logprob";
    #[inline]
    fn zero() -> f64 {
        f64::NEG_INFINITY
    }
    #[inline]
    fn one() -> f64 {
        0.0
    }
    #[inline]
    fn add(a: f64, b: f64) -> f64 {
        log_sum_exp(a, b)
    }
    #[inline]
    fn mul(a: f64, b: f64) -> f64 {
        a + b
    }
}

/// Tropical max-plus semiring (max, +) — the log-domain Viterbi algebra.
#[derive(Debug, Clone, Copy, Default)]
pub struct MaxPlus;

impl Semiring for MaxPlus {
    const NAME: &'static str = "maxplus";
    #[inline]
    fn zero() -> f64 {
        f64::NEG_INFINITY
    }
    #[inline]
    fn one() -> f64 {
        0.0
    }
    #[inline]
    fn add(a: f64, b: f64) -> f64 {
        a.max(b)
    }
    #[inline]
    fn mul(a: f64, b: f64) -> f64 {
        a + b
    }
    #[inline]
    fn specialized_matmul(d: usize, a: &[f64], b: &[f64], out: &mut [f64]) -> bool {
        crate::linalg::kernels::dispatch::<MaxPlus>(d, a, b, out)
    }
}

/// Max-times semiring (max, ×) on ℝ₊ — the linear-domain Viterbi algebra
/// (paper Eq. 42 as written).
#[derive(Debug, Clone, Copy, Default)]
pub struct MaxTimes;

impl Semiring for MaxTimes {
    const NAME: &'static str = "maxtimes";
    #[inline]
    fn zero() -> f64 {
        0.0
    }
    #[inline]
    fn one() -> f64 {
        1.0
    }
    #[inline]
    fn add(a: f64, b: f64) -> f64 {
        a.max(b)
    }
    #[inline]
    fn mul(a: f64, b: f64) -> f64 {
        a * b
    }
}

/// Min-plus semiring (min, +) — shortest-path algebra; included for the
/// generic-operator extension of paper §V-A and exercised by tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct MinPlus;

impl Semiring for MinPlus {
    const NAME: &'static str = "minplus";
    #[inline]
    fn zero() -> f64 {
        f64::INFINITY
    }
    #[inline]
    fn one() -> f64 {
        0.0
    }
    #[inline]
    fn add(a: f64, b: f64) -> f64 {
        a.min(b)
    }
    #[inline]
    fn mul(a: f64, b: f64) -> f64 {
        a + b
    }
}

/// Numerically-stable log(e^a + e^b).
#[inline]
pub fn log_sum_exp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptestx::Runner;

    fn sample<S: Semiring>(r: &mut crate::rng::Xoshiro256StarStar) -> f64 {
        // Domain-appropriate sampling: nonnegative for ×-based semirings,
        // arbitrary reals for +-based (log-domain) ones.
        match S::NAME {
            "prob" | "maxtimes" => r.uniform(0.0, 10.0),
            _ => r.uniform(-20.0, 20.0),
        }
    }

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()))
    }

    fn laws<S: Semiring>() {
        let mut runner = Runner::new(&format!("semiring-{}", S::NAME));
        runner.run(200, |r| {
            let (a, b, c) = (sample::<S>(r), sample::<S>(r), sample::<S>(r));
            // associativity
            assert!(close(S::add(S::add(a, b), c), S::add(a, S::add(b, c))));
            assert!(close(S::mul(S::mul(a, b), c), S::mul(a, S::mul(b, c))));
            // commutativity of add
            assert!(close(S::add(a, b), S::add(b, a)));
            // identities
            assert!(close(S::add(a, S::zero()), a));
            assert!(close(S::mul(a, S::one()), a));
            assert!(close(S::mul(S::one(), a), a));
            // annihilation
            let z = S::mul(S::zero(), a);
            assert!(z == S::zero() || close(z, S::zero()));
            // distributivity
            assert!(close(
                S::mul(a, S::add(b, c)),
                S::add(S::mul(a, b), S::mul(a, c))
            ));
        });
    }

    #[test]
    fn prob_laws() {
        laws::<Prob>();
    }

    #[test]
    fn logprob_laws() {
        laws::<LogProb>();
    }

    #[test]
    fn maxplus_laws() {
        laws::<MaxPlus>();
    }

    #[test]
    fn maxtimes_laws() {
        laws::<MaxTimes>();
    }

    #[test]
    fn minplus_laws() {
        laws::<MinPlus>();
    }

    #[test]
    fn logprob_matches_prob() {
        // log-domain semiring must mirror the linear one through exp/ln.
        let mut runner = Runner::new("logprob-mirror");
        runner.run(200, |r| {
            let a = r.uniform(0.01, 5.0);
            let b = r.uniform(0.01, 5.0);
            assert!(close(LogProb::add(a.ln(), b.ln()), (a + b).ln()));
            assert!(close(LogProb::mul(a.ln(), b.ln()), (a * b).ln()));
        });
    }

    #[test]
    fn log_sum_exp_extremes() {
        assert_eq!(log_sum_exp(f64::NEG_INFINITY, 3.0), 3.0);
        assert_eq!(log_sum_exp(3.0, f64::NEG_INFINITY), 3.0);
        assert_eq!(
            log_sum_exp(f64::NEG_INFINITY, f64::NEG_INFINITY),
            f64::NEG_INFINITY
        );
        // no overflow at large magnitudes
        let v = log_sum_exp(1000.0, 1000.0);
        assert!((v - (1000.0 + 2f64.ln())).abs() < 1e-12);
    }
}

//! Seeded property-test runner (the `proptest` crate is unavailable
//! offline — see DESIGN.md §1).
//!
//! Deliberately small: a named [`Runner`] derives a deterministic seed
//! from its name, hands the test closure a fresh RNG per case, and
//! reports the failing case index + seed on panic so a failure
//! reproduces exactly. Shrinking is out of scope — cases are generated
//! from independently seeded RNGs, so re-running a single failing index
//! is cheap.

use crate::rng::{SplitMix64, Xoshiro256StarStar};

/// Property-test runner with deterministic, name-derived seeding.
pub struct Runner {
    base_seed: u64,
    name: String,
}

impl Runner {
    /// A runner seeded deterministically from its name.
    pub fn new(name: &str) -> Self {
        // FNV-1a of the name → stable seed independent of test order.
        let h = crate::rng::fnv1a_64(crate::rng::FNV1A_OFFSET, name.as_bytes());
        Self { base_seed: h, name: name.to_string() }
    }

    /// Override the seed (e.g. to reproduce a reported failure).
    pub fn with_seed(name: &str, seed: u64) -> Self {
        Self { base_seed: seed, name: name.to_string() }
    }

    /// Run `cases` independent cases; each gets its own RNG.
    pub fn run<F>(&mut self, cases: usize, mut prop: F)
    where
        F: FnMut(&mut Xoshiro256StarStar),
    {
        for case in 0..cases {
            let mut sm = SplitMix64::new(self.base_seed.wrapping_add(case as u64));
            let mut rng = Xoshiro256StarStar::seed_from_u64(sm.next_u64());
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || prop(&mut rng),
            ));
            if let Err(payload) = result {
                eprintln!(
                    "[proptestx] property '{}' failed at case {case} \
                     (reproduce with Runner::with_seed(\"{}\", {:#x}) and a \
                     single case offset {case})",
                    self.name, self.name, self.base_seed
                );
                std::panic::resume_unwind(payload);
            }
        }
    }

    /// Run cases that also receive the case index (useful to scale sizes).
    pub fn run_indexed<F>(&mut self, cases: usize, mut prop: F)
    where
        F: FnMut(usize, &mut Xoshiro256StarStar),
    {
        let mut idx = 0;
        self.run(cases, move |rng| {
            prop(idx, rng);
            idx += 1;
        });
    }
}

/// Assert two f64 slices are **bit-identical** (`f64::to_bits`), with a
/// hex dump of the first mismatch. Bitwise comparison (not `==`)
/// distinguishes `0.0` from `-0.0` and treats equal-bit NaNs as equal —
/// the contract the kernel differential harness checks.
pub fn assert_bits_eq(label: &str, got: &[f64], want: &[f64]) {
    assert_eq!(
        got.len(),
        want.len(),
        "{label}: length mismatch {} vs {}",
        got.len(),
        want.len()
    );
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{label}: bit mismatch at index {i}: got {g:?} ({:#018x}), \
             want {w:?} ({:#018x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// Per-thread allocation counting for "this hot path is allocation-free"
/// assertions (the `dhat`/`allocation-counter` crates are unavailable
/// offline). Only compiled into the test binary: a counting
/// `#[global_allocator]` that forwards to the system allocator and bumps
/// a thread-local counter on every `alloc`/`realloc`. Tests snapshot
/// [`alloc_count::current`] around the code under test; other test
/// threads don't interfere because the counter is thread-local.
#[cfg(test)]
pub mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    thread_local! {
        static ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    /// Allocations observed on the current thread so far.
    pub fn current() -> u64 {
        ALLOCS.try_with(|c| c.get()).unwrap_or(0)
    }

    /// System-allocator wrapper that counts thread-local allocations.
    pub struct CountingAllocator;

    // SAFETY: forwards every operation to `System` unchanged; the
    // counter bump allocates nothing (const-initialized Cell TLS).
    unsafe impl GlobalAlloc for CountingAllocator {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static COUNTING: CountingAllocator = CountingAllocator;
}

/// Generator helpers for common HMM-shaped data.
pub mod gen {
    use crate::rng::Xoshiro256StarStar;

    /// Row-stochastic matrix with entries bounded away from zero.
    pub fn stochastic_matrix(r: &mut Xoshiro256StarStar, d: usize) -> Vec<f64> {
        let mut m = vec![0.0; d * d];
        for row in 0..d {
            let mut total = 0.0;
            for col in 0..d {
                let v = r.uniform(0.05, 1.0);
                m[row * d + col] = v;
                total += v;
            }
            for col in 0..d {
                m[row * d + col] /= total;
            }
        }
        m
    }

    /// Probability vector bounded away from zero.
    pub fn prob_vector(r: &mut Xoshiro256StarStar, d: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..d).map(|_| r.uniform(0.05, 1.0)).collect();
        let total: f64 = v.iter().sum();
        v.iter_mut().for_each(|x| *x /= total);
        v
    }

    /// Observation sequence of symbols in [0, m).
    pub fn obs_seq(r: &mut Xoshiro256StarStar, m: usize, len: usize) -> Vec<u32> {
        (0..len).map(|_| r.below(m as u64) as u32).collect()
    }

    /// Adversarial values for the linear-domain semirings (`Prob`,
    /// `MaxTimes`): signed zeros, subnormals, huge/tiny magnitudes,
    /// infinities, NaN.
    const ADVERSARIAL_LINEAR: [f64; 15] = [
        0.0,
        -0.0,
        1.0,
        -1.0,
        f64::MIN_POSITIVE,
        5e-324, // smallest subnormal
        1e-310, // mid-range subnormal
        f64::INFINITY,
        f64::NEG_INFINITY,
        f64::NAN,
        1e300,
        1e-300,
        0.5,
        2.0,
        -3.5,
    ];

    /// Adversarial values for the log-domain semirings (`MaxPlus`,
    /// `LogProb`): −∞ is the additive zero there, so it appears
    /// alongside signed zeros, subnormals, exp-overflow magnitudes and
    /// NaN.
    const ADVERSARIAL_LOG: [f64; 11] = [
        f64::NEG_INFINITY,
        0.0,
        -0.0,
        f64::INFINITY,
        f64::NAN,
        -1e30,
        5e-324,
        -745.3, // exp() underflows to 0
        708.4,  // exp() overflows to ∞
        1.5,
        -2.5,
    ];

    /// A d×d row-major matrix whose entries are ~50% drawn from an
    /// adversarial pool (signed zeros, subnormals, ±∞, NaN, extreme
    /// magnitudes) and otherwise uniform. `log_domain` selects the pool
    /// whose special values match semirings with `zero() = −∞`
    /// (`MaxPlus`, `LogProb`). Built for differential kernel tests,
    /// where bit-identity must survive exactly these inputs.
    pub fn adversarial_matrix(r: &mut Xoshiro256StarStar, d: usize, log_domain: bool) -> Vec<f64> {
        let pool: &[f64] = if log_domain {
            &ADVERSARIAL_LOG
        } else {
            &ADVERSARIAL_LINEAR
        };
        (0..d * d)
            .map(|_| {
                if r.below(2) == 0 {
                    pool[r.below(pool.len() as u64) as usize]
                } else if log_domain {
                    r.uniform(-30.0, 5.0)
                } else {
                    r.uniform(0.0, 1.5)
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut seen1 = Vec::new();
        Runner::new("det").run(5, |r| seen1.push(r.next_u64()));
        let mut seen2 = Vec::new();
        Runner::new("det").run(5, |r| seen2.push(r.next_u64()));
        assert_eq!(seen1, seen2);
    }

    #[test]
    fn different_names_different_streams() {
        let mut a = Vec::new();
        Runner::new("stream-a").run(3, |r| a.push(r.next_u64()));
        let mut b = Vec::new();
        Runner::new("stream-b").run(3, |r| b.push(r.next_u64()));
        assert_ne!(a, b);
    }

    #[test]
    fn generators_produce_valid_shapes() {
        Runner::new("gen-shapes").run(20, |r| {
            let d = 2 + (r.below(6) as usize);
            let m = gen::stochastic_matrix(r, d);
            assert_eq!(m.len(), d * d);
            for row in 0..d {
                let s: f64 = m[row * d..(row + 1) * d].iter().sum();
                assert!((s - 1.0).abs() < 1e-12);
            }
            let p = gen::prob_vector(r, d);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            let ys = gen::obs_seq(r, 4, 17);
            assert_eq!(ys.len(), 17);
            assert!(ys.iter().all(|&y| y < 4));
        });
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        Runner::new("fails").run(10, |_| panic!("boom"));
    }

    #[test]
    fn adversarial_matrix_has_right_shape_and_hits_special_values() {
        let mut saw_nonfinite = false;
        Runner::new("gen-adversarial").run(20, |r| {
            for log_domain in [false, true] {
                let m = gen::adversarial_matrix(r, 8, log_domain);
                assert_eq!(m.len(), 64);
                saw_nonfinite |= m.iter().any(|v| !v.is_finite());
            }
        });
        // With ~50% adversarial draws over 20×2 matrices, non-finite
        // specials are statistically certain under the fixed seed.
        assert!(saw_nonfinite);
    }

    #[test]
    fn assert_bits_eq_distinguishes_signed_zero_and_accepts_nan() {
        assert_bits_eq("nan-ok", &[f64::NAN, -0.0], &[f64::NAN, -0.0]);
        let r = std::panic::catch_unwind(|| assert_bits_eq("zero-sign", &[0.0], &[-0.0]));
        assert!(r.is_err());
    }
}

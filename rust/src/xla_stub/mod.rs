//! API-compatible stand-in for the `xla` PJRT-bindings crate, which is
//! unavailable in the offline build environment (DESIGN.md §1).
//!
//! [`crate::runtime::client`] is written against the real crate's call
//! surface (`HloModuleProto::from_text_file → XlaComputation →
//! PjRtClient::compile → execute`). This module preserves that surface
//! exactly but reports a typed "PJRT unavailable" error at client
//! construction, so:
//!
//! * the crate builds and tests with zero external dependencies;
//! * every serving path degrades to the native backend (the coordinator
//!   only enables the PJRT path when artifacts exist *and* the client
//!   comes up — see `CoordinatorConfig::default`);
//! * swapping the real bindings back in is a one-line change in
//!   `runtime/client.rs` (`use crate::xla_stub as xla` → `use xla`).

use std::fmt;

/// Error type mirroring the bindings crate's error (Display-able).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type XlaResult<T> = std::result::Result<T, XlaError>;

fn unavailable<T>() -> XlaResult<T> {
    Err(XlaError(
        "PJRT runtime unavailable: the `xla` bindings crate is not part of \
         the offline build (native backend serves all requests)"
            .to_string(),
    ))
}

/// Typed storage behind a [`Literal`].
#[derive(Debug, Clone)]
pub enum LiteralData {
    /// f32 payload.
    F32(Vec<f32>),
    /// i32 payload.
    I32(Vec<i32>),
}

/// Element types that can cross the literal boundary.
pub trait NativeType: Copy {
    /// Copy the literal's payload out as this type.
    fn read(lit: &Literal) -> XlaResult<Vec<Self>>
    where
        Self: Sized;
    /// Wrap a host slice as literal storage.
    fn store(data: &[Self]) -> LiteralData;
}

impl NativeType for f32 {
    fn read(lit: &Literal) -> XlaResult<Vec<f32>> {
        match &lit.data {
            LiteralData::F32(v) => Ok(v.clone()),
            _ => Err(XlaError("literal does not hold f32 data".to_string())),
        }
    }
    fn store(data: &[f32]) -> LiteralData {
        LiteralData::F32(data.to_vec())
    }
}

impl NativeType for i32 {
    fn read(lit: &Literal) -> XlaResult<Vec<i32>> {
        match &lit.data {
            LiteralData::I32(v) => Ok(v.clone()),
            _ => Err(XlaError("literal does not hold i32 data".to_string())),
        }
    }
    fn store(data: &[i32]) -> LiteralData {
        LiteralData::I32(data.to_vec())
    }
}

/// Host-side tensor literal.
#[derive(Debug, Clone)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: impl AsRef<[T]>) -> Literal {
        let data = data.as_ref();
        Literal { data: T::store(data), dims: vec![data.len() as i64] }
    }

    /// Reinterpret the literal under new dims (element count must
    /// match).
    pub fn reshape(&self, dims: &[i64]) -> XlaResult<Literal> {
        let want: i64 = dims.iter().product();
        let have = match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        } as i64;
        if want != have {
            return Err(XlaError(format!(
                "cannot reshape {have} elements to {dims:?}"
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// The literal's dims.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the payload out as `T`.
    pub fn to_vec<T: NativeType>(&self) -> XlaResult<Vec<T>> {
        T::read(self)
    }

    /// Decompose a tuple literal. The stub never produces tuples (no
    /// executable can run), so this is unreachable in practice.
    pub fn to_tuple(&self) -> XlaResult<Vec<Literal>> {
        unavailable()
    }
}

/// PJRT client handle. Construction always fails in the stub.
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client — always the typed "unavailable" error in the stub.
    pub fn cpu() -> XlaResult<PjRtClient> {
        unavailable()
    }

    /// Platform name (constant in the stub).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Device count (zero in the stub).
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation — unreachable in the stub (no client can
    /// be constructed).
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> XlaResult<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file — typed "unavailable" error in the stub.
    pub fn from_text_file(_path: &str) -> XlaResult<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with literal arguments — unreachable in the stub.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal — unreachable in the stub.
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.dims(), &[4]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn i32_literals() {
        let lit = Literal::vec1(&[7i32, 8]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8]);
    }
}

//! Unified error type for the crate.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for model validation, runtime, coordinator and IO
/// failures.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Invalid HMM specification (non-stochastic rows, shape mismatch…).
    #[error("invalid model: {0}")]
    InvalidModel(String),

    /// Invalid request (empty sequence, observation symbol out of range…).
    #[error("invalid request: {0}")]
    InvalidRequest(String),

    /// JSON parse/serialize failure (jsonx substrate).
    #[error("json error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Artifact manifest problems: missing file, bad signature, …
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT/XLA runtime failure.
    #[error("xla runtime: {0}")]
    Xla(String),

    /// Coordinator lifecycle errors (queue closed, worker panicked…).
    #[error("coordinator: {0}")]
    Coordinator(String),

    /// CLI usage error.
    #[error("usage: {0}")]
    Usage(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl Error {
    pub fn invalid_model(msg: impl fmt::Display) -> Self {
        Error::InvalidModel(msg.to_string())
    }
    pub fn invalid_request(msg: impl fmt::Display) -> Self {
        Error::InvalidRequest(msg.to_string())
    }
    pub fn artifact(msg: impl fmt::Display) -> Self {
        Error::Artifact(msg.to_string())
    }
    pub fn xla(msg: impl fmt::Display) -> Self {
        Error::Xla(msg.to_string())
    }
    pub fn coordinator(msg: impl fmt::Display) -> Self {
        Error::Coordinator(msg.to_string())
    }
    pub fn usage(msg: impl fmt::Display) -> Self {
        Error::Usage(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert_eq!(
            Error::invalid_model("rows").to_string(),
            "invalid model: rows"
        );
        assert_eq!(
            Error::Json { offset: 3, msg: "bad".into() }.to_string(),
            "json error at byte 3: bad"
        );
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}

//! Unified error type for the crate.
//!
//! Hand-rolled `Display`/`Error` impls — the `thiserror` derive crate is
//! unavailable in the offline build (DESIGN.md §1).

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for model validation, runtime, coordinator and IO
/// failures.
#[derive(Debug)]
pub enum Error {
    /// Invalid HMM specification (non-stochastic rows, shape mismatch…).
    InvalidModel(String),

    /// Invalid request (empty sequence, observation symbol out of range…).
    InvalidRequest(String),

    /// JSON parse/serialize failure (jsonx substrate).
    Json {
        /// Byte offset of the failure in the input text.
        offset: usize,
        /// What went wrong there.
        msg: String,
    },

    /// Artifact manifest problems: missing file, bad signature, …
    Artifact(String),

    /// PJRT/XLA runtime failure.
    Xla(String),

    /// Coordinator lifecycle errors (queue closed, worker panicked…).
    Coordinator(String),

    /// CLI usage error.
    Usage(String),

    /// Transient overload: the server (or a cluster router) refused the
    /// request but expects to accept it again after roughly
    /// `retry_after_ms` milliseconds. Carried over the wire as a
    /// dedicated reject frame so clients can back off instead of
    /// treating the refusal as fatal.
    Busy {
        /// Suggested client back-off before retrying, in milliseconds.
        retry_after_ms: u64,
        /// What was saturated (connection limit, drain, worker pool…).
        msg: String,
    },

    /// IO failure (transparent).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidModel(m) => write!(f, "invalid model: {m}"),
            Error::InvalidRequest(m) => write!(f, "invalid request: {m}"),
            Error::Json { offset, msg } => {
                write!(f, "json error at byte {offset}: {msg}")
            }
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla runtime: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator: {m}"),
            Error::Usage(m) => write!(f, "usage: {m}"),
            Error::Busy { retry_after_ms, msg } => {
                write!(f, "busy: {msg} (retry in {retry_after_ms} ms)")
            }
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// An [`Error::InvalidModel`] from any displayable message.
    pub fn invalid_model(msg: impl fmt::Display) -> Self {
        Error::InvalidModel(msg.to_string())
    }
    /// An [`Error::InvalidRequest`] from any displayable message.
    pub fn invalid_request(msg: impl fmt::Display) -> Self {
        Error::InvalidRequest(msg.to_string())
    }
    /// An [`Error::Artifact`] from any displayable message.
    pub fn artifact(msg: impl fmt::Display) -> Self {
        Error::Artifact(msg.to_string())
    }
    /// An [`Error::Xla`] from any displayable message.
    pub fn xla(msg: impl fmt::Display) -> Self {
        Error::Xla(msg.to_string())
    }
    /// An [`Error::Coordinator`] from any displayable message.
    pub fn coordinator(msg: impl fmt::Display) -> Self {
        Error::Coordinator(msg.to_string())
    }
    /// An [`Error::Usage`] from any displayable message.
    pub fn usage(msg: impl fmt::Display) -> Self {
        Error::Usage(msg.to_string())
    }
    /// An [`Error::Busy`] with a retry hint in milliseconds.
    pub fn busy(retry_after_ms: u64, msg: impl fmt::Display) -> Self {
        Error::Busy { retry_after_ms, msg: msg.to_string() }
    }
    /// Whether this error is a transient-overload rejection a client
    /// may retry after the carried back-off hint.
    pub fn is_busy(&self) -> bool {
        matches!(self, Error::Busy { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert_eq!(
            Error::invalid_model("rows").to_string(),
            "invalid model: rows"
        );
        assert_eq!(
            Error::Json { offset: 3, msg: "bad".into() }.to_string(),
            "json error at byte 3: bad"
        );
        let busy = Error::busy(250, "server draining");
        assert_eq!(busy.to_string(), "busy: server draining (retry in 250 ms)");
        assert!(busy.is_busy());
        assert!(!Error::usage("x").is_busy());
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert_eq!(e.to_string(), "x");
        assert!(std::error::Error::source(&e).is_some());
    }
}

//! Unified error type for the crate.
//!
//! Hand-rolled `Display`/`Error` impls — the `thiserror` derive crate is
//! unavailable in the offline build (DESIGN.md §1).

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for model validation, runtime, coordinator and IO
/// failures.
#[derive(Debug)]
pub enum Error {
    /// Invalid HMM specification (non-stochastic rows, shape mismatch…).
    InvalidModel(String),

    /// Invalid request (empty sequence, observation symbol out of range…).
    InvalidRequest(String),

    /// JSON parse/serialize failure (jsonx substrate).
    Json {
        /// Byte offset of the failure in the input text.
        offset: usize,
        /// What went wrong there.
        msg: String,
    },

    /// Artifact manifest problems: missing file, bad signature, …
    Artifact(String),

    /// PJRT/XLA runtime failure.
    Xla(String),

    /// Coordinator lifecycle errors (queue closed, worker panicked…).
    Coordinator(String),

    /// CLI usage error.
    Usage(String),

    /// IO failure (transparent).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidModel(m) => write!(f, "invalid model: {m}"),
            Error::InvalidRequest(m) => write!(f, "invalid request: {m}"),
            Error::Json { offset, msg } => {
                write!(f, "json error at byte {offset}: {msg}")
            }
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla runtime: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator: {m}"),
            Error::Usage(m) => write!(f, "usage: {m}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// An [`Error::InvalidModel`] from any displayable message.
    pub fn invalid_model(msg: impl fmt::Display) -> Self {
        Error::InvalidModel(msg.to_string())
    }
    /// An [`Error::InvalidRequest`] from any displayable message.
    pub fn invalid_request(msg: impl fmt::Display) -> Self {
        Error::InvalidRequest(msg.to_string())
    }
    /// An [`Error::Artifact`] from any displayable message.
    pub fn artifact(msg: impl fmt::Display) -> Self {
        Error::Artifact(msg.to_string())
    }
    /// An [`Error::Xla`] from any displayable message.
    pub fn xla(msg: impl fmt::Display) -> Self {
        Error::Xla(msg.to_string())
    }
    /// An [`Error::Coordinator`] from any displayable message.
    pub fn coordinator(msg: impl fmt::Display) -> Self {
        Error::Coordinator(msg.to_string())
    }
    /// An [`Error::Usage`] from any displayable message.
    pub fn usage(msg: impl fmt::Display) -> Self {
        Error::Usage(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert_eq!(
            Error::invalid_model("rows").to_string(),
            "invalid model: rows"
        );
        assert_eq!(
            Error::Json { offset: 3, msg: "bad".into() }.to_string(),
            "json error at byte 3: bad"
        );
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert_eq!(e.to_string(), "x");
        assert!(std::error::Error::source(&e).is_some());
    }
}

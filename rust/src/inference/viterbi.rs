//! The classical Viterbi algorithm (paper Algorithm 4), in log domain.

use crate::elements::safe_ln;
use crate::error::Result;
use crate::hmm::Hmm;
use crate::linalg::argmax;

use super::types::MapEstimate;

/// Classical Viterbi (Algorithm 4): forward max recursion storing the
/// argmax function u, then backtrace. O(D²T) work and span.
pub fn viterbi(hmm: &Hmm, ys: &[u32]) -> Result<MapEstimate> {
    hmm.check_observations(ys)?;
    let d = hmm.num_states();
    let t = ys.len();
    let pi = hmm.transition();

    // log-domain transition matrix, precomputed once.
    let lpi: Vec<f64> = pi.data().iter().map(|&v| safe_ln(v)).collect();

    // Forward pass (lines 2-6): V_k and u_{k-1}.
    let mut v: Vec<f64> = {
        let e = hmm.emission_col(ys[0]);
        (0..d).map(|s| safe_ln(hmm.prior()[s]) + safe_ln(e[s])).collect()
    };
    let mut u = vec![0u32; (t - 1) * d];
    for k in 1..t {
        let e = hmm.emission_col(ys[k]);
        let mut vn = vec![f64::NEG_INFINITY; d];
        let uk = &mut u[(k - 1) * d..k * d];
        for (i, &vi) in v.iter().enumerate() {
            let lrow = &lpi[i * d..(i + 1) * d];
            for j in 0..d {
                let cand = vi + lrow[j];
                if cand > vn[j] {
                    vn[j] = cand;
                    uk[j] = i as u32;
                }
            }
        }
        for (j, x) in vn.iter_mut().enumerate() {
            *x += safe_ln(e[j]);
        }
        v = vn;
    }

    // Backward pass (lines 8-11): backtrace from the best terminal state.
    let mut path = vec![0u32; t];
    let best_last = argmax(&v);
    path[t - 1] = best_last as u32;
    for k in (1..t).rev() {
        path[k - 1] = u[(k - 1) * d + path[k] as usize];
    }

    Ok(MapEstimate { path, log_prob: v[best_last] })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::{gilbert_elliott, GeParams, Hmm};
    use crate::linalg::Mat;

    #[test]
    fn deterministic_chain_recovers_states() {
        // Near-deterministic emissions: the Viterbi path must equal the
        // emitting states.
        let hmm = Hmm::new(
            Mat::from_vec(2, 2, vec![0.7, 0.3, 0.3, 0.7]),
            Mat::from_vec(2, 2, vec![0.99, 0.01, 0.01, 0.99]),
            vec![0.5, 0.5],
        )
        .unwrap();
        let ys = vec![0, 0, 1, 1, 1, 0, 0];
        let est = viterbi(&hmm, &ys).unwrap();
        assert_eq!(est.path, ys);
        assert!(est.log_prob < 0.0);
    }

    #[test]
    fn path_score_matches_reported_log_prob() {
        let hmm = gilbert_elliott(GeParams::default());
        let ys: Vec<u32> = (0..200).map(|i| ((i / 13) % 2) as u32).collect();
        let est = viterbi(&hmm, &ys).unwrap();
        // Re-score the returned path independently.
        let mut lp = (hmm.prior()[est.path[0] as usize]
            * hmm.emission()[(est.path[0] as usize, ys[0] as usize)])
            .ln();
        for k in 1..ys.len() {
            lp += (hmm.transition()[(est.path[k - 1] as usize, est.path[k] as usize)]
                * hmm.emission()[(est.path[k] as usize, ys[k] as usize)])
                .ln();
        }
        assert!((lp - est.log_prob).abs() < 1e-9);
    }

    #[test]
    fn impossible_observation_under_zero_emission() {
        // A state with zero emission probability for a symbol must never
        // appear at that step.
        let hmm = Hmm::new(
            Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]),
            Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]),
            vec![0.5, 0.5],
        )
        .unwrap();
        let est = viterbi(&hmm, &[0, 1, 0, 1]).unwrap();
        assert_eq!(est.path, vec![0, 1, 0, 1]);
    }
}

//! Max-product MAP estimators: sequential (Lemma 3 + Theorem 4),
//! parallel-scan (Algorithm 5), and the path-based parallel variant
//! (§IV-B, Definition 4 / Corollary 1).

use crate::elements::{
    mp_element_chain, mp_element_chain_into, mp_terminal, safe_ln, MpElement,
    MpOp, PathElement, PathOp,
};
use crate::error::Result;
use crate::hmm::Hmm;
use crate::linalg::argmax;
use crate::scan::{run_scan, run_scan_rev, AssocOp, ScanOptions};

use super::types::MapEstimate;
use super::workspace::{copy_elements, copy_elements_shifted, Workspace};

/// MP-Seq — sequential max-product: the ψ̃^f / ψ̃^b recursions of
/// Lemma 3, combined per Theorem 4 (Eq. 40). O(D²T) work and span.
pub fn mp_seq(hmm: &Hmm, ys: &[u32]) -> Result<MapEstimate> {
    hmm.check_observations(ys)?;
    let d = hmm.num_states();
    let t = ys.len();
    let lpi: Vec<f64> = hmm.transition().data().iter().map(|&v| safe_ln(v)).collect();

    // Forward maxima ψ̃^f_k (Lemma 3, first recursion).
    let mut fs = vec![f64::NEG_INFINITY; t * d];
    {
        let e = hmm.emission_col(ys[0]);
        for s in 0..d {
            fs[s] = safe_ln(hmm.prior()[s]) + safe_ln(e[s]);
        }
    }
    for k in 1..t {
        let e = hmm.emission_col(ys[k]);
        let (prev, cur) = fs.split_at_mut(k * d);
        let prev = &prev[(k - 1) * d..];
        let cur = &mut cur[..d];
        for (j, c) in cur.iter_mut().enumerate() {
            let mut best = f64::NEG_INFINITY;
            for (i, &p) in prev.iter().enumerate() {
                best = best.max(p + lpi[i * d + j]);
            }
            *c = best + safe_ln(e[j]);
        }
    }

    // Backward maxima ψ̃^b_k (Lemma 3, second recursion).
    let mut bs = vec![0.0f64; t * d];
    for k in (0..t.saturating_sub(1)).rev() {
        let e = hmm.emission_col(ys[k + 1]);
        let (cur, next) = bs.split_at_mut((k + 1) * d);
        let cur = &mut cur[k * d..];
        let next = &next[..d];
        for (i, c) in cur.iter_mut().enumerate() {
            let mut best = f64::NEG_INFINITY;
            for j in 0..d {
                best = best.max(lpi[i * d + j] + safe_ln(e[j]) + next[j]);
            }
            *c = best;
        }
    }

    // Theorem 4 (Eq. 40): x*_k = argmax ψ̃^f ψ̃^b.
    let mut path = vec![0u32; t];
    for k in 0..t {
        let delta: Vec<f64> = (0..d).map(|s| fs[k * d + s] + bs[k * d + s]).collect();
        path[k] = argmax(&delta) as u32;
    }
    let log_prob = fs[(t - 1) * d..]
        .iter()
        .fold(f64::NEG_INFINITY, |m, &v| m.max(v));
    Ok(MapEstimate { path, log_prob })
}

/// MP-Par — parallel max-product (Algorithm 5): forward and reversed
/// parallel scans over log-domain elements with the tropical ∨ combine,
/// MAP states via Eq. (40). O(D³ log T) span, O(D³ T) work.
///
/// Thin wrapper over [`mp_par_ws`] with a throwaway workspace; the
/// serving hot path goes through `engine::Engine`, which reuses one.
pub fn mp_par(hmm: &Hmm, ys: &[u32], opts: ScanOptions) -> Result<MapEstimate> {
    mp_par_ws(hmm, ys, opts, &mut Workspace::default())
}

/// [`mp_par`] with caller-owned scratch (see `inference::workspace`).
pub fn mp_par_ws(
    hmm: &Hmm,
    ys: &[u32],
    opts: ScanOptions,
    ws: &mut Workspace,
) -> Result<MapEstimate> {
    hmm.check_observations(ys)?;
    let d = hmm.num_states();
    let op = MpOp { d };

    let elems = &mut ws.mp.elems;
    mp_element_chain_into(hmm, ys, elems);
    let fwd = &mut ws.mp.fwd;
    copy_elements(elems.as_slice(), fwd);
    run_scan(&op, fwd.as_mut_slice(), opts);

    let bwd = &mut ws.mp.bwd;
    copy_elements_shifted(elems.as_slice(), mp_terminal(d), bwd);
    run_scan_rev(&op, bwd.as_mut_slice(), opts);

    Ok(mp_map_from_scans(d, fwd, bwd))
}

/// Eq. (40) finalization, shared by [`mp_par_ws`] and the streaming
/// `engine::Session`: x*_k = argmax ψ̃^f ψ̃^b, with ψ̃^f read from row 0
/// (prior-broadcast rows) and ψ̃^b from column 0 (terminal-broadcast
/// columns); the joint log-probability is the forward maximum at T.
pub(crate) fn mp_map_from_scans(
    d: usize,
    fwd: &[MpElement],
    bwd: &[MpElement],
) -> MapEstimate {
    let t = fwd.len();
    debug_assert_eq!(t, bwd.len());
    let mut path = vec![0u32; t];
    for k in 0..t {
        let frow = fwd[k].mat.row(0);
        let delta: Vec<f64> = (0..d).map(|s| frow[s] + bwd[k].mat[(s, 0)]).collect();
        path[k] = argmax(&delta) as u32;
    }
    let log_prob = fwd[t - 1]
        .mat
        .row(0)
        .iter()
        .fold(f64::NEG_INFINITY, |m, &v| m.max(v));
    MapEstimate { path, log_prob }
}

/// Path-based parallel Viterbi (§IV-B): a single parallel *reduction*
/// over [`PathElement`]s computes ã_{0:T+1} (Corollary 1) whose stored
/// path is x*_{1:T} directly. Memory O(D²T) — the cost Algorithm 5
/// avoids; provided for the paper's comparison of the two formulations.
pub fn mp_path_par(hmm: &Hmm, ys: &[u32], opts: ScanOptions) -> Result<MapEstimate> {
    hmm.check_observations(ys)?;
    let d = hmm.num_states();
    let op = PathOp { d };

    let mut elems: Vec<PathElement> = mp_element_chain(hmm, ys)
        .into_iter()
        .map(|e| PathElement::leaf(e.mat))
        .collect();
    elems.push(PathElement::leaf(mp_terminal(d).mat));

    // Tree reduction (the scan computes all prefixes; only the total is
    // needed here, so reduce pairwise — same O(log T) span, less work).
    let total = tree_reduce(&op, &mut elems, opts);

    // Corollary 1: ã_{0:T+1} holds x*_{1:T} as its interior path for any
    // (x_0, x_{T+1}) pair — both endpoints are broadcast dimensions.
    let path: Vec<u32> = total.paths[0].clone();
    let log_prob = total.mat[(0, 0)];
    Ok(MapEstimate { path, log_prob })
}

fn tree_reduce<E, Op>(op: &Op, elems: &mut Vec<E>, opts: ScanOptions) -> E
where
    E: Clone + Send + Sync,
    Op: AssocOp<E>,
{
    while elems.len() > 1 {
        let pairs = elems.len() / 2;
        let mut next: Vec<E> = Vec::with_capacity(pairs + 1);
        if pairs >= opts.min_parallel_work && opts.threads > 1 {
            let mut buf: Vec<Option<E>> = vec![None; pairs];
            {
                let out = crate::exec::SharedSliceMut::new(&mut buf);
                let elems_ref: &[E] = elems;
                crate::exec::parallel_for_chunks(pairs, opts.threads, |_, lo, hi| {
                    for p in lo..hi {
                        let combined =
                            op.combine(&elems_ref[2 * p], &elems_ref[2 * p + 1]);
                        // SAFETY: slot p written by exactly one chunk.
                        unsafe { out.write(p, Some(combined)) };
                    }
                });
            }
            next.extend(buf.into_iter().map(|o| o.unwrap()));
        } else {
            for p in 0..pairs {
                next.push(op.combine(&elems[2 * p], &elems[2 * p + 1]));
            }
        }
        if elems.len() % 2 == 1 {
            next.push(elems[elems.len() - 1].clone());
        }
        *elems = next;
    }
    elems.pop().expect("tree_reduce on empty input")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::{gilbert_elliott, GeParams};

    #[test]
    fn mp_seq_logprob_equals_forward_max() {
        let hmm = gilbert_elliott(GeParams::default());
        let ys = vec![0, 1, 0, 0, 1, 1, 0];
        let a = mp_seq(&hmm, &ys).unwrap();
        let b = super::super::viterbi(&hmm, &ys).unwrap();
        assert!((a.log_prob - b.log_prob).abs() < 1e-12);
    }

    #[test]
    fn tree_reduce_orders_correctly() {
        // Non-commutative check via string concatenation.
        struct Cat;
        impl AssocOp<String> for Cat {
            fn identity(&self) -> String {
                String::new()
            }
            fn combine(&self, a: &String, b: &String) -> String {
                format!("{a}{b}")
            }
        }
        for n in [1usize, 2, 3, 5, 8, 13] {
            let mut v: Vec<String> = (0..n).map(|i| i.to_string()).collect();
            let total = tree_reduce(&Cat, &mut v, ScanOptions::serial());
            let want: String = (0..n).map(|i| i.to_string()).collect();
            assert_eq!(total, want, "n={n}");
        }
    }

    #[test]
    fn path_par_full_path_length() {
        let hmm = gilbert_elliott(GeParams::default());
        let ys = vec![1, 0, 0, 1, 1];
        let est = mp_path_par(&hmm, &ys, ScanOptions::serial()).unwrap();
        assert_eq!(est.path.len(), 5);
        assert!(est.path.iter().all(|&s| s < 4));
    }
}

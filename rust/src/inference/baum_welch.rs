//! Baum–Welch parameter estimation (paper §V-C): EM where the E-step is
//! the forward–backward algorithm — and can therefore run through either
//! the sequential or the parallel-scan smoother, which is exactly the
//! parallelization the paper proposes for this task.

use crate::elements::{sp_element_chain, sp_terminal, SpOp, TINY};
use crate::error::Result;
use crate::hmm::Hmm;
use crate::linalg::{normalize_sum, Mat};
use crate::scan::{run_scan, run_scan_rev, ScanOptions};

/// Which smoother powers the E-step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EStepBackend {
    /// Classical O(T)-span forward-backward.
    Sequential,
    /// Parallel-scan forward-backward (Algorithm 3) — §V-C.
    ParallelScan,
}

/// Options for [`baum_welch`].
#[derive(Debug, Clone, Copy)]
pub struct BaumWelchOptions {
    /// Maximum EM iterations.
    pub max_iters: usize,
    /// Stop when the log-likelihood improves by less than this.
    pub tol: f64,
    /// Which forward–backward engine runs the E-step.
    pub backend: EStepBackend,
    /// Threading/schedule options for the parallel E-step.
    pub scan: ScanOptions,
    /// Dirichlet-style additive smoothing of the M-step counts, keeping
    /// estimated rows strictly positive.
    pub pseudocount: f64,
}

impl Default for BaumWelchOptions {
    fn default() -> Self {
        Self {
            max_iters: 50,
            tol: 1e-6,
            backend: EStepBackend::Sequential,
            scan: ScanOptions::default(),
            pseudocount: 1e-6,
        }
    }
}

/// Result of EM training.
#[derive(Debug, Clone)]
pub struct BaumWelchResult {
    /// The estimated model after the final iteration.
    pub model: Hmm,
    /// log p(y | θ_i) per iteration — monotone non-decreasing (checked by
    /// tests; the property EM guarantees).
    pub loglik_curve: Vec<f64>,
    /// Iterations actually run.
    pub iterations: usize,
    /// Whether the tolerance stop fired before `max_iters`.
    pub converged: bool,
}

/// E-step sufficient statistics.
struct EStats {
    gamma: Vec<f64>,   // (T, D) smoothed marginals
    xi_sum: Mat,       // Σ_k ξ_k(i, j) pairwise expectations
    loglik: f64,
}

/// Run Baum–Welch on a single observation sequence.
pub fn baum_welch(
    init: &Hmm,
    ys: &[u32],
    opts: BaumWelchOptions,
) -> Result<BaumWelchResult> {
    init.check_observations(ys)?;
    let mut model = init.clone();
    let mut curve = Vec::with_capacity(opts.max_iters);
    let mut converged = false;

    for _ in 0..opts.max_iters {
        let stats = e_step(&model, ys, opts)?;
        curve.push(stats.loglik);
        model = m_step(&model, ys, &stats, opts.pseudocount)?;
        if curve.len() >= 2 {
            let delta = curve[curve.len() - 1] - curve[curve.len() - 2];
            if delta.abs() < opts.tol {
                converged = true;
                break;
            }
        }
    }

    let iterations = curve.len();
    Ok(BaumWelchResult { model, loglik_curve: curve, iterations, converged })
}

fn e_step(hmm: &Hmm, ys: &[u32], opts: BaumWelchOptions) -> Result<EStats> {
    let d = hmm.num_states();
    let t = ys.len();

    // Forward/backward potentials — via parallel scans (§V-C) or the
    // classical recursions; both produce normalized ψ^f row / ψ^b col
    // representations we can take γ and ξ from.
    let (fwd_rows, bwd_cols, loglik) = match opts.backend {
        EStepBackend::ParallelScan => {
            let op = SpOp { d };
            let elems = sp_element_chain(hmm, ys);
            let mut fwd = elems.clone();
            run_scan(&op, &mut fwd, opts.scan);
            let mut bwd = elems[1..].to_vec();
            bwd.push(sp_terminal(d));
            run_scan_rev(&op, &mut bwd, opts.scan);
            let loglik = fwd[t - 1].log_scale
                + fwd[t - 1].mat.row(0).iter().sum::<f64>().max(TINY).ln();
            let f: Vec<Vec<f64>> = fwd
                .iter()
                .map(|e| {
                    let mut r = e.mat.row(0).to_vec();
                    normalize_sum(&mut r);
                    r
                })
                .collect();
            let b: Vec<Vec<f64>> = bwd
                .iter()
                .map(|e| {
                    let mut c = e.mat.col(0);
                    let m = c.iter().fold(0.0f64, |m, &v| m.max(v)).max(TINY);
                    c.iter_mut().for_each(|v| *v /= m);
                    c
                })
                .collect();
            (f, b, loglik)
        }
        EStepBackend::Sequential => {
            let pi = hmm.transition();
            let mut f = Vec::with_capacity(t);
            let mut loglik = 0.0;
            let e0 = hmm.emission_col(ys[0]);
            let mut alpha: Vec<f64> =
                (0..d).map(|s| hmm.prior()[s] * e0[s]).collect();
            loglik += normalize_sum(&mut alpha).max(TINY).ln();
            f.push(alpha.clone());
            for k in 1..t {
                let e = hmm.emission_col(ys[k]);
                let mut next = vec![0.0; d];
                for (j, n) in next.iter_mut().enumerate() {
                    for (i, &a) in alpha.iter().enumerate() {
                        *n += a * pi[(i, j)];
                    }
                    *n *= e[j];
                }
                loglik += normalize_sum(&mut next).max(TINY).ln();
                alpha = next;
                f.push(alpha.clone());
            }
            let mut b = vec![vec![1.0; d]; t];
            for k in (0..t - 1).rev() {
                let e = hmm.emission_col(ys[k + 1]);
                let mut cur = vec![0.0; d];
                for (i, c) in cur.iter_mut().enumerate() {
                    for j in 0..d {
                        *c += pi[(i, j)] * e[j] * b[k + 1][j];
                    }
                }
                let m = cur.iter().fold(0.0f64, |m, &v| m.max(v)).max(TINY);
                cur.iter_mut().for_each(|v| *v /= m);
                b[k] = cur;
            }
            (f, b, loglik)
        }
    };

    // γ_k ∝ ψ^f_k ∘ ψ^b_k ; ξ_k(i,j) ∝ ψ^f_k(i) Π(i,j) e_{k+1}(j) ψ^b_{k+1}(j).
    let pi = hmm.transition();
    let mut gamma = vec![0.0f64; t * d];
    let mut xi_sum = Mat::zeros(d, d);
    for k in 0..t {
        let g = &mut gamma[k * d..(k + 1) * d];
        for s in 0..d {
            g[s] = fwd_rows[k][s] * bwd_cols[k][s];
        }
        normalize_sum(g);
        if k + 1 < t {
            let e = hmm.emission_col(ys[k + 1]);
            let mut total = 0.0;
            let mut local = Mat::zeros(d, d);
            for i in 0..d {
                for j in 0..d {
                    let v = fwd_rows[k][i] * pi[(i, j)] * e[j] * bwd_cols[k + 1][j];
                    local[(i, j)] = v;
                    total += v;
                }
            }
            let total = total.max(TINY);
            for i in 0..d {
                for j in 0..d {
                    xi_sum[(i, j)] += local[(i, j)] / total;
                }
            }
        }
    }

    Ok(EStats { gamma, xi_sum, loglik })
}

fn m_step(hmm: &Hmm, ys: &[u32], stats: &EStats, pseudo: f64) -> Result<Hmm> {
    let d = hmm.num_states();
    let m = hmm.num_symbols();
    let t = ys.len();

    // Prior ← γ_1.
    let mut prior: Vec<f64> = stats.gamma[0..d].iter().map(|&v| v + pseudo).collect();
    normalize_sum(&mut prior);

    // Transition ← row-normalized Σ ξ.
    let mut pi = Mat::zeros(d, d);
    for i in 0..d {
        let mut row: Vec<f64> =
            (0..d).map(|j| stats.xi_sum[(i, j)] + pseudo).collect();
        normalize_sum(&mut row);
        for (j, v) in row.into_iter().enumerate() {
            pi[(i, j)] = v;
        }
    }

    // Emission ← per-state observed-symbol expectations.
    let mut obs = Mat::filled(d, m, pseudo);
    for k in 0..t {
        let y = ys[k] as usize;
        for s in 0..d {
            obs[(s, y)] += stats.gamma[k * d + s];
        }
    }
    for s in 0..d {
        let row = &mut obs.data_mut()[s * m..(s + 1) * m];
        normalize_sum(row);
    }

    Hmm::new(pi, obs, prior)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::{gilbert_elliott, sample, GeParams};
    use crate::rng::Xoshiro256StarStar;

    fn perturbed_ge() -> Hmm {
        gilbert_elliott(GeParams { p0: 0.1, p1: 0.2, p2: 0.15, q0: 0.05, q1: 0.2 })
    }

    #[test]
    fn loglik_is_monotone_nondecreasing() {
        let truth = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(21);
        let tr = sample(&truth, 400, &mut rng);
        let res = baum_welch(
            &perturbed_ge(),
            &tr.observations,
            BaumWelchOptions { max_iters: 15, ..Default::default() },
        )
        .unwrap();
        for w in res.loglik_curve.windows(2) {
            assert!(
                w[1] >= w[0] - 1e-7,
                "EM must not decrease loglik: {} -> {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn parallel_and_sequential_estep_agree() {
        let truth = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(22);
        let tr = sample(&truth, 300, &mut rng);
        let a = baum_welch(
            &perturbed_ge(),
            &tr.observations,
            BaumWelchOptions {
                max_iters: 5,
                backend: EStepBackend::Sequential,
                ..Default::default()
            },
        )
        .unwrap();
        let b = baum_welch(
            &perturbed_ge(),
            &tr.observations,
            BaumWelchOptions {
                max_iters: 5,
                backend: EStepBackend::ParallelScan,
                ..Default::default()
            },
        )
        .unwrap();
        for (x, y) in a.loglik_curve.iter().zip(&b.loglik_curve) {
            assert!((x - y).abs() < 1e-8, "curves diverge: {x} vs {y}");
        }
        for (x, y) in a
            .model
            .transition()
            .data()
            .iter()
            .zip(b.model.transition().data())
        {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn training_improves_fit_over_initial() {
        let truth = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(23);
        let tr = sample(&truth, 600, &mut rng);
        let init = perturbed_ge();
        let before = crate::inference::sp_seq(&init, &tr.observations)
            .unwrap()
            .log_likelihood();
        let res = baum_welch(
            &init,
            &tr.observations,
            BaumWelchOptions { max_iters: 20, ..Default::default() },
        )
        .unwrap();
        let after = crate::inference::sp_seq(&res.model, &tr.observations)
            .unwrap()
            .log_likelihood();
        assert!(after > before, "EM should improve fit: {before} -> {after}");
    }

    #[test]
    fn converges_and_reports() {
        let truth = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(24);
        let tr = sample(&truth, 200, &mut rng);
        let res = baum_welch(
            &perturbed_ge(),
            &tr.observations,
            BaumWelchOptions { max_iters: 200, tol: 1e-4, ..Default::default() },
        )
        .unwrap();
        assert!(res.converged);
        assert!(res.iterations < 200);
    }
}

//! HMM inference algorithms — every method the paper benchmarks (§VI)
//! plus the path-based parallel Viterbi (§IV-B) and Baum–Welch (§V-C).
//!
//! | paper name | function | section |
//! |------------|----------|---------|
//! | SP-Seq     | [`sp_seq`]      | Algorithm 1 + Eq. 22 |
//! | SP-Par     | [`sp_par`]      | Algorithm 3 |
//! | Viterbi    | [`viterbi`]     | Algorithm 4 |
//! | MP-Seq     | [`mp_seq`]      | Lemma 3 + Theorem 4 |
//! | MP-Par     | [`mp_par`]      | Algorithm 5 |
//! | (path)     | [`mp_path_par`] | §IV-B (Definition 4, Corollary 1) |
//! | BS-Seq     | [`bs_seq`]      | filter + RTS smoother [32] |
//! | BS-Par     | [`bs_par`]      | Ref. [30] discrete analogue |
//! | Baum-Welch | [`baum_welch`]  | §V-C |
//!
//! All functions share the same I/O shape: an [`Hmm`](crate::hmm::Hmm)
//! and an observation sequence; smoothers return a [`Posterior`], MAP
//! estimators a [`MapEstimate`]. Parallel variants additionally take
//! [`ScanOptions`](crate::scan::ScanOptions), and have `*_ws` forms
//! taking a reusable [`Workspace`] — the free functions are thin
//! wrappers over a throwaway one. The unified entry point over all nine
//! methods is [`engine::Engine`](crate::engine::Engine).

mod bayes;
mod baum_welch;
mod maxprod;
pub(crate) mod streaming;
mod sumprod;
mod types;
mod viterbi;
mod workspace;

pub use bayes::{bs_par, bs_par_ws, bs_seq};
pub use baum_welch::{baum_welch, BaumWelchOptions, BaumWelchResult, EStepBackend};
pub use maxprod::{mp_par, mp_par_ws, mp_path_par, mp_seq};
pub use sumprod::{sp_par, sp_par_ws, sp_seq};
pub use types::{MapEstimate, Posterior};
pub use viterbi::viterbi;
pub use workspace::{BsBuffers, MpBuffers, SpBuffers, StreamBuffers, Workspace};

pub(crate) use bayes::bs_posterior_from_forward;
pub(crate) use maxprod::mp_map_from_scans;
pub(crate) use sumprod::sp_posterior_from_scans;
pub(crate) use workspace::{apply_growth_policy, copy_elements_shifted, ElementBuf};

#[cfg(test)]
mod tests {
    //! Cross-algorithm equivalence tests — the paper's §VI premise that
    //! sequential and parallel methods are algebraically identical, plus
    //! exact brute-force oracles at small T.

    use super::*;
    use crate::hmm::{gilbert_elliott, sample, GeParams, Hmm};
    use crate::linalg::Mat;
    use crate::proptestx::{gen, Runner};
    use crate::rng::Xoshiro256StarStar;
    use crate::scan::ScanOptions;

    fn random_hmm(r: &mut Xoshiro256StarStar, d: usize, m: usize) -> Hmm {
        let pi = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
        let mut obs = Mat::zeros(d, m);
        for row in 0..d {
            let mut vals: Vec<f64> = (0..m).map(|_| r.uniform(0.05, 1.0)).collect();
            let s: f64 = vals.iter().sum();
            vals.iter_mut().for_each(|v| *v /= s);
            for (c, v) in vals.into_iter().enumerate() {
                obs[(row, c)] = v;
            }
        }
        Hmm::new(pi, obs, gen::prob_vector(r, d)).unwrap()
    }

    /// Exact marginals + log Z by enumerating all D^T sequences.
    fn brute_force_marginals(hmm: &Hmm, ys: &[u32]) -> (Vec<Vec<f64>>, f64) {
        let d = hmm.num_states();
        let t = ys.len();
        let mut marg = vec![vec![0.0; d]; t];
        let mut z = 0.0;
        let mut seq = vec![0usize; t];
        loop {
            let mut p = hmm.prior()[seq[0]] * hmm.emission()[(seq[0], ys[0] as usize)];
            for k in 1..t {
                p *= hmm.transition()[(seq[k - 1], seq[k])]
                    * hmm.emission()[(seq[k], ys[k] as usize)];
            }
            z += p;
            for k in 0..t {
                marg[k][seq[k]] += p;
            }
            // odometer increment
            let mut k = 0;
            loop {
                seq[k] += 1;
                if seq[k] < d {
                    break;
                }
                seq[k] = 0;
                k += 1;
                if k == t {
                    let m = marg
                        .iter()
                        .map(|row| row.iter().map(|&v| v / z).collect())
                        .collect();
                    return (m, z.ln());
                }
            }
        }
    }

    /// Exact MAP by enumeration.
    fn brute_force_map(hmm: &Hmm, ys: &[u32]) -> (Vec<u32>, f64) {
        let d = hmm.num_states();
        let t = ys.len();
        let mut best = f64::NEG_INFINITY;
        let mut best_seq = vec![0u32; t];
        let mut seq = vec![0usize; t];
        loop {
            let mut p = (hmm.prior()[seq[0]] * hmm.emission()[(seq[0], ys[0] as usize)]).ln();
            for k in 1..t {
                p += (hmm.transition()[(seq[k - 1], seq[k])]
                    * hmm.emission()[(seq[k], ys[k] as usize)])
                    .ln();
            }
            if p > best {
                best = p;
                best_seq = seq.iter().map(|&s| s as u32).collect();
            }
            let mut k = 0;
            loop {
                seq[k] += 1;
                if seq[k] < d {
                    break;
                }
                seq[k] = 0;
                k += 1;
                if k == t {
                    return (best_seq, best);
                }
            }
        }
    }

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn smoothers_match_brute_force() {
        let mut runner = Runner::new("inference-bf-smooth");
        runner.run(10, |r| {
            let d = 2 + r.below(2) as usize;
            let m = 2 + r.below(2) as usize;
            let t = 1 + r.below(6) as usize;
            let hmm = random_hmm(r, d, m);
            let ys = gen::obs_seq(r, m, t);
            let (exact, logz) = brute_force_marginals(&hmm, &ys);
            let opts = ScanOptions::serial();
            for (name, post) in [
                ("sp_seq", sp_seq(&hmm, &ys).unwrap()),
                ("sp_par", sp_par(&hmm, &ys, opts).unwrap()),
                ("bs_seq", bs_seq(&hmm, &ys).unwrap()),
                ("bs_par", bs_par(&hmm, &ys, opts).unwrap()),
            ] {
                assert!(close(post.log_likelihood(), logz, 1e-9), "{name} logZ");
                for k in 0..t {
                    for s in 0..d {
                        assert!(
                            close(post.gamma(k)[s], exact[k][s], 1e-8),
                            "{name} gamma[{k}][{s}]"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn map_estimators_match_brute_force() {
        let mut runner = Runner::new("inference-bf-map");
        runner.run(10, |r| {
            let d = 2 + r.below(2) as usize;
            let t = 1 + r.below(6) as usize;
            let hmm = random_hmm(r, d, 2);
            let ys = gen::obs_seq(r, 2, t);
            let (exact_path, exact_logp) = brute_force_map(&hmm, &ys);
            let opts = ScanOptions::serial();
            for (name, est) in [
                ("viterbi", viterbi(&hmm, &ys).unwrap()),
                ("mp_seq", mp_seq(&hmm, &ys).unwrap()),
                ("mp_par", mp_par(&hmm, &ys, opts).unwrap()),
                ("mp_path_par", mp_path_par(&hmm, &ys, opts).unwrap()),
            ] {
                assert!(close(est.log_prob, exact_logp, 1e-9), "{name} logp");
                assert_eq!(est.path, exact_path, "{name} path");
            }
        });
    }

    #[test]
    fn par_equals_seq_on_ge_long() {
        // The paper's headline equivalence claim (§VI: MAE ≤ 1e-16 class)
        // at realistic lengths, on the exact GE workload.
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(0xC0FFEE);
        for t in [100usize, 1000, 4096] {
            let tr = sample(&hmm, t, &mut rng);
            let ys = &tr.observations;
            let opts = ScanOptions::default();

            let seq = sp_seq(&hmm, ys).unwrap();
            let par = sp_par(&hmm, ys, opts).unwrap();
            let bss = bs_seq(&hmm, ys).unwrap();
            let bsp = bs_par(&hmm, ys, opts).unwrap();
            let mut max_err = 0.0f64;
            for k in 0..t {
                for s in 0..4 {
                    let g = seq.gamma(k)[s];
                    max_err = max_err
                        .max((par.gamma(k)[s] - g).abs())
                        .max((bss.gamma(k)[s] - g).abs())
                        .max((bsp.gamma(k)[s] - g).abs());
                }
            }
            assert!(max_err < 1e-10, "smoother max err {max_err} at T={t}");
            assert!(close(par.log_likelihood(), seq.log_likelihood(), 1e-10));
            assert!(close(bsp.log_likelihood(), seq.log_likelihood(), 1e-10));
            assert!(close(bss.log_likelihood(), seq.log_likelihood(), 1e-10));

            let vit = viterbi(&hmm, ys).unwrap();
            let mps = mp_seq(&hmm, ys).unwrap();
            let mpp = mp_par(&hmm, ys, opts).unwrap();
            assert!(close(mps.log_prob, vit.log_prob, 1e-10));
            assert!(close(mpp.log_prob, vit.log_prob, 1e-10));
            // Paths may differ only at exact ties (paper §IV-A assumes a
            // unique MAP); verify every chosen state attains the per-step
            // optimum.
            assert_paths_map_equivalent(&hmm, ys, &mpp.path, &vit.path);
            assert_paths_map_equivalent(&hmm, ys, &mps.path, &vit.path);
        }
    }

    /// Tie-aware MAP path comparison (see python tests for the rationale:
    /// the GE model develops exactly-tied MAP paths at long T).
    fn assert_paths_map_equivalent(hmm: &Hmm, ys: &[u32], got: &[u32], want: &[u32]) {
        use crate::elements::safe_ln;
        let d = hmm.num_states();
        let t = ys.len();
        // f64 δ_k oracle
        let mut f = vec![vec![0.0; d]; t];
        let mut b = vec![vec![0.0; d]; t];
        for s in 0..d {
            f[0][s] = safe_ln(hmm.prior()[s] * hmm.emission()[(s, ys[0] as usize)]);
        }
        for k in 1..t {
            for s in 0..d {
                let e = safe_ln(hmm.emission()[(s, ys[k] as usize)]);
                f[k][s] = (0..d)
                    .map(|p| f[k - 1][p] + safe_ln(hmm.transition()[(p, s)]))
                    .fold(f64::NEG_INFINITY, f64::max)
                    + e;
            }
        }
        for k in (0..t.saturating_sub(1)).rev() {
            for s in 0..d {
                b[k][s] = (0..d)
                    .map(|n| {
                        safe_ln(hmm.transition()[(s, n)])
                            + safe_ln(hmm.emission()[(n, ys[k + 1] as usize)])
                            + b[k + 1][n]
                    })
                    .fold(f64::NEG_INFINITY, f64::max);
            }
        }
        for k in 0..t {
            let delta: Vec<f64> = (0..d).map(|s| f[k][s] + b[k][s]).collect();
            let dmax = delta.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v));
            assert!(
                delta[got[k] as usize] > dmax - 1e-6,
                "step {k}: state {} not on an optimal path",
                got[k]
            );
            if got[k] != want[k] {
                // mismatch allowed only under a tie
                let mut sorted = delta.clone();
                sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
                assert!(
                    sorted[0] - sorted[1] < 1e-6,
                    "non-tied path mismatch at {k}"
                );
            }
        }
    }

    #[test]
    fn par_equals_seq_random_models() {
        let mut runner = Runner::new("inference-par-seq-random");
        runner.run(8, |r| {
            let d = 2 + r.below(6) as usize;
            let m = 2 + r.below(4) as usize;
            let t = 10 + r.below(200) as usize;
            let hmm = random_hmm(r, d, m);
            let ys = gen::obs_seq(r, m, t);
            let opts = ScanOptions { threads: 4, min_parallel_work: 8, ..ScanOptions::default() };

            let seq = sp_seq(&hmm, &ys).unwrap();
            let par = sp_par(&hmm, &ys, opts).unwrap();
            for k in 0..t {
                for s in 0..d {
                    assert!(close(par.gamma(k)[s], seq.gamma(k)[s], 1e-9));
                }
            }
            let vit = viterbi(&hmm, &ys).unwrap();
            let mpp = mp_par(&hmm, &ys, opts).unwrap();
            assert!(close(mpp.log_prob, vit.log_prob, 1e-9));
            assert_paths_map_equivalent(&hmm, &ys, &mpp.path, &vit.path);
        });
    }

    #[test]
    fn path_based_matches_max_product() {
        let mut runner = Runner::new("inference-pathpar");
        runner.run(6, |r| {
            let d = 2 + r.below(3) as usize;
            let t = 2 + r.below(40) as usize;
            let hmm = random_hmm(r, d, 2);
            let ys = gen::obs_seq(r, 2, t);
            let opts = ScanOptions::serial();
            let a = mp_path_par(&hmm, &ys, opts).unwrap();
            let b = viterbi(&hmm, &ys).unwrap();
            assert!(close(a.log_prob, b.log_prob, 1e-9));
            assert_paths_map_equivalent(&hmm, &ys, &a.path, &b.path);
        });
    }

    #[test]
    fn errors_on_bad_input() {
        let hmm = gilbert_elliott(GeParams::default());
        assert!(sp_seq(&hmm, &[]).is_err());
        assert!(sp_par(&hmm, &[], ScanOptions::serial()).is_err());
        assert!(viterbi(&hmm, &[7]).is_err()); // symbol out of range
        assert!(mp_par(&hmm, &[0, 5], ScanOptions::serial()).is_err());
    }

    #[test]
    fn single_step_sequences() {
        let hmm = gilbert_elliott(GeParams::default());
        let opts = ScanOptions::serial();
        let ys = vec![1u32];
        let seq = sp_seq(&hmm, &ys).unwrap();
        let par = sp_par(&hmm, &ys, opts).unwrap();
        for s in 0..4 {
            assert!(close(par.gamma(0)[s], seq.gamma(0)[s], 1e-12));
        }
        let vit = viterbi(&hmm, &ys).unwrap();
        let mpp = mp_par(&hmm, &ys, opts).unwrap();
        assert_eq!(vit.path, mpp.path);
    }
}

//! Sum-product smoothers: the classical two-filter algorithm
//! (Algorithm 1 + Eq. 22) and its parallel-scan version (Algorithm 3).

use crate::elements::{sp_element_chain_into, sp_terminal, SpElement, SpOp};
use crate::error::Result;
use crate::hmm::Hmm;
use crate::linalg::normalize_sum;
use crate::scan::{run_scan, run_scan_rev, ScanOptions};

use super::types::Posterior;
use super::workspace::{copy_elements, copy_elements_shifted, Workspace};

/// SP-Seq — classical sum-product (Algorithm 1): forward α and backward
/// β recursions with per-step rescaling, marginals via Eq. (22).
/// O(D²T) work and span.
pub fn sp_seq(hmm: &Hmm, ys: &[u32]) -> Result<Posterior> {
    hmm.check_observations(ys)?;
    let d = hmm.num_states();
    let t = ys.len();
    let pi = hmm.transition();

    // Forward pass: α_k ∝ ψ^f_{1,k}, rescaled to sum 1; log Z accumulates.
    let mut alphas = vec![0.0f64; t * d];
    let mut loglik = 0.0;
    {
        let e = hmm.emission_col(ys[0]);
        let a = &mut alphas[0..d];
        for s in 0..d {
            a[s] = hmm.prior()[s] * e[s];
        }
        loglik += normalize_sum(a).max(f64::MIN_POSITIVE).ln();
    }
    for k in 1..t {
        let e = hmm.emission_col(ys[k]);
        let (prev, cur) = alphas.split_at_mut(k * d);
        let prev = &prev[(k - 1) * d..];
        let cur = &mut cur[..d];
        for (j, c) in cur.iter_mut().enumerate() {
            let mut acc = 0.0;
            for (i, &p) in prev.iter().enumerate() {
                acc += p * pi[(i, j)];
            }
            *c = acc * e[j];
        }
        loglik += normalize_sum(cur).max(f64::MIN_POSITIVE).ln();
    }

    // Backward pass: β_k ∝ ψ^b_{k,T}, rescaled (scales cancel in Eq. 22).
    let mut beta = vec![1.0f64; d];
    let mut gamma = vec![0.0f64; t * d];
    for k in (0..t).rev() {
        let g = &mut gamma[k * d..(k + 1) * d];
        let a = &alphas[k * d..(k + 1) * d];
        for s in 0..d {
            g[s] = a[s] * beta[s];
        }
        normalize_sum(g);
        if k > 0 {
            let e = hmm.emission_col(ys[k]);
            let mut next = vec![0.0f64; d];
            for (i, n) in next.iter_mut().enumerate() {
                let mut acc = 0.0;
                for j in 0..d {
                    acc += pi[(i, j)] * e[j] * beta[j];
                }
                *n = acc;
            }
            normalize_sum(&mut next);
            beta = next;
        }
    }

    Ok(Posterior::new(d, gamma, loglik))
}

/// SP-Par — parallel sum-product (Algorithm 3): forward parallel scan
/// for ψ^f, reversed parallel scan for ψ^b, marginals via Eq. (22).
/// O(D³ log T) span, O(D³ T) work.
///
/// Thin wrapper over [`sp_par_ws`] with a throwaway workspace; the
/// serving hot path goes through `engine::Engine`, which reuses one.
pub fn sp_par(hmm: &Hmm, ys: &[u32], opts: ScanOptions) -> Result<Posterior> {
    sp_par_ws(hmm, ys, opts, &mut Workspace::default())
}

/// [`sp_par`] with caller-owned scratch: the element chain and both scan
/// buffers are overwritten in place across calls (identical results,
/// zero per-call D×D allocations once warm).
pub fn sp_par_ws(
    hmm: &Hmm,
    ys: &[u32],
    opts: ScanOptions,
    ws: &mut Workspace,
) -> Result<Posterior> {
    hmm.check_observations(ys)?;
    let d = hmm.num_states();
    let op = SpOp { d };

    // Algorithm 3 lines 1-4: initialize elements; forward scan.
    let elems = &mut ws.sp.elems;
    sp_element_chain_into(hmm, ys, elems);
    let fwd = &mut ws.sp.fwd;
    copy_elements(elems.as_slice(), fwd);
    run_scan(&op, fwd.as_mut_slice(), opts);

    // Lines 5-8: backward elements are ψ_{k,k+1} for k = 1..T, i.e. the
    // interior elements shifted by one plus the terminal all-ones
    // element; reversed scan yields a_{k:T+1} = ψ^b.
    let bwd = &mut ws.sp.bwd;
    copy_elements_shifted(elems.as_slice(), sp_terminal(d), bwd);
    run_scan_rev(&op, bwd.as_mut_slice(), opts);

    // Lines 9-11 (Eq. 22).
    Ok(sp_posterior_from_scans(d, fwd, bwd))
}

/// Eq. (22) finalization, shared by [`sp_par_ws`] and the streaming
/// `engine::Session`: p(x_k) ∝ ψ^f(x_k) ψ^b(x_k). The forward element
/// has identical rows (prior broadcast) — read row 0; the backward
/// element has identical columns — read column 0. The log scales cancel
/// in the per-step normalization; the log-likelihood is read off the
/// last forward element.
pub(crate) fn sp_posterior_from_scans(
    d: usize,
    fwd: &[SpElement],
    bwd: &[SpElement],
) -> Posterior {
    let t = fwd.len();
    debug_assert_eq!(t, bwd.len());
    let mut gamma = vec![0.0f64; t * d];
    for k in 0..t {
        let g = &mut gamma[k * d..(k + 1) * d];
        let frow = fwd[k].mat.row(0);
        for s in 0..d {
            g[s] = frow[s] * bwd[k].mat[(s, 0)];
        }
        normalize_sum(g);
    }

    let last = &fwd[t - 1];
    let loglik =
        last.log_scale + last.mat.row(0).iter().sum::<f64>().max(f64::MIN_POSITIVE).ln();
    Posterior::new(d, gamma, loglik)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::{gilbert_elliott, GeParams};

    #[test]
    fn uniform_emissions_give_prior_marginal_at_start() {
        // With uninformative emissions the k=1 smoothed marginal equals
        // the prior pushed through nothing — i.e. the prior itself for a
        // doubly-stochastic transition matrix.
        let hmm = crate::hmm::Hmm::new(
            crate::linalg::Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]),
            crate::linalg::Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]),
            vec![0.3, 0.7],
        )
        .unwrap();
        let post = sp_seq(&hmm, &[0, 1, 0]).unwrap();
        assert!((post.gamma(0)[0] - 0.3).abs() < 1e-12);
        assert!((post.gamma(0)[1] - 0.7).abs() < 1e-12);
        let par = sp_par(&hmm, &[0, 1, 0], ScanOptions::serial()).unwrap();
        assert!((par.gamma(0)[0] - 0.3).abs() < 1e-12);
    }

    #[test]
    fn loglik_decreases_with_unlikely_observations() {
        let hmm = gilbert_elliott(GeParams::default());
        // all-zeros is a typical sequence; rapid alternation is less
        // likely under sticky dynamics.
        let steady = sp_seq(&hmm, &vec![0; 64]).unwrap();
        let alt: Vec<u32> = (0..64).map(|i| (i % 2) as u32).collect();
        let jumpy = sp_seq(&hmm, &alt).unwrap();
        assert!(steady.log_likelihood() > jumpy.log_likelihood());
    }

    #[test]
    fn marginals_are_distributions() {
        let hmm = gilbert_elliott(GeParams::default());
        let ys: Vec<u32> = (0..333).map(|i| ((i / 7) % 2) as u32).collect();
        for post in [
            sp_seq(&hmm, &ys).unwrap(),
            sp_par(&hmm, &ys, ScanOptions::default()).unwrap(),
        ] {
            for k in 0..ys.len() {
                let s: f64 = post.gamma(k).iter().sum();
                assert!((s - 1.0).abs() < 1e-9);
                assert!(post.gamma(k).iter().all(|&v| (0.0..=1.0).contains(&v)));
            }
        }
    }
}

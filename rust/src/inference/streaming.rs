//! Fixed-lag streaming inference cores — the math behind
//! `engine::Session::smoothed_lag` / `map_lag`.
//!
//! A session's `scan::CheckpointedScan` supplies forward prefixes over
//! the suffix window covering the last L steps (cost O(L + B), B the
//! checkpoint block). These helpers build the *backward* suffix-scan
//! input from the cached per-symbol element prototypes and finalize
//! marginals / MAP states over the window only — so a fixed-lag query
//! after an append costs O(L + B) combines instead of the full
//! smoother's O(T).
//!
//! The window marginal is exact fixed-lag smoothing: p(x_k | y_{1:t})
//! for k in the window, conditioning on *all* observations so far — the
//! backward values are genuine suffix products ψ^b_{k,t}, identical in
//! form to the full smoother's (Eq. 22 / Eq. 40 restricted to the
//! window).

use crate::elements::{MpElement, SpElement};
use crate::linalg::{argmax, normalize_sum};

use super::types::Posterior;
use super::workspace::ElementBuf;

/// dst ← [protos[ys[0]], …, protos[ys[n-1]], terminal] — the backward
/// suffix-scan input for a window starting at absolute step `start`:
/// the interior elements for steps start+1..t plus the terminal element.
/// Overwrites in place when shapes match (the session hot path).
pub(crate) fn window_chain_into<E: ElementBuf>(
    protos: &[E],
    ys: &[u32],
    terminal: E,
    dst: &mut Vec<E>,
) {
    let n = ys.len() + 1;
    let key = terminal.shape_key();
    if dst.len() == n && dst.first().map_or(false, |e| e.shape_key() == key) {
        for (d, &y) in dst[..n - 1].iter_mut().zip(ys) {
            d.overwrite_from(&protos[y as usize]);
        }
        dst[n - 1].overwrite_from(&terminal);
    } else {
        dst.clear();
        dst.reserve(n);
        dst.extend(ys.iter().map(|&y| protos[y as usize].clone()));
        dst.push(terminal);
    }
}

/// Fixed-lag Eq. (22): marginals for absolute steps `start..start+n`
/// (n = `bwd_win.len()`), where `fwd_win[i]` is the forward prefix at
/// absolute index `fwd_offset + i` and `bwd_win[j]` the backward suffix
/// value at absolute step `start + j`. The returned log-likelihood is
/// that of the *full* prefix — read off the window's last forward
/// element, which is the running total.
pub(crate) fn sp_window_posterior(
    d: usize,
    start: usize,
    fwd_offset: usize,
    fwd_win: &[SpElement],
    bwd_win: &[SpElement],
) -> Posterior {
    let n = bwd_win.len();
    debug_assert!(start >= fwd_offset && start - fwd_offset + n == fwd_win.len());
    let mut gamma = vec![0.0f64; n * d];
    for (j, b) in bwd_win.iter().enumerate() {
        let frow = fwd_win[start + j - fwd_offset].mat.row(0);
        let g = &mut gamma[j * d..(j + 1) * d];
        for s in 0..d {
            g[s] = frow[s] * b.mat[(s, 0)];
        }
        normalize_sum(g);
    }
    let last = fwd_win.last().expect("non-empty window");
    let loglik =
        last.log_scale + last.mat.row(0).iter().sum::<f64>().max(f64::MIN_POSITIVE).ln();
    Posterior::new(d, gamma, loglik)
}

/// Fixed-lag Eq. (40): MAP states for absolute steps `start..start+n`
/// under the observations so far, plus the joint forward log-maximum at
/// the current step (indexing as [`sp_window_posterior`]).
pub(crate) fn mp_window_path(
    d: usize,
    start: usize,
    fwd_offset: usize,
    fwd_win: &[MpElement],
    bwd_win: &[MpElement],
) -> (Vec<u32>, f64) {
    let n = bwd_win.len();
    debug_assert!(start >= fwd_offset && start - fwd_offset + n == fwd_win.len());
    let mut path = vec![0u32; n];
    for (j, b) in bwd_win.iter().enumerate() {
        let frow = fwd_win[start + j - fwd_offset].mat.row(0);
        let delta: Vec<f64> = (0..d).map(|s| frow[s] + b.mat[(s, 0)]).collect();
        path[j] = argmax(&delta) as u32;
    }
    let last = fwd_win.last().expect("non-empty window");
    let log_prob = last
        .mat
        .row(0)
        .iter()
        .fold(f64::NEG_INFINITY, |m, &v| m.max(v));
    (path, log_prob)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::{
        sp_element_chain, sp_element_protos, sp_terminal, SpOp,
    };
    use crate::hmm::{gilbert_elliott, sample, GeParams};
    use crate::inference::sp_par;
    use crate::rng::Xoshiro256StarStar;
    use crate::scan::{run_scan_rev, CheckpointedScan, ScanOptions};

    #[test]
    fn window_posterior_matches_full_smoother() {
        let hmm = gilbert_elliott(GeParams::default());
        let d = hmm.num_states();
        let mut rng = Xoshiro256StarStar::seed_from_u64(0x51AE);
        let ys = sample(&hmm, 150, &mut rng).observations;
        let opts = ScanOptions::serial();
        let full = sp_par(&hmm, &ys, opts).unwrap();

        let block = 16usize;
        let mut ck = CheckpointedScan::new(SpOp { d }, block);
        ck.extend(sp_element_chain(&hmm, &ys));
        let protos = sp_element_protos(&hmm);

        for lag in [1usize, 7, 40, 150, 400] {
            let t = ys.len();
            let start = t.saturating_sub(lag);
            let mut fwd_win = Vec::new();
            let fwd_offset = ck.suffix_into(start, &mut fwd_win);
            let mut bwd_win = Vec::new();
            window_chain_into(
                &protos,
                &ys[start + 1..],
                sp_terminal(d),
                &mut bwd_win,
            );
            run_scan_rev(&SpOp { d }, &mut bwd_win, opts);
            let win =
                sp_window_posterior(d, start, fwd_offset, &fwd_win, &bwd_win);
            assert_eq!(win.len(), t - start, "lag={lag}");
            for j in 0..win.len() {
                for s in 0..d {
                    let got = win.gamma(j)[s];
                    let want = full.gamma(start + j)[s];
                    assert!(
                        (got - want).abs() < 1e-10,
                        "lag={lag} k={} s={s}: {got} vs {want}",
                        start + j
                    );
                }
            }
            assert!(
                (win.log_likelihood() - full.log_likelihood()).abs() < 1e-9,
                "lag={lag} loglik"
            );
        }
    }

    #[test]
    fn window_chain_reuse_is_identical() {
        let hmm = gilbert_elliott(GeParams::default());
        let d = hmm.num_states();
        let protos = sp_element_protos(&hmm);
        let ys = vec![0u32, 1, 1, 0];
        let mut a = Vec::new();
        window_chain_into(&protos, &ys, sp_terminal(d), &mut a);
        assert_eq!(a.len(), 5);
        let mut b = a.clone();
        window_chain_into(&protos, &ys, sp_terminal(d), &mut b); // in-place
        assert_eq!(a, b);
        let mut expected: Vec<_> =
            ys.iter().map(|&y| protos[y as usize].clone()).collect();
        expected.push(sp_terminal(d));
        assert_eq!(a, expected);
    }
}

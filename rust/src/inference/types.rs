//! Common result containers for the inference algorithms.

/// Smoothing posterior: p(x_k | y_{1:T}) for every k, plus log p(y_{1:T}).
#[derive(Debug, Clone, PartialEq)]
pub struct Posterior {
    d: usize,
    gamma: Vec<f64>, // row-major (T, D)
    loglik: f64,
}

impl Posterior {
    /// Wrap a row-major (T, D) marginal buffer and its log-likelihood.
    pub fn new(d: usize, gamma: Vec<f64>, loglik: f64) -> Self {
        assert!(d > 0 && gamma.len() % d == 0, "gamma shape");
        Self { d, gamma, loglik }
    }

    /// Sequence length T.
    pub fn len(&self) -> usize {
        self.gamma.len() / self.d
    }

    /// Whether the posterior covers zero steps.
    pub fn is_empty(&self) -> bool {
        self.gamma.is_empty()
    }

    /// Number of states D.
    pub fn num_states(&self) -> usize {
        self.d
    }

    /// Marginal distribution at step `k` (slice of length D, sums to 1).
    pub fn gamma(&self, k: usize) -> &[f64] {
        &self.gamma[k * self.d..(k + 1) * self.d]
    }

    /// Flat (T·D) marginal buffer.
    pub fn gamma_flat(&self) -> &[f64] {
        &self.gamma
    }

    /// log p(y_{1:T}).
    pub fn log_likelihood(&self) -> f64 {
        self.loglik
    }

    /// Pointwise MAP of the marginals (the smoothed state estimate).
    pub fn marginal_map(&self) -> Vec<u32> {
        (0..self.len())
            .map(|k| crate::linalg::argmax(self.gamma(k)) as u32)
            .collect()
    }
}

/// MAP (Viterbi) estimate: the most likely state sequence and its joint
/// log probability log p(x*_{1:T}, y_{1:T}).
#[derive(Debug, Clone, PartialEq)]
pub struct MapEstimate {
    /// The most likely state sequence x*_{1:T}.
    pub path: Vec<u32>,
    /// Joint log probability log p(x*_{1:T}, y_{1:T}).
    pub log_prob: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn posterior_accessors() {
        let p = Posterior::new(2, vec![0.3, 0.7, 0.9, 0.1, 0.5, 0.5], -1.0);
        assert_eq!(p.len(), 3);
        assert_eq!(p.num_states(), 2);
        assert_eq!(p.gamma(1), &[0.9, 0.1]);
        assert_eq!(p.log_likelihood(), -1.0);
        assert_eq!(p.marginal_map(), vec![1, 0, 0]);
        assert!(!p.is_empty());
    }

    #[test]
    #[should_panic]
    fn posterior_rejects_bad_shape() {
        Posterior::new(2, vec![0.1; 5], 0.0);
    }
}

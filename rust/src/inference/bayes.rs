//! Bayesian smoothers: sequential forward filter + RTS backward pass,
//! and the parallel version of Särkkä & García-Fernández [30] (discrete
//! analogue). The paper benchmarks these as BS-Seq / BS-Par alongside
//! the potential-based SP methods — the two differ in backward-pass
//! structure (RTS vs two-filter), not in results.

use crate::elements::{bs_element_chain_into, BsElement, BsFilterOp, TINY};
use crate::error::Result;
use crate::hmm::Hmm;
use crate::linalg::{normalize_sum, Mat};
use crate::scan::{run_scan, run_scan_rev, AssocOp, ScanOptions};
use crate::semiring::Prob;

use super::types::Posterior;
use super::workspace::Workspace;

/// BS-Seq — forward filter + Rauch–Tung–Striebel backward recursion.
/// O(D²T) work and span.
pub fn bs_seq(hmm: &Hmm, ys: &[u32]) -> Result<Posterior> {
    hmm.check_observations(ys)?;
    let d = hmm.num_states();
    let t = ys.len();
    let pi = hmm.transition();

    // Forward filter p(x_k | y_{1:k}).
    let mut filtered = vec![0.0f64; t * d];
    let mut loglik = 0.0;
    {
        let e = hmm.emission_col(ys[0]);
        let f = &mut filtered[0..d];
        for s in 0..d {
            f[s] = hmm.prior()[s] * e[s];
        }
        loglik += normalize_sum(f).max(f64::MIN_POSITIVE).ln();
    }
    for k in 1..t {
        let e = hmm.emission_col(ys[k]);
        let (prev, cur) = filtered.split_at_mut(k * d);
        let prev = &prev[(k - 1) * d..];
        let cur = &mut cur[..d];
        for (j, c) in cur.iter_mut().enumerate() {
            let mut acc = 0.0;
            for (i, &p) in prev.iter().enumerate() {
                acc += p * pi[(i, j)];
            }
            *c = acc * e[j];
        }
        loglik += normalize_sum(cur).max(f64::MIN_POSITIVE).ln();
    }

    // RTS backward: γ_k = f_k ∘ Π (γ_{k+1} ⊘ pred_{k+1}).
    let mut gamma = vec![0.0f64; t * d];
    gamma[(t - 1) * d..].copy_from_slice(&filtered[(t - 1) * d..]);
    for k in (0..t - 1).rev() {
        let f = &filtered[k * d..(k + 1) * d];
        // pred_{k+1}[j] = Σ_i f_k[i] Π[i,j]
        let mut pred = vec![0.0f64; d];
        for (j, p) in pred.iter_mut().enumerate() {
            for (i, &fi) in f.iter().enumerate() {
                *p += fi * pi[(i, j)];
            }
        }
        let ratio: Vec<f64> = (0..d)
            .map(|j| gamma[(k + 1) * d + j] / pred[j].max(TINY))
            .collect();
        let g = &mut gamma[k * d..(k + 1) * d];
        for (i, gi) in g.iter_mut().enumerate() {
            let mut acc = 0.0;
            for (j, &rj) in ratio.iter().enumerate() {
                acc += pi[(i, j)] * rj;
            }
            *gi = f[i] * acc;
        }
        normalize_sum(g);
    }

    Ok(Posterior::new(d, gamma, loglik))
}

/// Backward RTS conditional composition: the elements are the matrices
/// S_k[m, i] = p(x_k = i | x_{k+1} = m, y_{1:k}) and composition is
/// R_k = R_{k+1} · S_k (descending matrix product). With the ascending
/// suffix-scan convention (out[k] = a_k ⊗ … ⊗ a_{T-1}) the operator is
/// therefore the *flipped* row-normalized product.
struct RtsOp {
    d: usize,
}

impl AssocOp<Mat> for RtsOp {
    fn identity(&self) -> Mat {
        Mat::identity::<Prob>(self.d)
    }
    fn combine(&self, a: &Mat, b: &Mat) -> Mat {
        // later (higher-index) element `b` composes on the left
        let mut m = b.matmul::<Prob>(a);
        for r in 0..self.d {
            let row = &mut m.data_mut()[r * self.d..(r + 1) * self.d];
            normalize_sum(row);
        }
        m
    }
}

/// BS-Par — parallel Bayesian smoother [30]:
/// 1. parallel scan of filtering elements (f, ĝ, γ) → p(x_k | y_{1:k});
/// 2. reversed parallel scan of RTS conditionals → p(x_k | y_{1:T}).
///
/// O(D³ log T) span, O(D³ T) work.
///
/// Thin wrapper over [`bs_par_ws`] with a throwaway workspace; the
/// serving hot path goes through `engine::Engine`, which reuses one.
pub fn bs_par(hmm: &Hmm, ys: &[u32], opts: ScanOptions) -> Result<Posterior> {
    bs_par_ws(hmm, ys, opts, &mut Workspace::default())
}

/// [`bs_par`] with caller-owned scratch (see `inference::workspace`).
pub fn bs_par_ws(
    hmm: &Hmm,
    ys: &[u32],
    opts: ScanOptions,
    ws: &mut Workspace,
) -> Result<Posterior> {
    hmm.check_observations(ys)?;

    // Forward: filtering-element scan (scanned in place — the chain is
    // rebuilt into the same buffer on the next call).
    let op = BsFilterOp { d: hmm.num_states() };
    let fwd = &mut ws.bs.elems;
    bs_element_chain_into(hmm, ys, fwd);
    run_scan(&op, fwd.as_mut_slice(), opts);
    Ok(bs_posterior_from_forward(hmm, fwd, opts, &mut ws.bs.rts))
}

/// The BS-Par backward pass over an *already-scanned* forward element
/// chain (`fwd[k]` = a_{0:k+1}): filtered marginals → RTS conditional
/// suffix scan → smoothed posterior. Shared by [`bs_par_ws`] and the
/// streaming session's Bayes `finish` path (which materializes `fwd`
/// from its checkpoints), so the two cannot diverge. `fwd` must be
/// non-empty.
pub(crate) fn bs_posterior_from_forward(
    hmm: &Hmm,
    fwd: &[BsElement],
    opts: ScanOptions,
    suffix: &mut Vec<Mat>,
) -> Posterior {
    let d = hmm.num_states();
    let t = fwd.len();
    // After absorbing the first element the conditional rows coincide:
    // row 0 of f is p(x_k | y_{1:k}).
    let filtered: Vec<&[f64]> = fwd.iter().map(|e| e.f.row(0)).collect();

    // log p(y_{1:T}) from the full-interval element: g_full(x_0) is
    // constant in x_0 = p(y_{1:T}).
    let last = &fwd[t - 1];
    let loglik = last.log_scale + last.g[0].max(TINY).ln();

    // Backward: RTS conditionals S_k from filtered marginals, composed
    // by a reversed scan; smoothed_k = filtered_{T-1} · R_k.
    let pi = hmm.transition();
    if suffix.len() != t
        || suffix.first().map_or(true, |m| m.rows() != d || m.cols() != d)
    {
        suffix.clear();
        suffix.resize(t, Mat::zeros(d, d));
    }
    for k in 0..t - 1 {
        let f = filtered[k];
        let s = &mut suffix[k];
        for m in 0..d {
            let mut total = 0.0;
            for i in 0..d {
                let w = f[i] * pi[(i, m)];
                s[(m, i)] = w;
                total += w;
            }
            let total = total.max(TINY);
            for i in 0..d {
                s[(m, i)] /= total;
            }
        }
    }
    {
        // Terminal R_{T-1} = I, written in place.
        let term = &mut suffix[t - 1];
        for r in 0..d {
            for c in 0..d {
                term[(r, c)] = if r == c { 1.0 } else { 0.0 };
            }
        }
    }

    let rts = RtsOp { d };
    let f_last: Vec<f64> = filtered[t - 1].to_vec();
    run_scan_rev(&rts, suffix.as_mut_slice(), opts);

    let mut gamma = vec![0.0f64; t * d];
    for k in 0..t {
        let g = &mut gamma[k * d..(k + 1) * d];
        let r = &suffix[k];
        for (i, gi) in g.iter_mut().enumerate() {
            let mut acc = 0.0;
            for (m, &fm) in f_last.iter().enumerate() {
                acc += fm * r[(m, i)];
            }
            *gi = acc;
        }
        normalize_sum(g);
    }

    Posterior::new(d, gamma, loglik)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::{gilbert_elliott, GeParams};

    #[test]
    fn last_marginal_equals_filtered() {
        // RTS smoothing leaves the terminal filtered marginal unchanged.
        let hmm = gilbert_elliott(GeParams::default());
        let ys = vec![0, 1, 1, 0, 0, 1];
        let post = bs_seq(&hmm, &ys).unwrap();
        let par = bs_par(&hmm, &ys, ScanOptions::serial()).unwrap();
        let k = ys.len() - 1;
        for s in 0..4 {
            assert!((post.gamma(k)[s] - par.gamma(k)[s]).abs() < 1e-10);
        }
    }

    #[test]
    fn smoothing_uses_future_information() {
        // With sticky dynamics and an isolated flipped observation, the
        // smoothed marginal at the flip must stay closer to the
        // surrounding regime than the filtered estimate would be.
        let hmm = gilbert_elliott(GeParams::default());
        let mut ys = vec![0u32; 21];
        ys[10] = 1;
        let post = bs_seq(&hmm, &ys).unwrap();
        // bit(x) = 0 for states 0,1 — smoothed belief should still favor
        // bit 0 at the flip given 20 surrounding zeros.
        let p_bit0 = post.gamma(10)[0] + post.gamma(10)[1];
        assert!(p_bit0 > 0.5, "p_bit0 = {p_bit0}");
    }

    #[test]
    fn marginals_are_distributions() {
        let hmm = gilbert_elliott(GeParams::default());
        let ys: Vec<u32> = (0..257).map(|i| ((i / 11) % 2) as u32).collect();
        for post in [
            bs_seq(&hmm, &ys).unwrap(),
            bs_par(&hmm, &ys, ScanOptions::default()).unwrap(),
        ] {
            for k in 0..ys.len() {
                let s: f64 = post.gamma(k).iter().sum();
                assert!((s - 1.0).abs() < 1e-9);
            }
        }
    }
}

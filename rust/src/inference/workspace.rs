//! Reusable scratch workspaces for the parallel-scan algorithms.
//!
//! Every `*_par` smoother/estimator materializes three O(T) vectors of
//! D×D elements per call (the element chain plus its forward and
//! backward scan copies). On the serving hot path those allocations
//! dominate small-request latency, so the `engine` keeps one
//! [`Workspace`] per [`Engine`](crate::engine::Engine) and the
//! workspace-aware entry points (`sp_par_ws`, `mp_par_ws`, `bs_par_ws`)
//! overwrite the buffers in place when shapes match.
//!
//! Reuse never changes results: the in-place writers perform the exact
//! same floating-point operations as the allocating builders (asserted
//! bit-for-bit by `engine::tests::workspace_reuse_is_deterministic`).

use crate::elements::{BsElement, MpElement, SpElement};
use crate::linalg::Mat;

// The in-place-overwrite capability the copy helpers below build on
// lives in `scan` (its `CheckpointedScan::suffix_into` shares it); the
// element-type impls live in `elements`.
pub(crate) use crate::scan::ElementBuf;

/// Scratch buffers for the sum-product family (`sp_par`).
#[derive(Debug, Default)]
pub struct SpBuffers {
    /// Element chain built from the observations.
    pub elems: Vec<SpElement>,
    /// Forward prefix-scan values.
    pub fwd: Vec<SpElement>,
    /// Backward suffix-scan values.
    pub bwd: Vec<SpElement>,
}

/// Scratch buffers for the max-product family (`mp_par`).
#[derive(Debug, Default)]
pub struct MpBuffers {
    /// Element chain built from the observations.
    pub elems: Vec<MpElement>,
    /// Forward prefix-scan values.
    pub fwd: Vec<MpElement>,
    /// Backward suffix-scan values.
    pub bwd: Vec<MpElement>,
}

/// Scratch buffers for the Bayesian-smoother family (`bs_par`).
#[derive(Debug, Default)]
pub struct BsBuffers {
    /// Element chain built from the observations.
    pub elems: Vec<BsElement>,
    /// RTS backward-pass smoothing gains.
    pub rts: Vec<Mat>,
}

/// Scratch for the streaming session's suffix windows (`smoothed_lag` /
/// `map_lag`): the forward prefix values over the checkpoint-covering
/// window and the backward suffix-scan input.
#[derive(Debug, Default)]
pub struct StreamBuffers {
    /// Sum-product forward values over the covering window.
    pub sp_fwd_win: Vec<SpElement>,
    /// Sum-product backward suffix-scan input/output.
    pub sp_bwd_win: Vec<SpElement>,
    /// Max-product forward values over the covering window.
    pub mp_fwd_win: Vec<MpElement>,
    /// Max-product backward suffix-scan input/output.
    pub mp_bwd_win: Vec<MpElement>,
}

/// Workspace growth policy for window buffers: growth is left to the
/// allocator (amortized doubling), and capacity is released only once it
/// exceeds [`SHRINK_FACTOR`] × the live need — so a one-off wide
/// `smoothed_lag` window doesn't pin its memory for the session's
/// remaining lifetime, while steady-state appends never reallocate.
pub(crate) const SHRINK_FACTOR: usize = 4;

/// Apply the policy before refilling `buf` to `need` elements.
pub(crate) fn apply_growth_policy<E>(buf: &mut Vec<E>, need: usize) {
    if buf.capacity() > SHRINK_FACTOR * need.max(1) {
        buf.truncate(need);
        buf.shrink_to(need);
    }
}

/// Per-engine scratch: one buffer set per algorithm family, grown on
/// first use and overwritten in place afterwards.
#[derive(Debug, Default)]
pub struct Workspace {
    /// Sum-product scratch.
    pub sp: SpBuffers,
    /// Max-product scratch.
    pub mp: MpBuffers,
    /// Bayesian-smoother scratch.
    pub bs: BsBuffers,
    /// Streaming fixed-lag window scratch.
    pub stream: StreamBuffers,
}

fn reusable<E: ElementBuf>(src_len: usize, src_key: (usize, usize), dst: &[E]) -> bool {
    dst.len() == src_len && dst.first().map_or(src_len == 0, |e| e.shape_key() == src_key)
}

/// `dst ← src`, overwriting in place when shapes match.
pub(crate) fn copy_elements<E: ElementBuf>(src: &[E], dst: &mut Vec<E>) {
    let key = src.first().map_or((0, 0), |e| e.shape_key());
    if reusable(src.len(), key, dst) {
        for (d, s) in dst.iter_mut().zip(src) {
            d.overwrite_from(s);
        }
    } else {
        dst.clear();
        dst.extend(src.iter().cloned());
    }
}

/// `dst ← src[1..] ++ [terminal]` (the backward-scan input: interior
/// elements shifted by one plus the terminal element), overwriting in
/// place when shapes match. `src` must be non-empty.
pub(crate) fn copy_elements_shifted<E: ElementBuf>(
    src: &[E],
    terminal: E,
    dst: &mut Vec<E>,
) {
    let n = src.len();
    debug_assert!(n > 0, "shifted copy of an empty chain");
    let key = src.first().map_or((0, 0), |e| e.shape_key());
    if reusable(n, key, dst) {
        for (d, s) in dst[..n - 1].iter_mut().zip(&src[1..]) {
            d.overwrite_from(s);
        }
        dst[n - 1].overwrite_from(&terminal);
    } else {
        dst.clear();
        dst.extend(src[1..].iter().cloned());
        dst.push(terminal);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::{sp_element_chain, sp_terminal};
    use crate::hmm::{gilbert_elliott, GeParams};

    #[test]
    fn copy_helpers_match_allocating_path() {
        let hmm = gilbert_elliott(GeParams::default());
        let ys = vec![0u32, 1, 0, 0, 1];
        let src = sp_element_chain(&hmm, &ys);

        let mut dst = Vec::new();
        copy_elements(&src, &mut dst); // allocate path
        assert_eq!(dst, src);
        copy_elements(&src, &mut dst); // reuse path
        assert_eq!(dst, src);

        let mut want: Vec<SpElement> = src[1..].to_vec();
        want.push(sp_terminal(4));
        let mut shifted = Vec::new();
        copy_elements_shifted(&src, sp_terminal(4), &mut shifted);
        assert_eq!(shifted, want);
        copy_elements_shifted(&src, sp_terminal(4), &mut shifted); // reuse
        assert_eq!(shifted, want);

        // Shape change falls back to reallocation.
        let short = sp_element_chain(&hmm, &[1u32, 1]);
        copy_elements(&short, &mut dst);
        assert_eq!(dst, short);
    }
}

//! Work-span parallel-machine simulator — the substitute for the paper's
//! RTX 3090 testbed (see DESIGN.md §4, substitution note).
//!
//! Each algorithm is described as a leveled DAG of primitive operations
//! (element inits, ⊗/∨ combines, per-step finalizations). The simulator
//! schedules the DAG greedily on `p` identical cores and charges
//!
//! ```text
//! time = Σ_levels  [ ceil(ops_level / p) · c_op  +  c_launch ]
//! ```
//!
//! which is Brent's bound `max(work/p, span)` per level plus a fixed
//! kernel-launch latency per level — the two effects that shape the
//! paper's GPU figures: the O(log T) span curve while T·D³ work fits in
//! P cores, and the knee back to linear once it no longer does
//! (observed in Fig. 5 at T ≈ 5·10⁴ on 10496 cores).
//!
//! Per-op costs are calibrated from single-thread CPU measurements of
//! the same primitives (see `bench_harness`), scaled by a configurable
//! CPU→device throughput ratio, so the *shape* and the *ratios* of
//! Figs. 4–6 are meaningful while absolute milliseconds are explicitly
//! out of scope.

/// A primitive operation class with a cost in core-cycles (arbitrary
/// consistent unit; the calibration fixes the unit → seconds map).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Build one element from (Π, e_y): D² fused multiplies.
    ElementInit,
    /// One ⊗ / ∨ combine: a D×D semiring matmul (D³ mul-adds) + rescale.
    Combine,
    /// One per-step finalization (Eq. 22 / Eq. 40): D mul + normalize.
    Finalize,
    /// One step of a sequential recursion: D² mul-adds (vector-matrix).
    SeqStep,
}

/// One level of the DAG: `count` independent tasks, each performing
/// `ops_per_item` dependent ops of one class (a task is what one core
/// executes inside a single launch — e.g. a §V-B block fold is one task
/// of `block` dependent combines).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Level {
    /// Operation class every task in the level executes.
    pub class: OpClass,
    /// Independent tasks in the level.
    pub count: usize,
    /// Dependent ops inside each task.
    pub ops_per_item: usize,
}

/// A leveled DAG — levels execute in order, ops within a level are
/// independent.
#[derive(Debug, Clone, Default)]
pub struct Dag {
    /// The levels, execution order.
    pub levels: Vec<Level>,
}

impl Dag {
    /// Append a level of `count` single-op tasks.
    pub fn push(&mut self, class: OpClass, count: usize) {
        self.push_tasks(class, count, 1);
    }

    /// Append a level of `count` tasks of `ops_per_item` dependent ops.
    pub fn push_tasks(&mut self, class: OpClass, count: usize, ops_per_item: usize) {
        if count > 0 && ops_per_item > 0 {
            self.levels.push(Level { class, count, ops_per_item });
        }
    }

    /// Total work (op-count weighted by per-class cost).
    pub fn work(&self, costs: &CostModel, d: usize) -> f64 {
        self.levels
            .iter()
            .map(|l| (l.count * l.ops_per_item) as f64 * costs.op_cost(l.class, d))
            .sum()
    }

    /// Span (critical path): one task of each level in sequence.
    pub fn span(&self, costs: &CostModel, d: usize) -> f64 {
        self.levels
            .iter()
            .map(|l| l.ops_per_item as f64 * costs.op_cost(l.class, d) + costs.launch_overhead)
            .sum()
    }
}

/// Cost model: per-class per-element costs (seconds) + per-level launch
/// overhead, for a device with `p` cores.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Seconds per scalar multiply-add on one core.
    pub flop_time: f64,
    /// Fixed per-level (kernel launch / barrier) latency in seconds.
    pub launch_overhead: f64,
}

impl CostModel {
    /// Cost of one op of `class` at state-space size `d`, in seconds.
    pub fn op_cost(&self, class: OpClass, d: usize) -> f64 {
        let d = d as f64;
        let flops = match class {
            OpClass::ElementInit => d * d,
            OpClass::Combine => d * d * d + d * d, // matmul + rescale
            OpClass::Finalize => 4.0 * d,
            OpClass::SeqStep => 2.0 * d * d,
        };
        flops * self.flop_time
    }

    /// A CPU-like single-core calibration (no launch overhead).
    pub fn cpu_single_core(flop_time: f64) -> Self {
        Self { flop_time, launch_overhead: 0.0 }
    }
}

/// The simulated device.
#[derive(Debug, Clone, Copy)]
pub struct Device {
    /// Parallel cores available.
    pub cores: usize,
    /// Per-op cost calibration.
    pub cost: CostModel,
}

impl Device {
    /// An RTX-3090-like device: 10496 cores (paper §VI) and a launch
    /// overhead in the ~µs class. `flop_time` should come from
    /// calibration scaled by the CPU→GPU per-core throughput ratio.
    pub fn gpu_3090_like(flop_time: f64) -> Self {
        Self {
            cores: 10_496,
            cost: CostModel { flop_time, launch_overhead: 6.0e-6 },
        }
    }

    /// Default 3090-like device with a bandwidth-calibrated effective
    /// per-flop time: tiny (D ≈ 4) matrix combines are memory-bound, so
    /// the effective rate per "core" is bytes/op ÷ per-core bandwidth
    /// (936 GB/s ÷ 10496 ≈ 89 MB/s → ≈ 2.4 µs per 192-byte combine).
    pub fn gpu_3090_default() -> Self {
        Self::gpu_3090_like(2.5e-8)
    }

    /// A multicore-CPU-like device.
    pub fn cpu_like(cores: usize, flop_time: f64) -> Self {
        Self {
            cores,
            cost: CostModel { flop_time, launch_overhead: 2.0e-7 },
        }
    }

    /// Simulate greedy execution of `dag`: per level,
    /// `ceil(count / cores) · ops_per_item · op_cost + launch_overhead`
    /// (Brent's bound).
    pub fn run(&self, dag: &Dag, d: usize) -> f64 {
        dag.levels
            .iter()
            .map(|l| {
                let rounds = l.count.div_ceil(self.cores) as f64;
                rounds * l.ops_per_item as f64 * self.cost.op_cost(l.class, d)
                    + self.cost.launch_overhead
            })
            .sum()
    }
}

// ===========================================================================
// DAG builders for every benchmarked algorithm
// ===========================================================================

/// Number of up-sweep + down-sweep combine levels and their op counts
/// for a Blelloch scan over `t` elements.
fn scan_levels(dag: &mut Dag, t: usize) {
    if t <= 1 {
        return;
    }
    let levels = usize::BITS as usize - (t - 1).leading_zeros() as usize;
    // up-sweep
    for dlev in 0..levels {
        let stride = 1usize << (dlev + 1);
        dag.push(OpClass::Combine, t.div_ceil(stride));
    }
    // down-sweep
    for dlev in (0..levels).rev() {
        let stride = 1usize << (dlev + 1);
        dag.push(OpClass::Combine, t.div_ceil(stride));
    }
    // final inclusive pass
    dag.push(OpClass::Combine, t);
}

/// SP-Par / BS-Par / MP-Par: init level + two scans + finalize level.
/// (BS element combine cost ≈ SP combine cost at the same D — both are
/// D³; the distinction the figures show comes from constant factors the
/// calibration captures via `flop_time` scaling.)
pub fn dag_parallel_smoother(t: usize) -> Dag {
    let mut dag = Dag::default();
    dag.push(OpClass::ElementInit, t);
    scan_levels(&mut dag, t); // forward
    scan_levels(&mut dag, t); // backward (reversed)
    dag.push(OpClass::Finalize, t);
    dag
}

/// MP-Par: identical level structure to the smoother (the paper finds it
/// faster by constant factors — max-plus has no division/rescale; we
/// charge combine minus the rescale term).
pub fn dag_parallel_maxprod(t: usize) -> Dag {
    // Same structure; cost difference handled by the caller scaling.
    dag_parallel_smoother(t)
}

/// Sequential forward-backward / max-product / filter-smoother:
/// 2T dependent vector-matrix steps.
pub fn dag_sequential(t: usize) -> Dag {
    let mut dag = Dag::default();
    for _ in 0..(2 * t) {
        dag.push(OpClass::SeqStep, 1);
    }
    dag
}

/// Classical Viterbi: T dependent D² steps forward + T O(1) backtrace
/// steps (charged as Finalize).
pub fn dag_viterbi(t: usize) -> Dag {
    let mut dag = Dag::default();
    for _ in 0..t {
        dag.push(OpClass::SeqStep, 1);
    }
    for _ in 0..t {
        dag.push(OpClass::Finalize, 1);
    }
    dag
}

/// Block-wise two-level scan (§V-B) with B = ⌈T/l⌉ blocks: each block
/// fold is a single task of `block` dependent combines (one launch).
pub fn dag_blockwise(t: usize, block: usize) -> Dag {
    let mut dag = Dag::default();
    let block = block.max(1);
    let nb = t.div_ceil(block);
    dag.push(OpClass::ElementInit, t);
    // phase 1: per-block sequential folds, all blocks concurrent
    dag.push_tasks(OpClass::Combine, nb, block);
    // phase 2: leader scan over summaries
    scan_levels(&mut dag, nb);
    // phase 3: per-block rescan (fwd + bwd), then finalize
    dag.push_tasks(OpClass::Combine, nb, 2 * block);
    dag.push(OpClass::Finalize, t);
    dag
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_cost() -> CostModel {
        CostModel { flop_time: 1e-9, launch_overhead: 1e-6 }
    }

    #[test]
    fn work_and_span_scale_correctly() {
        let c = toy_cost();
        let small = dag_parallel_smoother(1024);
        let big = dag_parallel_smoother(4096);
        // work is ~linear in T
        let w_ratio = big.work(&c, 4) / small.work(&c, 4);
        assert!((w_ratio - 4.0).abs() < 0.3, "work ratio {w_ratio}");
        // span is ~logarithmic: +2 scan levels each direction + final
        let s_ratio = big.span(&c, 4) / small.span(&c, 4);
        assert!(s_ratio < 1.35, "span ratio {s_ratio}");
    }

    #[test]
    fn infinite_cores_approach_span() {
        let dev = Device { cores: usize::MAX, cost: toy_cost() };
        let dag = dag_parallel_smoother(4096);
        let t = dev.run(&dag, 4);
        let span = dag.span(&toy_cost(), 4);
        assert!((t - span).abs() / span < 1e-9);
    }

    #[test]
    fn single_core_approaches_work_plus_overhead() {
        let dev = Device { cores: 1, cost: toy_cost() };
        let dag = dag_parallel_smoother(512);
        let t = dev.run(&dag, 4);
        let work = dag.work(&toy_cost(), 4);
        let overhead = dag.levels.len() as f64 * toy_cost().launch_overhead;
        assert!((t - (work + overhead)).abs() / t < 1e-9);
    }

    #[test]
    fn parallel_beats_sequential_on_many_cores() {
        let dev = Device::gpu_3090_default();
        for t in [1_000usize, 10_000, 100_000] {
            let par = dev.run(&dag_parallel_smoother(t), 4);
            let seq = dev.run(&dag_sequential(t), 4);
            assert!(par < seq, "t={t}: par {par} !< seq {seq}");
        }
    }

    #[test]
    fn speedup_grows_then_saturates() {
        // The paper's Fig. 6 shape: ratio grows with T, then flattens
        // once work/p dominates span.
        let dev = Device::gpu_3090_default();
        let ratio = |t: usize| {
            dev.run(&dag_sequential(t), 4) / dev.run(&dag_parallel_smoother(t), 4)
        };
        let r3 = ratio(1_000);
        let r4 = ratio(10_000);
        let r6 = ratio(1_000_000);
        let r7 = ratio(10_000_000);
        assert!(r4 > r3, "speedup should grow: {r3} -> {r4}");
        // deep saturation: ratio stops growing appreciably
        assert!((r7 / r6) < 2.0, "saturation expected: {r6} -> {r7}");
    }

    #[test]
    fn knee_appears_when_work_exceeds_cores() {
        // Fig. 5 shape: parallel runtime ~log below the knee, ~linear
        // beyond it. Past the knee doubling T should ~double time.
        let dev = Device::gpu_3090_default();
        let t_lo = dev.run(&dag_parallel_smoother(1 << 20), 4);
        let t_hi = dev.run(&dag_parallel_smoother(1 << 21), 4);
        let growth = t_hi / t_lo;
        assert!(growth > 1.6, "expected near-linear growth, got {growth}");
        let s_lo = dev.run(&dag_parallel_smoother(1 << 8), 4);
        let s_hi = dev.run(&dag_parallel_smoother(1 << 9), 4);
        let log_growth = s_hi / s_lo;
        assert!(log_growth < 1.35, "expected log growth, got {log_growth}");
    }

    #[test]
    fn blockwise_tradeoff() {
        // With few cores, block-wise beats the flat parallel scan's
        // overhead-laden schedule; with many cores the flat scan wins.
        let few = Device::cpu_like(16, 1e-9);
        let t = 1 << 16;
        let flat_few = few.run(&dag_parallel_smoother(t), 4);
        let block_few = few.run(&dag_blockwise(t, t / 32), 4);
        assert!(block_few < flat_few, "{block_few} !< {flat_few}");
    }

    #[test]
    fn dag_counts_are_sane() {
        let dag = dag_parallel_smoother(8);
        let total_combines: usize = dag
            .levels
            .iter()
            .filter(|l| l.class == OpClass::Combine)
            .map(|l| l.count)
            .sum();
        // two scans over 8 elements: up 4+2+1, down 1+2+4, final 8 → 22 each
        assert_eq!(total_combines, 44);
        assert_eq!(dag.levels.first().unwrap().class, OpClass::ElementInit);
        assert_eq!(dag.levels.last().unwrap().class, OpClass::Finalize);
    }
}

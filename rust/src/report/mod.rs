//! Result reporting: CSV writers, ASCII log-log plots, markdown tables.
//!
//! The figure benches write a CSV per paper figure plus an ASCII
//! rendering into `results/`, and EXPERIMENTS.md references both.

use std::fmt::Write as _;
use std::path::Path;

use crate::error::Result;

/// A named series of (x, y) points — one plotted line.
#[derive(Debug, Clone, PartialEq)]
pub struct Series {
    /// Legend label.
    pub name: String,
    /// The (x, y) samples, plot order.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// An empty named series.
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), points: Vec::new() }
    }

    /// Append one sample.
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Write series as tidy CSV: `series,x,y`.
pub fn write_csv(path: impl AsRef<Path>, series: &[Series]) -> Result<()> {
    let mut out = String::from("series,x,y\n");
    for s in series {
        for &(x, y) in &s.points {
            let _ = writeln!(out, "{},{},{}", csv_escape(&s.name), x, y);
        }
    }
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Parse the same tidy CSV back (used by tests and the report builder).
pub fn read_csv(text: &str) -> Vec<Series> {
    let mut series: Vec<Series> = Vec::new();
    for line in text.lines().skip(1) {
        // The name field may be quoted and contain commas.
        let (name, rest) = if let Some(stripped) = line.strip_prefix('"') {
            let Some(end) = stripped.find('"') else { continue };
            let name = stripped[..end].replace("\"\"", "\"");
            let Some(rest) = stripped[end + 1..].strip_prefix(',') else { continue };
            (name, rest)
        } else {
            let Some((name, rest)) = line.split_once(',') else { continue };
            (name.to_string(), rest)
        };
        let Some((x, y)) = rest.split_once(',') else { continue };
        let (Ok(x), Ok(y)) = (x.parse::<f64>(), y.parse::<f64>()) else { continue };
        match series.iter_mut().find(|s| s.name == name) {
            Some(s) => s.points.push((x, y)),
            None => series.push(Series { name, points: vec![(x, y)] }),
        }
    }
    series
}

fn csv_escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Options for [`ascii_plot`].
#[derive(Debug, Clone, Copy)]
pub struct PlotOptions {
    /// Plot width in character cells.
    pub width: usize,
    /// Plot height in character cells.
    pub height: usize,
    /// Log-scale the x axis.
    pub log_x: bool,
    /// Log-scale the y axis.
    pub log_y: bool,
}

impl Default for PlotOptions {
    fn default() -> Self {
        Self { width: 72, height: 20, log_x: true, log_y: true }
    }
}

/// Render series as an ASCII scatter/line chart (the paper's figures are
/// log-log runtime plots, so that is the default).
pub fn ascii_plot(title: &str, series: &[Series], opts: PlotOptions) -> String {
    let mut pts: Vec<(f64, f64, usize)> = Vec::new();
    for (si, s) in series.iter().enumerate() {
        for &(x, y) in &s.points {
            if (!opts.log_x || x > 0.0) && (!opts.log_y || y > 0.0) {
                let tx = if opts.log_x { x.log10() } else { x };
                let ty = if opts.log_y { y.log10() } else { y };
                pts.push((tx, ty, si));
            }
        }
    }
    let mut out = format!("## {title}\n");
    if pts.is_empty() {
        out.push_str("(no data)\n");
        return out;
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y, _) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let marks = ['*', '+', 'o', 'x', '#', '@', '%', '&', '~', '$'];
    let mut grid = vec![vec![' '; opts.width]; opts.height];
    for &(x, y, si) in &pts {
        let cx = ((x - x0) / (x1 - x0) * (opts.width - 1) as f64).round() as usize;
        let cy = ((y - y0) / (y1 - y0) * (opts.height - 1) as f64).round() as usize;
        let row = opts.height - 1 - cy;
        grid[row][cx] = marks[si % marks.len()];
    }
    let fmt_axis = |v: f64, log: bool| {
        if log {
            format!("1e{v:.1}")
        } else {
            format!("{v:.3}")
        }
    };
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            fmt_axis(y1, opts.log_y)
        } else if i == opts.height - 1 {
            fmt_axis(y0, opts.log_y)
        } else {
            String::new()
        };
        let _ = writeln!(out, "{label:>8} |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "{:>8} +{}", "", "-".repeat(opts.width));
    let _ = writeln!(
        out,
        "{:>8}  {}{}{}",
        "",
        fmt_axis(x0, opts.log_x),
        " ".repeat(opts.width.saturating_sub(12)),
        fmt_axis(x1, opts.log_x)
    );
    for (si, s) in series.iter().enumerate() {
        let _ = writeln!(out, "  {} {}", marks[si % marks.len()], s.name);
    }
    out
}

/// Render a markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "| {} |", headers.join(" | "));
    let _ = writeln!(
        out,
        "|{}|",
        headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
    );
    for row in rows {
        let _ = writeln!(out, "| {} |", row.join(" | "));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_round_trip() {
        let mut a = Series::new("sp-par");
        a.push(100.0, 0.01);
        a.push(1000.0, 0.02);
        let mut b = Series::new("with,comma");
        b.push(1.0, 2.0);
        let dir = std::env::temp_dir().join("hmm_scan_report_test");
        let path = dir.join("fig.csv");
        write_csv(&path, &[a.clone(), b.clone()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let back = read_csv(&text);
        assert_eq!(back.len(), 2);
        assert_eq!(back[0], a);
        assert_eq!(back[1].points, b.points);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn plot_contains_series_markers_and_title() {
        let mut s1 = Series::new("seq");
        let mut s2 = Series::new("par");
        for i in 1..6 {
            let x = 10f64.powi(i);
            s1.push(x, x * 1e-6);
            s2.push(x, (x.log10()) * 1e-4);
        }
        let plot = ascii_plot("Fig. 3", &[s1, s2], PlotOptions::default());
        assert!(plot.contains("## Fig. 3"));
        assert!(plot.contains("* seq"));
        assert!(plot.contains("+ par"));
        assert!(plot.contains('|'));
    }

    #[test]
    fn plot_handles_empty_and_degenerate() {
        let p = ascii_plot("x", &[], PlotOptions::default());
        assert!(p.contains("no data"));
        let s = Series { name: "one".into(), points: vec![(1.0, 1.0)] };
        let p = ascii_plot("x", &[s], PlotOptions::default());
        assert!(p.contains("one"));
    }

    #[test]
    fn markdown_table_shape() {
        let t = markdown_table(
            &["method", "T", "time"],
            &[vec!["sp".into(), "100".into(), "1ms".into()]],
        );
        assert_eq!(t.lines().count(), 3);
        assert!(t.contains("| sp | 100 | 1ms |"));
    }
}

//! Thread-pool execution substrate (tokio/rayon are unavailable offline).
//!
//! Provides the two primitives the rest of the crate needs:
//!
//! * [`ThreadPool`] — a fixed set of workers fed by an mpsc job queue;
//!   used by the coordinator's worker pool and the parallel scan.
//! * [`parallel_for_chunks`] / [`scope_join`] — scoped fork-join helpers
//!   built on `std::thread::scope`, used by the Blelloch scan levels and
//!   the bench harness sweeps.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Jobs are `FnOnce()`; completion is tracked by
/// a [`WaitGroup`] the caller can block on.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("hmm-scan-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool queue poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // A panicking job must not kill the worker;
                                // the WaitGroup still gets decremented by
                                // its Drop guard.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers, size }
    }

    /// Pool sized to the machine: `available_parallelism`, capped.
    pub fn with_default_size() -> Self {
        Self::new(default_parallelism())
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job. Returns an error only if the pool is shut down.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool queue closed");
    }

    /// Submit a batch of jobs and wait for all of them to finish.
    pub fn run_all<F: FnOnce() + Send + 'static>(&self, jobs: Vec<F>) {
        let wg = WaitGroup::new(jobs.len());
        for f in jobs {
            let guard = wg.guard();
            self.submit(move || {
                let _guard = guard; // decremented on drop, even on panic
                f();
            });
        }
        wg.wait();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Machine parallelism with a sane floor.
pub fn default_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Countdown latch used to join a batch of pool jobs.
pub struct WaitGroup {
    inner: Arc<WgInner>,
}

struct WgInner {
    count: AtomicUsize,
    mutex: Mutex<()>,
    cond: std::sync::Condvar,
}

/// RAII decrement handle for a [`WaitGroup`].
pub struct WgGuard {
    inner: Arc<WgInner>,
}

impl WaitGroup {
    /// A latch that opens after `count` guard drops.
    pub fn new(count: usize) -> Self {
        Self {
            inner: Arc::new(WgInner {
                count: AtomicUsize::new(count),
                mutex: Mutex::new(()),
                cond: std::sync::Condvar::new(),
            }),
        }
    }

    /// Hand out one RAII decrement (dropped even on panic).
    pub fn guard(&self) -> WgGuard {
        WgGuard { inner: Arc::clone(&self.inner) }
    }

    /// Block until every guard has dropped.
    pub fn wait(&self) {
        let mut g = self.inner.mutex.lock().unwrap();
        while self.inner.count.load(Ordering::Acquire) != 0 {
            g = self.inner.cond.wait(g).unwrap();
        }
    }
}

impl Drop for WgGuard {
    fn drop(&mut self) {
        if self.inner.count.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = self.inner.mutex.lock().unwrap();
            self.inner.cond.notify_all();
        }
    }
}

/// Split `0..len` into at most `max_chunks` contiguous ranges and run `f`
/// on each range concurrently (scoped threads — no 'static bound).
///
/// `f(chunk_index, start, end)`.
pub fn parallel_for_chunks<F>(len: usize, max_chunks: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if len == 0 {
        return;
    }
    let chunks = max_chunks.clamp(1, len);
    if chunks == 1 {
        f(0, 0, len);
        return;
    }
    let per = len.div_ceil(chunks);
    thread::scope(|s| {
        for (idx, start) in (0..len).step_by(per).enumerate() {
            let end = (start + per).min(len);
            let f = &f;
            s.spawn(move || f(idx, start, end));
        }
    });
}

/// Unsafe shared mutable view of a slice for structured data-parallel
/// writes (each thread must touch a disjoint index set — the caller's
/// proof obligation, documented at every use site).
///
/// Accessors are methods (not pub fields) so closures capture the whole
/// wrapper — edition-2021 disjoint-field capture would otherwise grab the
/// raw pointer directly and lose the Send/Sync impls.
pub struct SharedSliceMut<E> {
    ptr: *mut E,
    len: usize,
}

unsafe impl<E: Send> Send for SharedSliceMut<E> {}
unsafe impl<E: Send> Sync for SharedSliceMut<E> {}

impl<E> SharedSliceMut<E> {
    /// Wrap a slice for disjoint-range parallel writes.
    pub fn new(slice: &mut [E]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    /// Length of the wrapped slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the wrapped slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// # Safety
    /// The caller must guarantee no concurrent access to any index in
    /// `start..end` from another thread.
    pub unsafe fn range_mut(&self, start: usize, end: usize) -> &mut [E] {
        debug_assert!(start <= end && end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), end - start)
    }

    /// # Safety
    /// As [`range_mut`](Self::range_mut) for the full slice: caller must
    /// ensure the concurrently-touched index sets are disjoint.
    pub unsafe fn full_mut(&self) -> &mut [E] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }

    /// # Safety
    /// No concurrent access to index `i`.
    pub unsafe fn write(&self, i: usize, v: E) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }
}

/// Run two closures concurrently and return both results (fork-join).
pub fn scope_join<A, B, RA, RB>(a: A, b: B) -> (RA, RB)
where
    A: FnOnce() -> RA + Send,
    B: FnOnce() -> RB + Send,
    RA: Send,
    RB: Send,
{
    thread::scope(|s| {
        let ha = s.spawn(a);
        let rb = b();
        (ha.join().expect("scope_join: left side panicked"), rb)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }
            })
            .collect();
        pool.run_all(jobs);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        let wg = WaitGroup::new(1);
        let g = wg.guard();
        pool.submit(move || {
            let _g = g;
            panic!("job panic must not kill the worker");
        });
        wg.wait();
        // Pool still functional afterwards.
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.run_all(vec![move || {
            c.fetch_add(1, Ordering::Relaxed);
        }]);
        assert_eq!(counter.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_shutdown_joins_workers() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // must drain the queue before joining
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn parallel_for_chunks_covers_range_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(1000, 7, |_idx, start, end| {
            for item in hits.iter().take(end).skip(start) {
                item.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_chunks_empty_and_single() {
        parallel_for_chunks(0, 4, |_, _, _| panic!("must not run"));
        let ran = AtomicU64::new(0);
        parallel_for_chunks(5, 1, |idx, s, e| {
            assert_eq!((idx, s, e), (0, 0, 5));
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn scope_join_returns_both() {
        let (a, b) = scope_join(|| 1 + 1, || "x".to_string());
        assert_eq!(a, 2);
        assert_eq!(b, "x");
    }
}

//! `hmm-scan` — launcher for the temporal-parallel HMM inference system.
//!
//! Subcommands:
//!   decode     run one inference request through the coordinator
//!   serve      start the coordinator (TCP with --listen, else a
//!              synthetic in-process load)
//!   bench-net  drive a remote server: verify bit-identity vs a local
//!              coordinator, then measure wire throughput/latency
//!   route      front a worker pool with the consistent-hash session
//!              router (the distributed serving tier, DESIGN.md §7)
//!   stat       scrape a remote server's metrics as `key value` text
//!   replay     fold an event timeline back into the registry view it
//!              implies (docs/OBSERVABILITY.md)
//!   trace      merge N process timelines into causally ordered
//!              per-request span trees with stage latency attribution
//!   cluster-demo  three-worker loopback cluster end to end: placement,
//!              failover-by-drain, live migration, bit-identity checks
//!   figures    regenerate the paper's figures/tables into results/
//!   simulate   query the work-span GPU simulator
//!   train      Baum–Welch parameter estimation (§V-C) on GE data
//!   info       artifact manifest + environment report

use std::sync::Arc;
use std::time::{Duration, Instant};

use hmm_scan::cli::{flag, opt, Cli};
use hmm_scan::cluster::{ClusterConfig, ClusterRouter};
use hmm_scan::config::RunConfig;
use hmm_scan::coordinator::{
    Algo, Coordinator, CoordinatorConfig, DecodeRequest, DecodeResult,
    ExecMode, StreamReply, StreamRequest,
};
use hmm_scan::engine::{Algorithm, Engine, SessionOptions};
use hmm_scan::error::{Error, Result};
use hmm_scan::hmm::{gilbert_elliott, sample};
use hmm_scan::inference::{BaumWelchOptions, EStepBackend};
use hmm_scan::net::{NetClient, NetServer, NetServerConfig};
use hmm_scan::rng::Xoshiro256StarStar;
use hmm_scan::simulator::Device;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => {}
        Err(Error::Usage(msg)) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn cli() -> Cli {
    Cli::new("hmm-scan", "temporal parallelization of HMM inference (TSP 2021)")
        .command(
            "decode",
            "run one inference request through the coordinator",
            vec![
                opt("t", "sequence length to sample", "1000"),
                opt("algo", "smooth | map | bayes", "smooth"),
                opt("mode", "auto | native | pjrt | sharded", "auto"),
                opt("seed", "workload RNG seed", "3405691582"),
                opt("config", "JSON config file path", ""),
            ],
            vec![],
        )
        .command(
            "serve",
            "start the coordinator: TCP with --listen, else a synthetic load",
            vec![
                opt("requests", "number of requests (synthetic mode)", "64"),
                opt("t", "sequence length per request (synthetic mode)", "1000"),
                opt("workers", "XLA worker threads", "4"),
                opt("store", "durable session-store directory ('' = memory)", ""),
                opt("listen", "TCP listen address, e.g. 127.0.0.1:7171 ('' = synthetic load)", ""),
                opt("duration", "seconds to serve TCP before draining (0 = forever)", "0"),
                opt("max-conns", "TCP connection limit", "64"),
                opt("max-inflight", "pipelined requests per connection", "32"),
                opt("inflight-quota", "per-connection decode quota: shed instead of block past it (0 = off)", "0"),
                opt("timeline", "event-timeline directory ('' = off)", ""),
                opt("slow-ms", "flag request spans slower than this many ms (0 = off)", "0"),
                opt("config", "JSON config file path", ""),
                flag("native", "serve natively (no artifacts)"),
            ],
            vec![],
        )
        .command(
            "bench-net",
            "verify + benchmark a remote server over the wire protocol",
            vec![
                opt("connect", "server address (host:port)", ""),
                opt("requests", "decode requests per connection", "64"),
                opt("t", "sequence length per request", "512"),
                opt("conns", "concurrent client connections", "4"),
                opt("pipeline", "requests in flight per connection", "8"),
                opt("deadline-ms", "per-request latency budget stamped on the wire (0 = none)", "0"),
                opt("seed", "workload RNG seed", "3405691582"),
                opt("config", "JSON config file path", ""),
            ],
            vec![],
        )
        .command(
            "route",
            "front a worker pool with the consistent-hash session router",
            vec![
                opt("listen", "router TCP listen address", "127.0.0.1:0"),
                opt("workers", "comma-separated worker addresses (host:port,...)", ""),
                opt("duration", "seconds to route before draining (0 = forever)", "0"),
                opt("max-conns", "client connection limit", "64"),
                opt("max-inflight", "pipelined requests per client connection", "32"),
                opt("pool", "decode connections per worker", "4"),
                opt("timeline", "event-timeline directory ('' = off)", ""),
                opt("slow-ms", "flag request spans slower than this many ms (0 = off)", "0"),
            ],
            vec![],
        )
        .command(
            "stat",
            "scrape a remote server's metrics snapshot as key-value text",
            vec![opt("connect", "server address (host:port)", "")],
            vec![],
        )
        .command(
            "replay",
            "fold an event timeline back into the registry view it implies",
            vec![
                opt("timeline", "timeline directory to fold", ""),
                opt("until", "stop after this sequence number (0 = all)", "0"),
            ],
            vec![],
        )
        .command(
            "trace",
            "merge process timelines into per-request span trees",
            vec![
                opt("merge", "comma-separated timeline directories to fold (router,worker,...)", ""),
                opt("until", "stop each source after this sequence number (0 = all)", "0"),
                flag("slow-only", "print only traces flagged slow (serve/route --slow-ms)"),
            ],
            vec![],
        )
        .command(
            "cluster-demo",
            "three-worker loopback cluster: placement, drain, migration",
            vec![
                opt("t", "observations per verification sequence", "240"),
                opt("sessions", "streaming sessions to place", "4"),
                opt("config", "JSON config file path", ""),
            ],
            vec![],
        )
        .command(
            "figures",
            "regenerate the paper's figures and tables",
            vec![
                opt("fig", "2|3|4|5|6|table1|equiv|ablations", "all"),
                opt("out", "output directory", "results"),
                opt("config", "JSON config file path", ""),
                flag("all", "generate everything"),
                flag("quick", "reduced grid for smoke runs"),
            ],
            vec![],
        )
        .command(
            "simulate",
            "query the work-span GPU simulator",
            vec![
                opt("t", "sequence length", "100000"),
                opt("d", "number of states", "4"),
                opt("cores", "device cores", "10496"),
                opt("method", "one of the paper's seven methods", "SP-Par"),
            ],
            vec![],
        )
        .command(
            "train",
            "Baum-Welch (§V-C) on sampled GE data",
            vec![
                opt("t", "training sequence length", "2000"),
                opt("iters", "max EM iterations", "30"),
                opt("backend", "seq | par (E-step engine)", "par"),
                opt("config", "JSON config file path", ""),
            ],
            vec![],
        )
        .command("info", "artifact manifest + environment report", vec![], vec![])
}

fn load_config(parsed: &hmm_scan::cli::Parsed) -> Result<RunConfig> {
    match parsed.get("config") {
        Some("") | None => Ok(RunConfig::default()),
        Some(path) => RunConfig::from_json_file(std::path::Path::new(path)),
    }
}

fn run(args: &[String]) -> Result<()> {
    let parsed = cli().parse(args)?;
    match parsed.command.as_str() {
        "decode" => cmd_decode(&parsed),
        "serve" => cmd_serve(&parsed),
        "bench-net" => cmd_bench_net(&parsed),
        "route" => cmd_route(&parsed),
        "stat" => cmd_stat(&parsed),
        "replay" => cmd_replay(&parsed),
        "trace" => cmd_trace(&parsed),
        "cluster-demo" => cmd_cluster_demo(&parsed),
        "figures" => cmd_figures(&parsed),
        "simulate" => cmd_simulate(&parsed),
        "train" => cmd_train(&parsed),
        "info" => cmd_info(),
        _ => unreachable!("cli parser validates commands"),
    }
}

fn cmd_decode(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let config = load_config(p)?;
    let t = p.get_usize("t")?;
    let algo_str = p.get("algo").unwrap_or("smooth");
    let algo = Algo::parse(algo_str)
        .ok_or_else(|| Error::usage(format!("unknown algo '{algo_str}'")))?;
    let mode = match p.get("mode").unwrap_or("auto") {
        "auto" => ExecMode::Auto,
        "native" => ExecMode::Native,
        "pjrt" => ExecMode::Pjrt,
        "sharded" => ExecMode::Sharded,
        other => return Err(Error::usage(format!("unknown mode '{other}'"))),
    };
    let seed: u64 = p.get_usize("seed")? as u64;

    let hmm = gilbert_elliott(config.ge);
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
    let tr = sample(&hmm, t, &mut rng);

    let coord_config = if mode == ExecMode::Native {
        CoordinatorConfig::native_only()
    } else {
        CoordinatorConfig::default()
    };
    let coord = Coordinator::new(coord_config)?;
    coord.register_model("ge", hmm.clone());
    let resp = coord.decode(
        DecodeRequest::new(1, "ge", tr.observations.clone(), algo).with_mode(mode),
    )?;
    println!("plan:    {}", resp.plan);
    println!("elapsed: {:?}", resp.elapsed);
    match resp.result {
        hmm_scan::coordinator::DecodeResult::Posterior(post) => {
            println!("loglik:  {:.6}", post.log_likelihood());
            let map = post.marginal_map();
            let acc = accuracy(&map, &tr.states);
            println!("smoothed-marginal state accuracy vs truth: {acc:.4}");
        }
        hmm_scan::coordinator::DecodeResult::Map(est) => {
            println!("logp:    {:.6}", est.log_prob);
            let acc = accuracy(&est.path, &tr.states);
            println!("MAP path state accuracy vs truth: {acc:.4}");
        }
    }
    Ok(())
}

fn accuracy(got: &[u32], truth: &[u32]) -> f64 {
    let same = got.iter().zip(truth).filter(|(a, b)| a == b).count();
    same as f64 / truth.len().max(1) as f64
}

fn cmd_serve(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let config = load_config(p)?;
    let n = p.get_usize("requests")?;
    let t = p.get_usize("t")?;
    let workers = p.get_usize("workers")?;
    // Store/housekeeping knobs come from the JSON config; the CLI can
    // point the durable store somewhere without editing a file.
    let mut coord_config = config.coordinator_config();
    if p.flag("native") {
        coord_config.artifacts = None;
    } else {
        coord_config.xla_workers = workers;
    }
    if let Some(dir) = p.get("store") {
        if !dir.is_empty() {
            coord_config.session_store = Some(dir.into());
        }
    }
    // One shared timeline across the coordinator and the net server, so
    // session and connection events interleave in a single monotonic
    // log (`hmm-scan replay --timeline DIR` folds it back).
    let timeline = match p.get("timeline") {
        Some(dir) if !dir.is_empty() => {
            Some(hmm_scan::obs::Timeline::open(dir)?)
        }
        _ => None,
    };
    coord_config.timeline = timeline.clone();
    let coord = Arc::new(Coordinator::new(coord_config)?);
    let hmm = gilbert_elliott(config.ge);
    coord.register_model("ge", hmm.clone());
    // The canonical Kalman-tier model, so remote clients can open
    // `SessionKind::Kalman` sessions against a stock server.
    coord.register_lgssm(
        "cv",
        hmm_scan::kalman::Lgssm::constant_velocity(0.1, 0.8, 0.5),
    );

    // TCP mode: expose every decode and streaming verb over the wire
    // (docs/WIRE_FORMAT.md) and serve until killed (or --duration).
    if let Some(listen) = p.get("listen").filter(|l| !l.is_empty()) {
        let net_config = NetServerConfig {
            max_connections: p.get_usize("max-conns")?,
            max_inflight_per_conn: p.get_usize("max-inflight")?,
            inflight_quota: p.get_usize("inflight-quota")?,
            timeline: timeline.clone(),
            slow_ms: p.get_usize("slow-ms")? as u64,
            ..NetServerConfig::default()
        };
        let server =
            NetServer::start(Arc::clone(&coord), listen, net_config)?;
        // The exact line CI's loopback job parses for the bound port.
        println!("listening on {}", server.local_addr());
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        let duration = p.get_usize("duration")?;
        let started = Instant::now();
        loop {
            std::thread::sleep(Duration::from_millis(500));
            if duration > 0
                && started.elapsed() >= Duration::from_secs(duration as u64)
            {
                break;
            }
        }
        let graceful = server.shutdown(Duration::from_secs(10));
        // Shutdown ordering: the drain above stops new work, but spill /
        // sync jobs queued by the served connections may still be in
        // flight. Quiesce housekeeping *before* the store closes (when
        // `coord` drops at the end of this function) so every queued
        // append hits disk — otherwise a --duration run could lose the
        // tail of its durable log.
        coord.quiesce_housekeeping();
        if let Some(tl) = &timeline {
            tl.flush();
        }
        let snap = coord.metrics().snapshot();
        println!(
            "drained ({}): {} conns served ({} refused), {} decode reqs",
            if graceful { "graceful" } else { "forced" },
            snap.conns_opened,
            snap.conns_refused,
            snap.requests,
        );
        for v in &snap.wire_verbs {
            println!(
                "  wire {:<7} n={:<7} p50 {}µs  p99 {}µs  max {}µs",
                v.verb, v.count, v.p50_us, v.p99_us, v.max_us
            );
        }
        return Ok(());
    }

    let handle = Arc::clone(&coord).serve();
    let mut rng = Xoshiro256StarStar::seed_from_u64(config.seed);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let tr = sample(&hmm, t, &mut rng);
            let algo = if i % 2 == 0 { Algo::Smooth } else { Algo::Map };
            handle.submit(DecodeRequest::new(i as u64, "ge", tr.observations, algo))
        })
        .collect();
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv().map_err(|_| Error::coordinator("reply dropped"))?.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    handle.shutdown();

    let snap = coord.metrics().snapshot();
    println!("served {ok}/{n} requests in {wall:?}");
    println!(
        "throughput: {:.1} req/s   p50 {}µs   p99 {}µs   max {}µs",
        ok as f64 / wall.as_secs_f64(),
        snap.p50_us,
        snap.p99_us,
        snap.max_us
    );
    println!(
        "batches: {} (mean occupancy {:.2})   sharded blocks: {}",
        snap.batches,
        snap.batch_occupancy(),
        snap.sharded_blocks
    );
    println!(
        "session store: {}   spills {}   restores {}   hk queue {}   \
         sync batches {} ({:.2} appends/sync)",
        coord.session_store().name(),
        snap.spills,
        snap.restores,
        snap.hk_queue_depth,
        snap.sync_batches,
        snap.sync_batch_occupancy(),
    );
    Ok(())
}

/// Drive a remote server end to end: first verify that decode and the
/// full streaming lifecycle return results **bit-identical** to a local
/// native coordinator fed the same requests (any mismatch is a nonzero
/// exit — CI's loopback smoke job relies on that), then measure
/// pipelined wire throughput and latency.
fn cmd_bench_net(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let config = load_config(p)?;
    let addr = match p.get("connect") {
        Some(a) if !a.is_empty() => a.to_string(),
        _ => return Err(Error::usage("bench-net requires --connect HOST:PORT")),
    };
    let requests = p.get_usize("requests")?;
    let t = p.get_usize("t")?;
    let conns = p.get_usize("conns")?.max(1);
    let pipeline = p.get_usize("pipeline")?.max(1);
    let deadline_ms = match p.get_usize("deadline-ms")? as u64 {
        0 => None,
        ms => Some(ms),
    };
    let seed = p.get_usize("seed")? as u64;

    let hmm = gilbert_elliott(config.ge);
    let local = Coordinator::new(CoordinatorConfig::native_only())?;
    local.register_model("ge", hmm.clone());
    let mut client = NetClient::connect(&addr)?;
    client.set_deadline_ms(deadline_ms);
    client.ping()?;
    println!("connected to {addr}");

    // ---- verification: remote must equal in-process, bit for bit ----
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
    let ys = sample(&hmm, t.max(8), &mut rng).observations;
    for algo in Algo::ALL {
        let req = DecodeRequest::new(1, "ge", ys.clone(), algo)
            .with_mode(ExecMode::Native);
        let remote = client.decode(&req)?;
        let want = local.decode(req)?;
        let ok = match (&remote.result, &want.result) {
            (DecodeResult::Posterior(a), DecodeResult::Posterior(b)) => a == b,
            (DecodeResult::Map(a), DecodeResult::Map(b)) => a == b,
            _ => false,
        };
        if !ok {
            return Err(Error::coordinator(format!(
                "verification failed: remote {algo:?} decode diverged from \
                 the local coordinator"
            )));
        }
    }
    // Streaming lifecycle: open → append* → stat → close, mirrored on
    // the local coordinator.
    let remote_sid = client.open("ge", SessionOptions::default(), 16)?;
    let opened = local.stream(StreamRequest::open(0, "ge", 16))?;
    let StreamReply::Opened { session: local_sid } = opened.reply else {
        return Err(Error::coordinator("local open failed"));
    };
    for chunk in ys.chunks((ys.len() / 3).max(1)) {
        let remote = client.append(remote_sid, chunk)?;
        let want =
            local.stream(StreamRequest::append(0, local_sid, chunk.to_vec()))?;
        let (
            StreamReply::Appended { len: rl, filtered: rf, window: rw, .. },
            StreamReply::Appended { len: wl, filtered: wf, window: ww, .. },
        ) = (remote, want.reply)
        else {
            return Err(Error::coordinator("append reply shape mismatch"));
        };
        let windows_match = match (&rw, &ww) {
            (Some(a), Some(b)) => {
                a.start == b.start && a.posterior == b.posterior
            }
            (None, None) => true,
            _ => false,
        };
        if rl != wl || rf != wf || !windows_match {
            return Err(Error::coordinator(
                "verification failed: streaming append diverged over the wire",
            ));
        }
    }
    let StreamReply::Stats { len, .. } = client.stat(remote_sid)? else {
        return Err(Error::coordinator("stat reply shape mismatch"));
    };
    if len != ys.len() {
        return Err(Error::coordinator(format!(
            "verification failed: stat reports {len} of {} observations",
            ys.len()
        )));
    }
    let remote_posterior = client.close(remote_sid)?;
    let closed = local.stream(StreamRequest::close(0, local_sid))?;
    let StreamReply::Closed { posterior: want_posterior, .. } = closed.reply
    else {
        return Err(Error::coordinator("local close failed"));
    };
    if remote_posterior != want_posterior {
        return Err(Error::coordinator(
            "verification failed: close posterior diverged over the wire",
        ));
    }
    println!(
        "verification OK: decode ×{} and open→append→stat→close are \
         bit-identical to the local coordinator",
        Algo::ALL.len()
    );

    // ---- throughput: conns × pipelining ------------------------------
    let t0 = Instant::now();
    let mut all_lat: Vec<Duration> = Vec::new();
    let mut served = 0usize;
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for c in 0..conns {
            let addr = addr.clone();
            let hmm = hmm.clone();
            joins.push(scope.spawn(move || -> Result<Vec<Duration>> {
                let mut client = NetClient::connect(&addr)?;
                client.set_deadline_ms(deadline_ms);
                let mut rng =
                    Xoshiro256StarStar::seed_from_u64(seed ^ (c as u64 + 1));
                let reqs: Vec<DecodeRequest> = (0..requests)
                    .map(|i| {
                        let ys = sample(&hmm, t, &mut rng).observations;
                        let algo =
                            if i % 2 == 0 { Algo::Smooth } else { Algo::Map };
                        DecodeRequest::new(i as u64, "ge", ys, algo)
                    })
                    .collect();
                client.pipeline_decodes(reqs, pipeline)
            }));
        }
        for join in joins {
            let lat = join.join().expect("bench thread panicked")?;
            served += lat.len();
            all_lat.extend(lat);
        }
        Ok(())
    })?;
    let wall = t0.elapsed();
    all_lat.sort_unstable();
    let pct = |p: f64| -> u128 {
        if all_lat.is_empty() {
            0
        } else {
            let idx = ((all_lat.len() as f64 - 1.0) * p).floor() as usize;
            all_lat[idx].as_micros()
        }
    };
    println!(
        "throughput: {served} requests over {conns} conns × pipeline \
         {pipeline} in {wall:?} = {:.1} req/s",
        served as f64 / wall.as_secs_f64()
    );
    println!(
        "wire latency: p50 {}µs  p99 {}µs  max {}µs",
        pct(0.50),
        pct(0.99),
        all_lat.last().map_or(0, |d| d.as_micros())
    );
    Ok(())
}

/// `route`: front a pool of already-running workers with the cluster
/// router. Speaks the same wire protocol as `serve`, so `bench-net
/// --connect <router>` and any `NetClient` work unchanged against it.
fn cmd_route(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let workers: Vec<String> = match p.get("workers") {
        Some(list) if !list.is_empty() => list
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect(),
        _ => return Err(Error::usage("route requires --workers A,B,C")),
    };
    let mut cluster_config = ClusterConfig::new(workers);
    cluster_config.decode_pool = p.get_usize("pool")?.max(1);
    // One shared timeline across the router and its front-end, so
    // placement/migration events interleave with connection events.
    let timeline = match p.get("timeline") {
        Some(dir) if !dir.is_empty() => {
            Some(hmm_scan::obs::Timeline::open(dir)?)
        }
        _ => None,
    };
    cluster_config.timeline = timeline.clone();
    let router = Arc::new(ClusterRouter::new(cluster_config)?);
    let net_config = NetServerConfig {
        max_connections: p.get_usize("max-conns")?,
        max_inflight_per_conn: p.get_usize("max-inflight")?,
        timeline: timeline.clone(),
        slow_ms: p.get_usize("slow-ms")? as u64,
        ..NetServerConfig::default()
    };
    let listen = p.get("listen").unwrap_or("127.0.0.1:0");
    let server = NetServer::start(Arc::clone(&router), listen, net_config)?;
    // The exact line CI's cluster smoke job parses for the bound port.
    println!("listening on {}", server.local_addr());
    for (addr, state) in router.worker_states() {
        println!("worker {addr}: {state}");
    }
    use std::io::Write as _;
    std::io::stdout().flush().ok();

    let duration = p.get_usize("duration")?;
    let started = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(500));
        if duration > 0
            && started.elapsed() >= Duration::from_secs(duration as u64)
        {
            break;
        }
    }
    let graceful = server.shutdown(Duration::from_secs(10));
    if let Some(tl) = &timeline {
        tl.flush();
    }
    let snap = router.metrics().snapshot();
    println!(
        "drained ({}): {} conns served ({} refused), {} sessions placed, \
         {} migrated, {} decode failovers, {} rejects",
        if graceful { "graceful" } else { "forced" },
        snap.conns_opened,
        snap.conns_refused,
        snap.sessions_placed,
        snap.sessions_migrated,
        snap.decode_failovers,
        snap.rejects_sent,
    );
    for link in &snap.worker_links {
        println!(
            "  worker {:<21} n={:<7} p50 {}µs  p99 {}µs  max {}µs",
            link.worker, link.count, link.p50_us, link.p99_us, link.max_us
        );
    }
    Ok(())
}

/// `stat`: scrape a remote server's full metrics snapshot as `key
/// value` text (the wire v3 scrape verb). Works identically against a
/// worker (`serve --listen`) and a router (`route`) front-end — the
/// scrape renders whatever `WireService` the server fronts.
fn cmd_stat(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let addr = match p.get("connect") {
        Some(a) if !a.is_empty() => a.to_string(),
        _ => return Err(Error::usage("stat requires --connect HOST:PORT")),
    };
    let mut client = NetClient::connect(&addr)?;
    let text = client.scrape()?;
    print!("{text}");
    Ok(())
}

/// `replay`: fold a recorded event timeline back into the state it
/// implies — open sessions with model/length/residency, cluster
/// placements, connection and shed counters — optionally stopping at
/// `--until SEQ` to reconstruct an intermediate moment. The replayed
/// view is bit-identical to what a live `Stat` reported at the same
/// seq (the coordinator and cluster test suites enforce this).
fn cmd_replay(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let dir = match p.get("timeline") {
        Some(d) if !d.is_empty() => d.to_string(),
        _ => return Err(Error::usage("replay requires --timeline DIR")),
    };
    let until = p.get_usize("until")?;
    let until = (until > 0).then_some(until as u64);
    let records = hmm_scan::obs::read_events(&dir)?;
    let state = hmm_scan::obs::replay_records(&records, until);
    println!(
        "replayed {} events (last seq {})",
        state.events, state.last_seq
    );
    // The exact line CI's observability job parses for the final count.
    println!(
        "sessions: {} open, {} resident",
        state.open_sessions(),
        state.resident_sessions()
    );
    for (id, v) in &state.sessions {
        println!(
            "  session {id}: model {} len {} {}",
            v.model,
            v.len,
            if v.resident { "resident" } else { "evicted" }
        );
    }
    if !state.placements.is_empty() {
        println!("placements:");
        for (id, worker) in &state.placements {
            println!("  session {id} -> {worker}");
        }
    }
    println!(
        "conns: {} opened, {} closed, {} refused, {} still open",
        state.conns_opened,
        state.conns_closed,
        state.conns_refused,
        state.open_conns.len()
    );
    println!(
        "rejects {}  drains {}  migrations {}  recovered {}",
        state.rejects, state.drains, state.migrations, state.recovered
    );
    Ok(())
}

/// `trace`: fold N process timelines (a router's plus its workers')
/// into one causally ordered view keyed by trace id, and print each
/// request's span tree with per-stage latency. Parent links cross
/// process boundaries — a worker's `execute` span nests under the
/// router span that dispatched it. `--slow-only` keeps just the traces
/// whose spans crossed the serving side's `--slow-ms` threshold; torn
/// traces (a `span-begin` with no end — a crashed or killed process
/// mid-request) are flagged rather than hidden.
fn cmd_trace(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let dirs: Vec<String> = match p.get("merge") {
        Some(list) if !list.is_empty() => list
            .split(',')
            .map(|d| d.trim().to_string())
            .filter(|d| !d.is_empty())
            .collect(),
        _ => return Err(Error::usage("trace requires --merge DIR,DIR,...")),
    };
    let until = p.get_usize("until")? as u64;
    let slow_only = p.flag("slow-only");
    // Label each source with its directory name when unambiguous (span
    // trees read `[rt]`, `[worker_a]`), the full path otherwise. Labels
    // must stay distinct: the merge dedups replayed records by
    // (source, seq).
    let names: Vec<String> = dirs
        .iter()
        .map(|d| {
            std::path::Path::new(d)
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_else(|| d.clone())
        })
        .collect();
    let unique = names
        .iter()
        .collect::<std::collections::BTreeSet<_>>()
        .len()
        == names.len();
    let mut inputs = Vec::new();
    for (dir, name) in dirs.iter().zip(names) {
        let mut records = hmm_scan::obs::read_events(dir)?;
        if until > 0 {
            records.retain(|r| r.seq <= until);
        }
        let label = if unique { name } else { dir.clone() };
        inputs.push((label, records));
    }
    let merged = hmm_scan::obs::merge_records(&inputs);
    let views = hmm_scan::obs::trace_views(&merged);
    let (slow, torn) = (
        views.iter().filter(|v| v.slow).count(),
        views.iter().filter(|v| v.torn).count(),
    );
    let mut shown = 0usize;
    for v in &views {
        if slow_only && !v.slow {
            continue;
        }
        shown += 1;
        let mut line = format!("trace {:016x}", v.trace);
        if v.slow {
            line.push_str("  SLOW");
        }
        if v.torn {
            line.push_str("  TORN");
        }
        println!("{line}");
        // Roots: parent 0, or a parent whose own span record is missing
        // (its process' timeline wasn't merged in) — still printed, at
        // the top level, so partial merges degrade readably.
        let ids: std::collections::BTreeSet<u64> =
            v.spans.iter().map(|s| s.span).collect();
        for (i, s) in v.spans.iter().enumerate() {
            if s.parent == 0 || !ids.contains(&s.parent) {
                print_span_tree(v, i, 1);
            }
        }
    }
    // The exact line CI's cluster tracing job parses for the counts.
    println!(
        "{} traces across {} timelines ({} slow, {} torn, {} shown)",
        views.len(),
        dirs.len(),
        slow,
        torn,
        shown
    );
    Ok(())
}

/// Print one span and, recursively, its children (indented two spaces
/// per hop — process boundaries show up as a `[source]` change).
fn print_span_tree(view: &hmm_scan::obs::TraceView, idx: usize, depth: usize) {
    let s = &view.spans[idx];
    let us = s
        .us
        .map_or_else(|| "never closed".to_string(), |us| format!("{us}µs"));
    let detail = if s.detail.is_empty() {
        String::new()
    } else {
        format!("  ({})", s.detail)
    };
    let slow = if s.slow { "  SLOW" } else { "" };
    println!(
        "{:indent$}[{}] {} {us}{detail}{slow}",
        "",
        s.source,
        s.stage,
        indent = depth * 2
    );
    for child in view.children_of(s.span) {
        print_span_tree(view, child, depth + 1);
    }
}

/// `cluster-demo`: the whole distributed tier on loopback, verified.
/// Spins up three native workers, fronts them with a router, and drives
/// a client through decode fan-out, session placement, an
/// administrative drain (live-migrating every resident session), and
/// more traffic after the drain — checking every response bit-identical
/// to a local control coordinator. Any divergence is a nonzero exit.
fn cmd_cluster_demo(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let config = load_config(p)?;
    let t = p.get_usize("t")?.max(8);
    let n_sessions = p.get_usize("sessions")?.max(1);
    let hmm = gilbert_elliott(config.ge);

    // Three independent workers, each a full serve stack on loopback.
    let mut workers = Vec::new();
    for _ in 0..3 {
        let coord = Arc::new(Coordinator::new(CoordinatorConfig::native_only())?);
        coord.register_model("ge", hmm.clone());
        let server = NetServer::start(
            Arc::clone(&coord),
            "127.0.0.1:0",
            NetServerConfig::default(),
        )?;
        let addr = server.local_addr().to_string();
        println!("worker up at {addr}");
        workers.push((coord, server, addr));
    }
    let addrs: Vec<String> = workers.iter().map(|w| w.2.clone()).collect();
    let router = Arc::new(ClusterRouter::new(ClusterConfig::new(addrs))?);
    let front = NetServer::start(
        Arc::clone(&router),
        "127.0.0.1:0",
        NetServerConfig::default(),
    )?;
    println!("router up at {}", front.local_addr());

    let control = Coordinator::new(CoordinatorConfig::native_only())?;
    control.register_model("ge", hmm.clone());
    let mut client = NetClient::connect(front.local_addr().to_string())?;
    client.ping()?;

    // Decode fan-out: every algorithm, bit-identical to the control.
    let mut rng = Xoshiro256StarStar::seed_from_u64(config.seed);
    let ys = sample(&hmm, t, &mut rng).observations;
    for algo in Algo::ALL {
        let req = DecodeRequest::new(1, "ge", ys.clone(), algo)
            .with_mode(ExecMode::Native);
        let remote = client.decode(&req)?;
        let want = control.decode(req)?;
        let ok = match (&remote.result, &want.result) {
            (DecodeResult::Posterior(a), DecodeResult::Posterior(b)) => a == b,
            (DecodeResult::Map(a), DecodeResult::Map(b)) => a == b,
            _ => false,
        };
        if !ok {
            return Err(Error::coordinator(format!(
                "cluster-demo: routed {algo:?} decode diverged from control"
            )));
        }
    }
    println!("decode fan-out OK: ×{} bit-identical", Algo::ALL.len());

    // Place sessions and feed the first half of the stream.
    let mut sessions = Vec::new();
    for _ in 0..n_sessions {
        let sid = client.open("ge", SessionOptions::default(), 8)?;
        let opened = control.stream(StreamRequest::open(0, "ge", 8))?;
        let StreamReply::Opened { session: ctl } = opened.reply else {
            return Err(Error::coordinator("control open failed"));
        };
        sessions.push((sid, ctl));
    }
    let (head, tail) = ys.split_at(ys.len() / 2);
    for &(sid, ctl) in &sessions {
        client.append(sid, head)?;
        control.stream(StreamRequest::append(0, ctl, head.to_vec()))?;
    }
    for &(sid, _) in &sessions {
        let home = router.session_home(sid).ok_or_else(|| {
            Error::coordinator("placed session has no route")
        })?;
        println!("session {sid} placed on {home}");
    }

    // Drain the worker serving the first session: every resident
    // session live-migrates (export → import → verified stat → cutover).
    let victim = router
        .session_home(sessions[0].0)
        .ok_or_else(|| Error::coordinator("no home for first session"))?;
    let moved = router.drain_worker(&victim)?;
    println!("drained {victim}: {moved} sessions live-migrated");

    // Keep serving after the drain; finish and verify bit-identity.
    for &(sid, ctl) in &sessions {
        client.append(sid, tail)?;
        control.stream(StreamRequest::append(0, ctl, tail.to_vec()))?;
        let routed = client.close(sid)?;
        let closed = control.stream(StreamRequest::close(0, ctl))?;
        let StreamReply::Closed { posterior: want, .. } = closed.reply else {
            return Err(Error::coordinator("control close failed"));
        };
        if routed != want {
            return Err(Error::coordinator(format!(
                "cluster-demo: migrated session {sid} diverged from control"
            )));
        }
    }
    println!(
        "post-drain serving OK: {n_sessions} migrated sessions finished \
         bit-identical to control"
    );

    let snap = router.metrics().snapshot();
    println!(
        "router: {} placed, {} migrated, {} failovers",
        snap.sessions_placed, snap.sessions_migrated, snap.decode_failovers
    );
    for link in &snap.worker_links {
        println!(
            "  worker {:<21} n={:<7} p50 {}µs  p99 {}µs  max {}µs",
            link.worker, link.count, link.p50_us, link.p99_us, link.max_us
        );
    }
    drop(client);
    front.shutdown(Duration::from_secs(5));
    drop(router);
    for (_, server, _) in workers {
        server.shutdown(Duration::from_secs(5));
    }
    Ok(())
}

fn cmd_figures(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let mut config = load_config(p)?;
    if let Some(out) = p.get("out") {
        config.out_dir = out.into();
    }
    let quick = p.flag("quick");
    std::fs::create_dir_all(&config.out_dir)?;
    let which = if p.flag("all") { "all" } else { p.get("fig").unwrap_or("all") };
    match which {
        "2" => println!("{}", hmm_scan::experiments::fig2(&config)?),
        "3" => {
            hmm_scan::experiments::fig3(&config, quick)?;
            println!("wrote {}", config.out_dir.join("fig3.csv").display());
        }
        "4" => {
            hmm_scan::experiments::fig4(&config)?;
            println!("wrote {}", config.out_dir.join("fig4.csv").display());
        }
        "5" => {
            hmm_scan::experiments::fig5(&config)?;
            println!("wrote {}", config.out_dir.join("fig5.csv").display());
        }
        "6" => {
            hmm_scan::experiments::fig6(&config)?;
            println!("wrote {}", config.out_dir.join("fig6.csv").display());
        }
        "table1" => println!("{}", hmm_scan::experiments::table1(&config, quick)?),
        "equiv" => {
            println!("{}", hmm_scan::experiments::equivalence_report(&config, quick)?)
        }
        "ablations" => {
            hmm_scan::experiments::ablation_block_len(&config, quick)?;
            hmm_scan::experiments::ablation_threads(&config, quick)?;
            println!("wrote ablation CSVs to {}", config.out_dir.display());
        }
        "all" => {
            let summary = hmm_scan::experiments::run_all(&config, quick)?;
            println!("{summary}");
            println!("all outputs in {}", config.out_dir.display());
        }
        other => return Err(Error::usage(format!("unknown figure '{other}'"))),
    }
    Ok(())
}

fn cmd_simulate(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let t = p.get_usize("t")?;
    let d = p.get_usize("d")?;
    let cores = p.get_usize("cores")?;
    let method = p.get("method").unwrap_or("SP-Par").to_string();
    if !hmm_scan::experiments::METHODS.contains(&method.as_str()) {
        return Err(Error::usage(format!(
            "unknown method '{method}' (expected one of {:?})",
            hmm_scan::experiments::METHODS
        )));
    }
    let mut dev = Device::gpu_3090_default();
    dev.cores = cores;
    let secs = hmm_scan::experiments::simulate_method(&method, t, d, &dev);
    println!("{method} T={t} D={d} cores={cores}: simulated {secs:.6}s");
    Ok(())
}

fn cmd_train(p: &hmm_scan::cli::Parsed) -> Result<()> {
    let config = load_config(p)?;
    let t = p.get_usize("t")?;
    let iters = p.get_usize("iters")?;
    let backend = match p.get("backend").unwrap_or("par") {
        "seq" => EStepBackend::Sequential,
        "par" => EStepBackend::ParallelScan,
        other => return Err(Error::usage(format!("unknown backend '{other}'"))),
    };
    let truth = gilbert_elliott(config.ge);
    let mut rng = Xoshiro256StarStar::seed_from_u64(config.seed);
    let tr = sample(&truth, t, &mut rng);
    // Perturbed initialization (the estimation task).
    let init = gilbert_elliott(hmm_scan::hmm::GeParams {
        p0: 0.1,
        p1: 0.2,
        p2: 0.15,
        q0: 0.05,
        q1: 0.2,
    });
    let mut engine = Engine::builder(init)
        .baum_welch_options(BaumWelchOptions {
            max_iters: iters,
            backend,
            ..Default::default()
        })
        .build();
    let res = engine.run(Algorithm::BaumWelch, &tr.observations)?.into_training()?;
    println!("iterations: {} (converged: {})", res.iterations, res.converged);
    for (i, ll) in res.loglik_curve.iter().enumerate() {
        println!("  iter {i:>3}: loglik {ll:.6}");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("hmm-scan — three-layer rust+JAX+Pallas HMM inference");
    let dir = hmm_scan::runtime::artifacts_dir();
    if dir.join("manifest.json").exists() {
        let manifest = hmm_scan::runtime::Manifest::load(&dir)?;
        println!("artifacts: {} at {}", manifest.artifacts().len(), dir.display());
        for a in manifest.artifacts() {
            println!(
                "  {:<36} entry={:<24} T={:<6} D={} M={}",
                a.name, a.entry, a.t, a.d, a.m
            );
        }
    } else {
        println!("artifacts: none (run `make artifacts`)");
    }
    println!("cpu parallelism: {}", hmm_scan::exec::default_parallelism());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn decode_native_smoke() {
        run(&argv("decode --t 200 --algo smooth --mode native")).unwrap();
        run(&argv("decode --t 50 --algo map --mode native")).unwrap();
        run(&argv("decode --t 50 --algo bayes --mode native")).unwrap();
    }

    #[test]
    fn simulate_smoke() {
        run(&argv("simulate --t 10000 --method MP-Par")).unwrap();
        assert!(run(&argv("simulate --method Bogus")).is_err());
    }

    #[test]
    fn usage_errors() {
        assert!(run(&argv("")).is_err());
        assert!(run(&argv("decode --algo nope")).is_err());
        assert!(run(&argv("decode --mode nope")).is_err());
        assert!(run(&argv("bench-net")).is_err(), "--connect is required");
        assert!(run(&argv("route")).is_err(), "--workers is required");
        assert!(run(&argv("stat")).is_err(), "--connect is required");
        assert!(run(&argv("replay")).is_err(), "--timeline is required");
        assert!(run(&argv("trace")).is_err(), "--merge is required");
    }

    #[test]
    fn replay_command_smoke() {
        use hmm_scan::obs::{Timeline, TimelineEvent};
        let dir = std::env::temp_dir()
            .join(format!("hmm-scan-replay-cmd-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        {
            let tl = Timeline::open(&dir).unwrap();
            tl.record(TimelineEvent::SessionOpen {
                session: 1,
                model: "ge".into(),
                len: 0,
            });
            tl.record(TimelineEvent::Append {
                session: 1,
                appended: 3,
                len: 3,
            });
            tl.record(TimelineEvent::ConnOpen { conn: 1 });
            tl.flush();
        }
        let cmd = format!("replay --timeline {}", dir.display());
        run(&argv(&cmd)).unwrap();
        run(&argv(&format!("{cmd} --until 1"))).unwrap();
        // An absent directory is a typed error, not a panic.
        let missing = dir.join("nope");
        assert!(run(&argv(&format!(
            "replay --timeline {}",
            missing.display()
        )))
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_command_smoke() {
        use hmm_scan::obs::span::StageSpan;
        use hmm_scan::obs::Timeline;
        let dir = std::env::temp_dir()
            .join(format!("hmm-scan-trace-cmd-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let rt = dir.join("rt");
        let wk = dir.join("wk");
        {
            let rt_tl = Timeline::open(&rt).unwrap();
            let wk_tl = Timeline::open(&wk).unwrap();
            // A cross-process pair: the worker's span parents the
            // router's, as the real dispatch path produces.
            let root = StageSpan::begin_root(Some(&rt_tl), "execute");
            let child = StageSpan::begin_under(
                Some(&wk_tl),
                root.trace(),
                root.id(),
                "execute",
            );
            child.finish();
            root.finish();
            // A torn trace: a begin that never closes (killed process).
            let open = StageSpan::begin_root(Some(&wk_tl), "queue");
            drop(open);
            rt_tl.flush();
            wk_tl.flush();
        }
        let cmd = format!("trace --merge {},{}", rt.display(), wk.display());
        run(&argv(&cmd)).unwrap();
        run(&argv(&format!("{cmd} --slow-only"))).unwrap();
        run(&argv(&format!("{cmd} --until 1"))).unwrap();
        // A missing directory is a typed error, not a panic.
        assert!(run(&argv(&format!(
            "trace --merge {}",
            dir.join("nope").display()
        )))
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cluster_demo_smoke() {
        run(&argv("cluster-demo --t 60 --sessions 2")).unwrap();
    }

    #[test]
    fn train_smoke() {
        run(&argv("train --t 200 --iters 3 --backend par")).unwrap();
    }
}

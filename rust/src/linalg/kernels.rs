//! Shape-specialized semiring matmul microkernels and batched SoA combines.
//!
//! Every algorithm in the stack — the paper's sum-product / max-product
//! scans (Eq. 16/42) and the Kalman tier alike — bottoms out in D×D `f64`
//! semiring matrix products inside [`AssocOp::combine`]. This module is
//! the raw-speed tier under [`matmul_into`]:
//!
//!   * [`spec_mm`] — a const-generic microkernel, monomorphized per
//!     semiring **and** per D ∈ {2, 4, 8, 16}. The compile-time shape
//!     lets the compiler fully unroll the j-loop and keep the output row
//!     in registers, which is what autovectorization needs. `Prob` gets
//!     a mul/add inner loop, `MaxPlus` gets add/max — two genuinely
//!     different instruction mixes (max-plus has no FMA form), produced
//!     from one source by monomorphization over the [`Semiring`] type.
//!   * [`batch_matmul_soa`] — a batched combine over a
//!     structure-of-arrays layout ([`SoaBatch`]): lane ℓ of the batch is
//!     one D×D matrix, and entry (r, c) of every lane is contiguous in
//!     memory. One pass over the contiguous lane runs combines a whole
//!     level-sweep of the tree scan at once.
//!
//! Both take the dispatch path behind [`matmul_into`] via
//! [`Semiring::specialized_matmul`]; shapes outside {2, 4, 8, 16} fall
//! back to [`matmul_into_generic`].
//!
//! **Bit-identity contract.** Every kernel here reproduces the generic
//! kernel bit-for-bit: the same k-ascending accumulation order, the same
//! `aik == S::zero()` annihilator skip (which is load-bearing — it keeps
//! `0 × ∞` from minting NaNs through structural zeros), and no FMA
//! contraction (Rust never auto-contracts `mul` + `add`). The
//! differential harness in this module's tests asserts `f64::to_bits`
//! equality against [`matmul_into_generic`] for both semirings over
//! adversarial inputs (±0.0, subnormals, ±∞, NaN). That contract is why
//! the kernels can be toggled freely: results never depend on which
//! path ran.
//!
//! **Toggle.** `HMM_SCAN_KERNELS=0|off|false|no` disables the tier at
//! process start; [`set_kernels_enabled`] flips it at runtime (used by
//! the differential tests and the force-on/force-off e2e regression).
//!
//! [`AssocOp::combine`]: crate::scan::AssocOp::combine
//! [`matmul_into`]: crate::linalg::matmul_into
//! [`matmul_into_generic`]: crate::linalg::matmul_into_generic
//! [`Semiring::specialized_matmul`]: crate::semiring::Semiring::specialized_matmul

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use crate::linalg::Mat;
use crate::semiring::Semiring;

/// Kernel-tier enable state: 0 = unset (read env on first use), 1 = on,
/// 2 = off. Relaxed ordering is fine — both paths are bit-identical, so
/// a racy flip can never change a result.
static MODE: AtomicU8 = AtomicU8::new(0);

static SPEC_D2: AtomicU64 = AtomicU64::new(0);
static SPEC_D4: AtomicU64 = AtomicU64::new(0);
static SPEC_D8: AtomicU64 = AtomicU64::new(0);
static SPEC_D16: AtomicU64 = AtomicU64::new(0);
static GENERIC: AtomicU64 = AtomicU64::new(0);
static BATCHED_CALLS: AtomicU64 = AtomicU64::new(0);
static BATCHED_LANES: AtomicU64 = AtomicU64::new(0);

/// Whether the specialized-kernel tier is active. First call reads the
/// `HMM_SCAN_KERNELS` environment variable; later calls are one relaxed
/// atomic load.
#[inline]
pub fn kernels_enabled() -> bool {
    match MODE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let v = std::env::var("HMM_SCAN_KERNELS");
            let on = env_enables(v.ok().as_deref());
            MODE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
            on
        }
    }
}

/// Force the kernel tier on or off for this process, overriding the
/// environment. Pure atomic store (no allocation), so tests can flip it
/// inside allocation-counting windows.
#[inline]
pub fn set_kernels_enabled(on: bool) {
    MODE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

/// Pure decision function for the `HMM_SCAN_KERNELS` variable: unset
/// means on; `0`, `off`, `false`, `no` (any case, surrounding
/// whitespace ignored) mean off; anything else means on.
pub(crate) fn env_enables(value: Option<&str>) -> bool {
    match value {
        None => true,
        Some(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "0" | "off" | "false" | "no"
        ),
    }
}

/// Point-in-time counts of which kernel served each combine. Counters
/// are process-wide (relaxed atomics bumped on the hot path) and
/// monotone; the metrics scrape surfaces them as `kernel_*` keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KernelStatsSnapshot {
    /// Calls served by the D=2 specialized kernel.
    pub spec_d2: u64,
    /// Calls served by the D=4 specialized kernel.
    pub spec_d4: u64,
    /// Calls served by the D=8 specialized kernel.
    pub spec_d8: u64,
    /// Calls served by the D=16 specialized kernel.
    pub spec_d16: u64,
    /// Calls that fell back to the generic kernel (non-specialized
    /// shape, non-square product, or kernels disabled).
    pub generic: u64,
    /// Batched SoA combine invocations.
    pub batched_calls: u64,
    /// Total lanes (element pairs) combined across all batched calls.
    pub batched_lanes: u64,
}

/// Snapshot the process-wide kernel counters.
pub fn kernel_stats() -> KernelStatsSnapshot {
    KernelStatsSnapshot {
        spec_d2: SPEC_D2.load(Ordering::Relaxed),
        spec_d4: SPEC_D4.load(Ordering::Relaxed),
        spec_d8: SPEC_D8.load(Ordering::Relaxed),
        spec_d16: SPEC_D16.load(Ordering::Relaxed),
        generic: GENERIC.load(Ordering::Relaxed),
        batched_calls: BATCHED_CALLS.load(Ordering::Relaxed),
        batched_lanes: BATCHED_LANES.load(Ordering::Relaxed),
    }
}

/// Record one generic-kernel fallback (called by `matmul_into`).
#[inline]
pub(crate) fn note_generic() {
    GENERIC.fetch_add(1, Ordering::Relaxed);
}

/// Whether a square shape has a specialized kernel.
#[inline]
pub fn specializes(d: usize) -> bool {
    matches!(d, 2 | 4 | 8 | 16)
}

/// Shape-dispatch entry point: run the specialized kernel for a square
/// D×D product if one exists and the tier is enabled. Returns `false`
/// (buffers untouched) when the caller should fall back to the generic
/// kernel. Slices are row-major D×D.
#[inline]
pub fn dispatch<S: Semiring>(d: usize, a: &[f64], b: &[f64], out: &mut [f64]) -> bool {
    if !kernels_enabled() {
        return false;
    }
    match d {
        2 => {
            SPEC_D2.fetch_add(1, Ordering::Relaxed);
            spec_mm::<S, 2>(a, b, out);
            true
        }
        4 => {
            SPEC_D4.fetch_add(1, Ordering::Relaxed);
            spec_mm::<S, 4>(a, b, out);
            true
        }
        8 => {
            SPEC_D8.fetch_add(1, Ordering::Relaxed);
            spec_mm::<S, 8>(a, b, out);
            true
        }
        16 => {
            SPEC_D16.fetch_add(1, Ordering::Relaxed);
            spec_mm::<S, 16>(a, b, out);
            true
        }
        _ => false,
    }
}

/// Const-generic D×D semiring matmul microkernel: `out = a ⋆ b`.
///
/// Monomorphized per (semiring, D), so the compiler sees fixed trip
/// counts: the row accumulator `[f64; D]` stays in registers and the
/// inner `zip` over `&[f64; D]` unrolls/vectorizes. The accumulation is
/// k-ascending with the generic kernel's annihilator skip, so results
/// are bit-identical to [`matmul_into_generic`] — including when `out`
/// aliases neither, one, or both inputs *by value* (the accumulator
/// makes the kernel safe for `a ⋆ a` into a distinct buffer; Rust's
/// borrow rules already forbid true slice aliasing).
///
/// [`matmul_into_generic`]: crate::linalg::matmul_into_generic
pub fn spec_mm<S: Semiring, const D: usize>(a: &[f64], b: &[f64], out: &mut [f64]) {
    assert_eq!(a.len(), D * D, "spec_mm: a is not DxD");
    assert_eq!(b.len(), D * D, "spec_mm: b is not DxD");
    assert_eq!(out.len(), D * D, "spec_mm: out is not DxD");
    for (arow, orow) in a.chunks_exact(D).zip(out.chunks_exact_mut(D)) {
        let mut acc = [S::zero(); D];
        for (k, &aik) in arow.iter().enumerate() {
            if aik == S::zero() {
                continue; // annihilator: skip the whole row of b
            }
            let brow: &[f64; D] = b[k * D..k * D + D].try_into().unwrap();
            for (o, &bkj) in acc.iter_mut().zip(brow) {
                *o = S::add(*o, S::mul(aik, bkj));
            }
        }
        orow.copy_from_slice(&acc);
    }
}

/// A batch of D×D matrices in structure-of-arrays layout: entry (r, c)
/// of lane ℓ lives at `data[(r·D + c)·lanes + ℓ]`, so a fixed matrix
/// entry across all lanes is one contiguous run. That is the layout
/// [`batch_matmul_soa`] streams over — the batched analogue of packing
/// a whole tree-scan level into one kernel call.
#[derive(Debug, Clone, PartialEq)]
pub struct SoaBatch {
    d: usize,
    lanes: usize,
    data: Vec<f64>,
}

impl SoaBatch {
    /// An all-zero batch of `lanes` D×D matrices.
    pub fn zeros(d: usize, lanes: usize) -> Self {
        Self { d, lanes, data: vec![0.0; d * d * lanes] }
    }

    /// Matrix dimension D.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of lanes (matrices) in the batch.
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The SoA backing buffer (length D·D·lanes).
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Scatter one row-major D×D matrix into lane `lane`.
    pub fn set_lane(&mut self, lane: usize, m: &Mat) {
        assert!(lane < self.lanes, "lane {lane} out of range");
        assert_eq!((m.rows(), m.cols()), (self.d, self.d), "lane shape mismatch");
        for (idx, &v) in m.data().iter().enumerate() {
            self.data[idx * self.lanes + lane] = v;
        }
    }

    /// Gather lane `lane` back into a row-major D×D matrix.
    pub fn lane_into(&self, lane: usize, out: &mut Mat) {
        assert!(lane < self.lanes, "lane {lane} out of range");
        assert_eq!((out.rows(), out.cols()), (self.d, self.d), "lane shape mismatch");
        for (idx, v) in out.data_mut().iter_mut().enumerate() {
            *v = self.data[idx * self.lanes + lane];
        }
    }
}

/// Batched semiring matmul over SoA batches: for every lane ℓ,
/// `out[ℓ] = a[ℓ] ⋆ b[ℓ]`.
///
/// The loop nest is (i, k, j, lane) with the lane loop innermost over
/// three contiguous runs — a vector-friendly shape (the per-lane
/// annihilator skip compiles to a select). Per lane, the operations and
/// their order are exactly the scalar kernel's (k ascending, zero
/// skip), so each lane is bit-identical to [`matmul_into_generic`] on
/// that lane's matrices.
///
/// [`matmul_into_generic`]: crate::linalg::matmul_into_generic
pub fn batch_matmul_soa<S: Semiring>(a: &SoaBatch, b: &SoaBatch, out: &mut SoaBatch) {
    let (d, lanes) = (a.d, a.lanes);
    assert_eq!((b.d, b.lanes), (d, lanes), "batch shape mismatch");
    assert_eq!((out.d, out.lanes), (d, lanes), "batch shape mismatch");
    BATCHED_CALLS.fetch_add(1, Ordering::Relaxed);
    BATCHED_LANES.fetch_add(lanes as u64, Ordering::Relaxed);
    out.data.fill(S::zero());
    if lanes == 0 {
        return;
    }
    for i in 0..d {
        for k in 0..d {
            let arun = &a.data[(i * d + k) * lanes..(i * d + k + 1) * lanes];
            for j in 0..d {
                let brun = &b.data[(k * d + j) * lanes..(k * d + j + 1) * lanes];
                let orun = &mut out.data[(i * d + j) * lanes..(i * d + j + 1) * lanes];
                for ((o, &av), &bv) in orun.iter_mut().zip(arun).zip(brun) {
                    if av == S::zero() {
                        continue; // same annihilator skip, per lane
                    }
                    *o = S::add(*o, S::mul(av, bv));
                }
            }
        }
    }
}

/// Serializes tests that flip the process-wide kernel toggle. Every
/// test that calls [`set_kernels_enabled`] must hold this guard for its
/// whole body, or parallel `cargo test` runs will race on [`MODE`].
#[cfg(test)]
pub(crate) fn toggle_guard() -> std::sync::MutexGuard<'static, ()> {
    use std::sync::{Mutex, PoisonError};
    static TOGGLE_LOCK: Mutex<()> = Mutex::new(());
    TOGGLE_LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_into, matmul_into_generic};
    use crate::proptestx::{assert_bits_eq, gen, Runner};
    use crate::semiring::{MaxPlus, Prob};

    fn log_domain<S: Semiring>() -> bool {
        S::zero() == f64::NEG_INFINITY
    }

    /// The differential harness: specialized kernel vs generic kernel,
    /// bit-for-bit, over adversarial matrices, with the output buffer
    /// pre-poisoned with NaN and an `a ⋆ a` same-input pattern.
    fn spec_vs_generic<S: Semiring, const D: usize>() {
        let mut runner = Runner::new(&format!("kernel-diff-{}-d{}", S::NAME, D));
        runner.run(200, |r| {
            let a = gen::adversarial_matrix(r, D, log_domain::<S>());
            let b = gen::adversarial_matrix(r, D, log_domain::<S>());
            let mut got = vec![f64::NAN; D * D];
            spec_mm::<S, D>(&a, &b, &mut got);
            let am = Mat::from_vec(D, D, a.clone());
            let bm = Mat::from_vec(D, D, b.clone());
            let mut want = Mat::filled(D, D, f64::NAN);
            matmul_into_generic::<S>(&am, &bm, &mut want);
            assert_bits_eq(&format!("{} d={} a*b", S::NAME, D), &got, want.data());
            // Same-input pattern: a ⋆ a (the up-sweep combines an
            // element with itself at degenerate tree shapes).
            let mut got_aa = vec![f64::NAN; D * D];
            spec_mm::<S, D>(&a, &a, &mut got_aa);
            let mut want_aa = Mat::filled(D, D, f64::NAN);
            matmul_into_generic::<S>(&am, &am, &mut want_aa);
            assert_bits_eq(&format!("{} d={} a*a", S::NAME, D), &got_aa, want_aa.data());
        });
    }

    #[test]
    fn differential_prob_all_specialized_shapes() {
        spec_vs_generic::<Prob, 2>();
        spec_vs_generic::<Prob, 4>();
        spec_vs_generic::<Prob, 8>();
        spec_vs_generic::<Prob, 16>();
    }

    #[test]
    fn differential_maxplus_all_specialized_shapes() {
        spec_vs_generic::<MaxPlus, 2>();
        spec_vs_generic::<MaxPlus, 4>();
        spec_vs_generic::<MaxPlus, 8>();
        spec_vs_generic::<MaxPlus, 16>();
    }

    #[test]
    fn dispatch_covers_exactly_the_specialized_shapes() {
        let _guard = toggle_guard();
        set_kernels_enabled(true);
        for d in [2usize, 4, 8, 16] {
            assert!(specializes(d));
            let a = vec![0.5; d * d];
            let b = vec![0.25; d * d];
            let mut out = vec![f64::NAN; d * d];
            assert!(dispatch::<Prob>(d, &a, &b, &mut out));
        }
        for d in [1usize, 3, 5, 17, 64] {
            assert!(!specializes(d));
            let a = vec![0.5; d * d];
            let b = vec![0.25; d * d];
            let mut out = vec![f64::NAN; d * d];
            assert!(!dispatch::<Prob>(d, &a, &b, &mut out));
            // fallback contract: buffers untouched on false
            assert!(out.iter().all(|v| v.is_nan()));
        }
        set_kernels_enabled(true);
    }

    #[test]
    fn matmul_into_identical_across_dispatch_boundary() {
        // D ∈ {1, 3, 5, 17, 64} take the generic path; D ∈ {2, 4, 8, 16}
        // the specialized one. All must agree bitwise with the generic
        // kernel called directly.
        let _guard = toggle_guard();
        set_kernels_enabled(true);
        let mut runner = Runner::new("kernel-boundary");
        runner.run(40, |r| {
            for d in [1usize, 2, 3, 4, 5, 8, 16, 17, 64] {
                let a = Mat::from_vec(d, d, gen::adversarial_matrix(r, d, false));
                let b = Mat::from_vec(d, d, gen::adversarial_matrix(r, d, false));
                let mut via_dispatch = Mat::filled(d, d, f64::NAN);
                matmul_into::<Prob>(&a, &b, &mut via_dispatch);
                let mut via_generic = Mat::filled(d, d, f64::NAN);
                matmul_into_generic::<Prob>(&a, &b, &mut via_generic);
                assert_bits_eq(
                    &format!("boundary d={d}"),
                    via_dispatch.data(),
                    via_generic.data(),
                );
            }
        });
        set_kernels_enabled(true);
    }

    #[test]
    fn dispatch_counters_are_monotone() {
        let _guard = toggle_guard();
        set_kernels_enabled(true);
        let before = kernel_stats();
        let a = Mat::identity::<Prob>(4);
        let b = Mat::identity::<Prob>(4);
        let mut out = Mat::zeros(4, 4);
        matmul_into::<Prob>(&a, &b, &mut out);
        let g = Mat::identity::<Prob>(3);
        let mut gout = Mat::zeros(3, 3);
        matmul_into::<Prob>(&g, &g, &mut gout);
        let after = kernel_stats();
        assert!(after.spec_d4 >= before.spec_d4 + 1);
        assert!(after.generic >= before.generic + 1);
        set_kernels_enabled(true);
    }

    #[test]
    fn batched_soa_matches_scalar_kernel_per_lane() {
        // Seeded sweep over batch shapes, including the degenerate
        // lanes = 0 and 1 and odd / non-power-of-two lane counts that a
        // non-power-of-two tree level produces.
        let mut runner = Runner::new("kernel-soa");
        for &(d, lanes) in &[
            (2usize, 0usize),
            (2, 1),
            (2, 7),
            (3, 5),
            (4, 1),
            (4, 13),
            (5, 3),
            (8, 9),
            (16, 2),
        ] {
            runner.run(20, |r| {
                let mats_a: Vec<Mat> = (0..lanes)
                    .map(|_| Mat::from_vec(d, d, gen::adversarial_matrix(r, d, false)))
                    .collect();
                let mats_b: Vec<Mat> = (0..lanes)
                    .map(|_| Mat::from_vec(d, d, gen::adversarial_matrix(r, d, false)))
                    .collect();
                let mut a = SoaBatch::zeros(d, lanes);
                let mut b = SoaBatch::zeros(d, lanes);
                for (lane, (ma, mb)) in mats_a.iter().zip(&mats_b).enumerate() {
                    a.set_lane(lane, ma);
                    b.set_lane(lane, mb);
                }
                let mut out = SoaBatch::zeros(d, lanes);
                batch_matmul_soa::<Prob>(&a, &b, &mut out);
                let mut got = Mat::zeros(d, d);
                let mut want = Mat::filled(d, d, f64::NAN);
                for (lane, (ma, mb)) in mats_a.iter().zip(&mats_b).enumerate() {
                    out.lane_into(lane, &mut got);
                    matmul_into_generic::<Prob>(ma, mb, &mut want);
                    assert_bits_eq(&format!("soa d={d} lane {lane}"), got.data(), want.data());
                }
            });
        }
    }

    #[test]
    fn batched_soa_maxplus_matches_scalar_kernel() {
        let mut runner = Runner::new("kernel-soa-maxplus");
        runner.run(40, |r| {
            let (d, lanes) = (4usize, 11usize);
            let mats_a: Vec<Mat> = (0..lanes)
                .map(|_| Mat::from_vec(d, d, gen::adversarial_matrix(r, d, true)))
                .collect();
            let mats_b: Vec<Mat> = (0..lanes)
                .map(|_| Mat::from_vec(d, d, gen::adversarial_matrix(r, d, true)))
                .collect();
            let mut a = SoaBatch::zeros(d, lanes);
            let mut b = SoaBatch::zeros(d, lanes);
            for (lane, (ma, mb)) in mats_a.iter().zip(&mats_b).enumerate() {
                a.set_lane(lane, ma);
                b.set_lane(lane, mb);
            }
            let mut out = SoaBatch::zeros(d, lanes);
            batch_matmul_soa::<MaxPlus>(&a, &b, &mut out);
            let mut got = Mat::zeros(d, d);
            let mut want = Mat::filled(d, d, f64::NAN);
            for (lane, (ma, mb)) in mats_a.iter().zip(&mats_b).enumerate() {
                out.lane_into(lane, &mut got);
                matmul_into_generic::<MaxPlus>(ma, mb, &mut want);
                assert_bits_eq(
                    &format!("soa maxplus lane {lane}"),
                    got.data(),
                    want.data(),
                );
            }
        });
    }

    #[test]
    fn soa_lane_round_trip() {
        let m = Mat::from_vec(2, 2, vec![1.0, -0.0, f64::INFINITY, 5e-324]);
        let mut batch = SoaBatch::zeros(2, 3);
        batch.set_lane(1, &m);
        let mut back = Mat::zeros(2, 2);
        batch.lane_into(1, &mut back);
        assert_bits_eq("soa round trip", back.data(), m.data());
        // untouched lanes stay zero
        batch.lane_into(0, &mut back);
        assert!(back.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn toggle_disables_and_reenables_dispatch() {
        let _guard = toggle_guard();
        set_kernels_enabled(false);
        let a = vec![1.0; 4];
        let b = vec![1.0; 4];
        let mut out = vec![f64::NAN; 4];
        assert!(!dispatch::<Prob>(2, &a, &b, &mut out));
        set_kernels_enabled(true);
        assert!(dispatch::<Prob>(2, &a, &b, &mut out));
        assert!(kernels_enabled());
    }

    #[test]
    fn env_decision_table() {
        assert!(env_enables(None));
        assert!(env_enables(Some("1")));
        assert!(env_enables(Some("on")));
        assert!(env_enables(Some("anything")));
        assert!(!env_enables(Some("0")));
        assert!(!env_enables(Some("off")));
        assert!(!env_enables(Some("OFF")));
        assert!(!env_enables(Some("false")));
        assert!(!env_enables(Some(" no ")));
    }
}

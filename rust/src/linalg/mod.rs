//! Dense matrices over a [`Semiring`](crate::semiring::Semiring).
//!
//! The paper's elements a_{i:j} are D×D potential matrices and both of
//! its associative operators are semiring matrix products (Eq. 16 over
//! (+,×); Eq. 42 over (max,×) / (max,+)). This module provides the
//! storage type and the (small-D, cache-friendly) product kernels the
//! scan and the inference algorithms build on.

use std::fmt;

use crate::semiring::Semiring;

pub mod kernels;

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows {
            writeln!(f, "  {:?}", &self.data[r * self.cols..(r + 1) * self.cols])?;
        }
        write!(f, "]")
    }
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Constant-filled matrix.
    pub fn filled(rows: usize, cols: usize, v: f64) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    /// Wrap a row-major buffer (length must be rows·cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Multiplicative identity of semiring `S` (S::one on the diagonal,
    /// S::zero elsewhere).
    pub fn identity<S: Semiring>(d: usize) -> Self {
        let mut m = Self::filled(d, d, S::zero());
        for i in 0..d {
            m[(i, i)] = S::one();
        }
        m
    }

    /// All-entries S::one matrix (the paper's terminal element ψ_{T,T+1}=1).
    pub fn all_one<S: Semiring>(rows: usize, cols: usize) -> Self {
        Self::filled(rows, cols, S::one())
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The row-major backing buffer.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable row-major backing buffer.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// One row as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// One column, copied out.
    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// The transposed matrix (copied).
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Largest absolute entry (0 for an empty matrix).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }

    /// Largest entry (−∞ for an empty matrix).
    pub fn max(&self) -> f64 {
        self.data.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v))
    }

    /// Scale every entry (linear domain).
    pub fn scale(&mut self, s: f64) {
        self.data.iter_mut().for_each(|v| *v *= s);
    }

    /// Add a constant to every entry (log domain rescale).
    pub fn shift(&mut self, s: f64) {
        self.data.iter_mut().for_each(|v| *v += s);
    }

    /// `C = A ∘ B` (entrywise semiring mul).
    pub fn hadamard<S: Semiring>(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| S::mul(a, b))
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Semiring matrix product `self ⋆ other`.
    ///
    /// ikj loop order: the inner loop runs over contiguous rows of both
    /// the output and `other`, which is the hot path of every combine —
    /// see EXPERIMENTS.md §Perf.
    pub fn matmul<S: Semiring>(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "inner dimension mismatch");
        let mut out = Mat::filled(self.rows, other.cols, S::zero());
        matmul_into::<S>(self, other, &mut out);
        out
    }

    /// Semiring vector-matrix product `v ⋆ self` (row vector).
    pub fn vecmat<S: Semiring>(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![S::zero(); self.cols];
        for (i, &vi) in v.iter().enumerate() {
            let row = self.row(i);
            for (o, &m) in out.iter_mut().zip(row) {
                *o = S::add(*o, S::mul(vi, m));
            }
        }
        out
    }

    /// Semiring matrix-vector product `self ⋆ v` (column vector).
    pub fn matvec<S: Semiring>(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows)
            .map(|r| {
                self.row(r)
                    .iter()
                    .zip(v)
                    .fold(S::zero(), |acc, (&m, &x)| S::add(acc, S::mul(m, x)))
            })
            .collect()
    }

    /// Argmax version of `vecmat` over a tropical semiring (`add` = max):
    /// per output column, the extremal value `max_i v[i] ⋆ self[i,c]` and
    /// the first index achieving it (the Viterbi `u` function).
    pub fn vecmat_argmax<S: Semiring>(&self, v: &[f64]) -> (Vec<f64>, Vec<usize>) {
        assert_eq!(v.len(), self.rows);
        let mut best = vec![S::zero(); self.cols];
        let mut arg = vec![0usize; self.cols];
        for (i, &vi) in v.iter().enumerate() {
            let row = self.row(i);
            for c in 0..self.cols {
                let cand = S::mul(vi, row[c]);
                if i == 0 || cand > best[c] {
                    best[c] = cand;
                    arg[c] = i;
                }
            }
        }
        (best, arg)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

/// `out = a ⋆ b` without allocating (out must be pre-shaped and is
/// overwritten).
///
/// Square D×D products with D ∈ {2, 4, 8, 16} are served by the
/// const-generic microkernels in [`kernels`] (per-semiring, via
/// [`Semiring::specialized_matmul`]); everything else falls through to
/// [`matmul_into_generic`]. Both paths are bit-identical — see the
/// kernel module's differential harness — so callers never observe
/// which one ran except through the [`kernels::kernel_stats`] counters.
pub fn matmul_into<S: Semiring>(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((out.rows, out.cols), (a.rows, b.cols));
    let d = a.rows;
    if d == a.cols && d == b.cols && S::specialized_matmul(d, &a.data, &b.data, &mut out.data) {
        return;
    }
    kernels::note_generic();
    matmul_into_generic::<S>(a, b, out);
}

/// The reference ikj kernel behind [`matmul_into`]: works for any
/// shape, keeps the inner loop contiguous, and skips `S::zero()` rows
/// of `a` (the annihilator shortcut that also keeps structural zeros
/// from minting NaNs via `0 × ∞`). The specialized kernels are defined
/// to match this function bit-for-bit.
pub fn matmul_into_generic<S: Semiring>(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((out.rows, out.cols), (a.rows, b.cols));
    out.data.fill(S::zero());
    let n = b.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            if aik == S::zero() {
                continue; // annihilator: skip the whole row of b
            }
            let brow = &b.data[k * n..(k + 1) * n];
            for (o, &bkj) in orow.iter_mut().zip(brow) {
                *o = S::add(*o, S::mul(aik, bkj));
            }
        }
    }
}

/// Normalize `v` to sum 1 (linear domain). Returns the pre-normalization
/// sum; if the sum is zero the vector is left unchanged and 0 returned.
pub fn normalize_sum(v: &mut [f64]) -> f64 {
    let s: f64 = v.iter().sum();
    if s > 0.0 {
        v.iter_mut().for_each(|x| *x /= s);
    }
    s
}

/// Row-pivoted LU factorization `P·A = L·U` with a tiny-pivot guard —
/// the solve kernel behind the Kalman-tier combines (`kalman::KfOp`).
///
/// The factorization and every solve are *total*: a singular (or
/// garbage — NaN/Inf) input never panics or divides by exact zero.
/// Pivots whose magnitude falls below a threshold scaled to the
/// matrix's largest entry are replaced by the signed threshold, so the
/// solves keep producing (possibly nonsensical, but finite-operation)
/// output — exactly the contract `scan::AssocOp::combine` needs, since
/// a scan must never panic mid-tree. Well-conditioned inputs are
/// untouched by the guard and solve to ordinary partial-pivoting
/// accuracy.
#[derive(Debug, Clone)]
pub struct Lu {
    /// L (unit diagonal, strictly below) and U (on/above) packed in one
    /// matrix.
    lu: Mat,
    /// Row permutation: `(P·A)[i, j] = A[perm[i], j]`.
    perm: Vec<usize>,
}

impl Lu {
    /// Factor a square matrix. See the type docs for the pivot guard.
    pub fn factor(a: &Mat) -> Lu {
        assert_eq!(a.rows(), a.cols(), "LU factorization needs a square matrix");
        let n = a.rows();
        // Guard scaled to the matrix magnitude; MIN_POSITIVE floor keeps
        // the all-zero (and non-finite) cases total too.
        let scale = a.max_abs();
        let guard = if scale.is_finite() && scale > 0.0 {
            (scale * f64::EPSILON).max(f64::MIN_POSITIVE)
        } else {
            f64::MIN_POSITIVE
        };
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // Partial pivot: largest |entry| in column k at or below the
            // diagonal (NaN entries compare false and are skipped).
            let mut p = k;
            let mut best = lu[(k, k)].abs();
            for r in k + 1..n {
                let v = lu[(r, k)].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if p != k {
                perm.swap(p, k);
                for c in 0..n {
                    let tmp = lu[(k, c)];
                    lu[(k, c)] = lu[(p, c)];
                    lu[(p, c)] = tmp;
                }
            }
            let mut piv = lu[(k, k)];
            if !(piv.abs() > guard) {
                // Singular / tiny / NaN pivot: substitute the signed
                // guard so elimination and the solves stay total.
                piv = if piv < 0.0 { -guard } else { guard };
                lu[(k, k)] = piv;
            }
            for r in k + 1..n {
                let m = lu[(r, k)] / piv;
                lu[(r, k)] = m;
                if m == 0.0 {
                    continue;
                }
                for c in k + 1..n {
                    lu[(r, c)] -= m * lu[(k, c)];
                }
            }
        }
        Lu { lu, perm }
    }

    /// Matrix dimension n.
    pub fn dim(&self) -> usize {
        self.lu.rows()
    }

    /// `log |det A|` — the sum of log-magnitudes of the U diagonal
    /// (guarded pivots included), as the Gaussian log-likelihood needs.
    pub fn ln_abs_det(&self) -> f64 {
        (0..self.dim()).map(|i| self.lu[(i, i)].abs().ln()).sum()
    }

    /// Solve `A·x = b`.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.dim();
        assert_eq!(b.len(), n, "rhs length mismatch");
        // y ← P·b, then forward-substitute L·y' = y (unit diagonal).
        let mut y: Vec<f64> = (0..n).map(|i| b[self.perm[i]]).collect();
        for i in 1..n {
            let mut acc = y[i];
            for k in 0..i {
                let l = self.lu[(i, k)];
                if l == 0.0 {
                    continue; // exact-zero skip keeps identity solves exact
                }
                acc -= l * y[k];
            }
            y[i] = acc;
        }
        // Back-substitute U·x = y.
        for i in (0..n).rev() {
            let mut acc = y[i];
            for c in i + 1..n {
                let u = self.lu[(i, c)];
                if u == 0.0 {
                    continue;
                }
                acc -= u * y[c];
            }
            y[i] = acc / self.lu[(i, i)];
        }
        y
    }

    /// Solve `A·X = B` column-wise (B may be rectangular n×m).
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.dim();
        assert_eq!(b.rows(), n, "rhs row-count mismatch");
        let mut out = Mat::zeros(n, b.cols());
        for c in 0..b.cols() {
            let col = b.col(c);
            let x = self.solve_vec(&col);
            for (r, v) in x.into_iter().enumerate() {
                out[(r, c)] = v;
            }
        }
        out
    }

    /// Solve `Aᵀ·x = b` (transpose solve, no refactorization): since
    /// `Aᵀ = Uᵀ·Lᵀ·P`, forward-substitute `Uᵀ·z = b`, back-substitute
    /// `Lᵀ·w = z`, then un-permute `x[perm[i]] = w[i]`.
    pub fn solve_transpose_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.dim();
        assert_eq!(b.len(), n, "rhs length mismatch");
        // Uᵀ is lower-triangular with the U diagonal.
        let mut z = b.to_vec();
        for i in 0..n {
            let mut acc = z[i];
            for k in 0..i {
                let u = self.lu[(k, i)];
                if u == 0.0 {
                    continue;
                }
                acc -= u * z[k];
            }
            z[i] = acc / self.lu[(i, i)];
        }
        // Lᵀ is upper-triangular with a unit diagonal.
        for i in (0..n).rev() {
            let mut acc = z[i];
            for k in i + 1..n {
                let l = self.lu[(k, i)];
                if l == 0.0 {
                    continue;
                }
                acc -= l * z[k];
            }
            z[i] = acc;
        }
        let mut x = vec![0.0; n];
        for (i, v) in z.into_iter().enumerate() {
            x[self.perm[i]] = v;
        }
        x
    }

    /// Solve `Aᵀ·X = B` column-wise.
    pub fn solve_transpose_mat(&self, b: &Mat) -> Mat {
        let n = self.dim();
        assert_eq!(b.rows(), n, "rhs row-count mismatch");
        let mut out = Mat::zeros(n, b.cols());
        for c in 0..b.cols() {
            let col = b.col(c);
            let x = self.solve_transpose_vec(&col);
            for (r, v) in x.into_iter().enumerate() {
                out[(r, c)] = v;
            }
        }
        out
    }
}

/// Index of the maximum element (first maximizer on ties).
pub fn argmax(v: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate().skip(1) {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptestx::{gen, Runner};
    use crate::semiring::{MaxPlus, MaxTimes, Prob};

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-10 * (1.0 + a.abs().max(b.abs()))
    }

    fn mats_close(a: &Mat, b: &Mat) -> bool {
        a.rows() == b.rows()
            && a.cols() == b.cols()
            && a.data().iter().zip(b.data()).all(|(&x, &y)| close(x, y))
    }

    #[test]
    fn identity_is_neutral() {
        let mut runner = Runner::new("linalg-identity");
        runner.run(50, |r| {
            let d = 1 + r.below(6) as usize;
            let a = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let i = Mat::identity::<Prob>(d);
            assert!(mats_close(&a.matmul::<Prob>(&i), &a));
            assert!(mats_close(&i.matmul::<Prob>(&a), &a));
        });
    }

    #[test]
    fn matmul_associative_prob_and_tropical() {
        let mut runner = Runner::new("linalg-assoc");
        runner.run(50, |r| {
            let d = 2 + r.below(5) as usize;
            let a = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let b = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let c = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let l = a.matmul::<Prob>(&b).matmul::<Prob>(&c);
            let rr = a.matmul::<Prob>(&b.matmul::<Prob>(&c));
            assert!(mats_close(&l, &rr));
            let l = a.matmul::<MaxTimes>(&b).matmul::<MaxTimes>(&c);
            let rr = a.matmul::<MaxTimes>(&b.matmul::<MaxTimes>(&c));
            assert!(mats_close(&l, &rr));
        });
    }

    #[test]
    fn prob_matmul_known_values() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul::<Prob>(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn maxplus_matmul_known_values() {
        let a = Mat::from_vec(2, 2, vec![0.0, -1.0, -2.0, 0.0]);
        let b = Mat::from_vec(2, 2, vec![0.0, -3.0, -1.0, 0.0]);
        let c = a.matmul::<MaxPlus>(&b);
        // c[0,0] = max(0+0, -1+-1) = 0 ; c[0,1] = max(0-3, -1+0) = -1
        // c[1,0] = max(-2+0, 0-1) = -1 ; c[1,1] = max(-2-3, 0+0) = 0
        assert_eq!(c.data(), &[0.0, -1.0, -1.0, 0.0]);
    }

    #[test]
    fn rectangular_matmul_shapes() {
        let a = Mat::filled(2, 3, 1.0);
        let b = Mat::filled(3, 4, 2.0);
        let c = a.matmul::<Prob>(&b);
        assert_eq!((c.rows(), c.cols()), (2, 4));
        assert!(c.data().iter().all(|&v| close(v, 6.0)));
    }

    #[test]
    fn vecmat_matvec_match_matmul() {
        let mut runner = Runner::new("linalg-vec");
        runner.run(50, |r| {
            let d = 1 + r.below(6) as usize;
            let a = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let v = gen::prob_vector(r, d);
            // v as 1×d matrix
            let vm = Mat::from_vec(1, d, v.clone());
            let via_mat = vm.matmul::<Prob>(&a);
            let direct = a.vecmat::<Prob>(&v);
            assert!(via_mat.data().iter().zip(&direct).all(|(&x, &y)| close(x, y)));
            let vm2 = Mat::from_vec(d, 1, v.clone());
            let via_mat2 = a.matmul::<Prob>(&vm2);
            let direct2 = a.matvec::<Prob>(&v);
            assert!(via_mat2.data().iter().zip(&direct2).all(|(&x, &y)| close(x, y)));
        });
    }

    #[test]
    fn vecmat_argmax_consistent() {
        let mut runner = Runner::new("linalg-argmax");
        runner.run(50, |r| {
            let d = 2 + r.below(5) as usize;
            let a = Mat::from_vec(
                d,
                d,
                (0..d * d).map(|_| r.uniform(-5.0, 0.0)).collect(),
            );
            let v: Vec<f64> = (0..d).map(|_| r.uniform(-5.0, 0.0)).collect();
            let (best, arg) = a.vecmat_argmax::<MaxPlus>(&v);
            let plain = a.transpose().matvec::<MaxPlus>(&v);
            for c in 0..d {
                assert!(close(best[c], plain[c]));
                assert!(close(v[arg[c]] + a[(arg[c], c)], best[c]));
            }
        });
    }

    #[test]
    fn zero_annihilator_shortcut_is_correct() {
        // matmul_into skips S::zero() entries; verify against a naive
        // product on a sparse matrix.
        let a = Mat::from_vec(2, 2, vec![0.0, 2.0, 0.0, 0.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 3.0, 4.0]);
        let c = a.matmul::<Prob>(&b);
        assert_eq!(c.data(), &[6.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    fn normalize_and_argmax_helpers() {
        let mut v = vec![1.0, 3.0];
        assert!(close(normalize_sum(&mut v), 4.0));
        assert!(close(v[0], 0.25) && close(v[1], 0.75));
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize_sum(&mut z), 0.0);
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1); // first maximizer
    }

    #[test]
    fn transpose_row_col() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = a.transpose();
        assert_eq!((t.rows(), t.cols()), (3, 2));
        assert_eq!(t.row(1), &[2.0, 5.0]);
        assert_eq!(a.col(2), vec![3.0, 6.0]);
    }

    /// A well-conditioned random matrix: random entries plus a dominant
    /// diagonal, so the LU solves should hit ordinary accuracy.
    fn dominant_matrix(r: &mut crate::rng::Xoshiro256StarStar, d: usize) -> Mat {
        let mut m = Mat::from_vec(
            d,
            d,
            (0..d * d).map(|_| r.uniform(-1.0, 1.0)).collect(),
        );
        for i in 0..d {
            m[(i, i)] += d as f64 + 1.0;
        }
        m
    }

    #[test]
    fn lu_solve_round_trips() {
        let mut runner = Runner::new("linalg-lu-solve");
        runner.run(50, |r| {
            let d = 1 + r.below(6) as usize;
            let a = dominant_matrix(r, d);
            let lu = Lu::factor(&a);
            let x: Vec<f64> = (0..d).map(|_| r.uniform(-2.0, 2.0)).collect();
            let b = a.matvec::<Prob>(&x);
            let got = lu.solve_vec(&b);
            for (u, v) in x.iter().zip(&got) {
                assert!(close(*u, *v), "solve_vec: {u} vs {v}");
            }
            // Matrix solve: A·X = A·M recovers M.
            let m = dominant_matrix(r, d);
            let am = a.matmul::<Prob>(&m);
            assert!(mats_close(&lu.solve_mat(&am), &m));
        });
    }

    #[test]
    fn lu_transpose_solve_matches_transposed_factorization() {
        let mut runner = Runner::new("linalg-lu-transpose");
        runner.run(50, |r| {
            let d = 1 + r.below(6) as usize;
            let a = dominant_matrix(r, d);
            let lu = Lu::factor(&a);
            let lut = Lu::factor(&a.transpose());
            let b: Vec<f64> = (0..d).map(|_| r.uniform(-2.0, 2.0)).collect();
            let via_transpose_solve = lu.solve_transpose_vec(&b);
            let via_refactor = lut.solve_vec(&b);
            for (u, v) in via_transpose_solve.iter().zip(&via_refactor) {
                assert!(close(*u, *v), "transpose solve: {u} vs {v}");
            }
            let bm = dominant_matrix(r, d);
            assert!(mats_close(&lu.solve_transpose_mat(&bm), &lut.solve_mat(&bm)));
        });
    }

    #[test]
    fn lu_identity_solves_are_bit_exact() {
        // The exact-zero skips keep identity solves free of rounding —
        // the property that makes `combine(identity, e)` value-exact in
        // the Kalman scan operators.
        let d = 5;
        let i = Mat::identity::<Prob>(d);
        let lu = Lu::factor(&i);
        let b = vec![1.25, -3.5, 0.0, f64::MIN_POSITIVE, 1e300];
        assert_eq!(lu.solve_vec(&b), b);
        assert_eq!(lu.solve_transpose_vec(&b), b);
        assert_eq!(lu.ln_abs_det(), 0.0);
    }

    #[test]
    fn lu_ln_abs_det_matches_known_values() {
        // Diagonal matrix: |det| = product of |diagonal|.
        let a = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, -2.0]);
        let lu = Lu::factor(&a);
        assert!(close(lu.ln_abs_det(), 6.0_f64.ln()));
        // Permutation effects: a matrix needing a row swap.
        let b = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        assert!(close(Lu::factor(&b).ln_abs_det(), 0.0));
    }

    #[test]
    fn lu_is_total_on_singular_and_garbage_input() {
        // Singular, all-zero, and non-finite matrices must factor and
        // solve without panicking (the scan-combine totality contract).
        for m in [
            Mat::zeros(3, 3),
            Mat::filled(3, 3, 1.0), // rank 1
            Mat::filled(3, 3, f64::NAN),
            Mat::filled(3, 3, f64::INFINITY),
        ] {
            let lu = Lu::factor(&m);
            let _ = lu.solve_vec(&[1.0, 2.0, 3.0]);
            let _ = lu.solve_transpose_vec(&[1.0, 2.0, 3.0]);
            let _ = lu.ln_abs_det();
        }
    }
}

//! Dense matrices over a [`Semiring`](crate::semiring::Semiring).
//!
//! The paper's elements a_{i:j} are D×D potential matrices and both of
//! its associative operators are semiring matrix products (Eq. 16 over
//! (+,×); Eq. 42 over (max,×) / (max,+)). This module provides the
//! storage type and the (small-D, cache-friendly) product kernels the
//! scan and the inference algorithms build on.

use std::fmt;

use crate::semiring::Semiring;

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows {
            writeln!(f, "  {:?}", &self.data[r * self.cols..(r + 1) * self.cols])?;
        }
        write!(f, "]")
    }
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Constant-filled matrix.
    pub fn filled(rows: usize, cols: usize, v: f64) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    /// Wrap a row-major buffer (length must be rows·cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Multiplicative identity of semiring `S` (S::one on the diagonal,
    /// S::zero elsewhere).
    pub fn identity<S: Semiring>(d: usize) -> Self {
        let mut m = Self::filled(d, d, S::zero());
        for i in 0..d {
            m[(i, i)] = S::one();
        }
        m
    }

    /// All-entries S::one matrix (the paper's terminal element ψ_{T,T+1}=1).
    pub fn all_one<S: Semiring>(rows: usize, cols: usize) -> Self {
        Self::filled(rows, cols, S::one())
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The row-major backing buffer.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable row-major backing buffer.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// One row as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// One column, copied out.
    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// The transposed matrix (copied).
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Largest absolute entry (0 for an empty matrix).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }

    /// Largest entry (−∞ for an empty matrix).
    pub fn max(&self) -> f64 {
        self.data.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v))
    }

    /// Scale every entry (linear domain).
    pub fn scale(&mut self, s: f64) {
        self.data.iter_mut().for_each(|v| *v *= s);
    }

    /// Add a constant to every entry (log domain rescale).
    pub fn shift(&mut self, s: f64) {
        self.data.iter_mut().for_each(|v| *v += s);
    }

    /// `C = A ∘ B` (entrywise semiring mul).
    pub fn hadamard<S: Semiring>(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| S::mul(a, b))
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Semiring matrix product `self ⋆ other`.
    ///
    /// ikj loop order: the inner loop runs over contiguous rows of both
    /// the output and `other`, which is the hot path of every combine —
    /// see EXPERIMENTS.md §Perf.
    pub fn matmul<S: Semiring>(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "inner dimension mismatch");
        let mut out = Mat::filled(self.rows, other.cols, S::zero());
        matmul_into::<S>(self, other, &mut out);
        out
    }

    /// Semiring vector-matrix product `v ⋆ self` (row vector).
    pub fn vecmat<S: Semiring>(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![S::zero(); self.cols];
        for (i, &vi) in v.iter().enumerate() {
            let row = self.row(i);
            for (o, &m) in out.iter_mut().zip(row) {
                *o = S::add(*o, S::mul(vi, m));
            }
        }
        out
    }

    /// Semiring matrix-vector product `self ⋆ v` (column vector).
    pub fn matvec<S: Semiring>(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows)
            .map(|r| {
                self.row(r)
                    .iter()
                    .zip(v)
                    .fold(S::zero(), |acc, (&m, &x)| S::add(acc, S::mul(m, x)))
            })
            .collect()
    }

    /// Argmax version of `vecmat` over a tropical semiring (`add` = max):
    /// per output column, the extremal value `max_i v[i] ⋆ self[i,c]` and
    /// the first index achieving it (the Viterbi `u` function).
    pub fn vecmat_argmax<S: Semiring>(&self, v: &[f64]) -> (Vec<f64>, Vec<usize>) {
        assert_eq!(v.len(), self.rows);
        let mut best = vec![S::zero(); self.cols];
        let mut arg = vec![0usize; self.cols];
        for (i, &vi) in v.iter().enumerate() {
            let row = self.row(i);
            for c in 0..self.cols {
                let cand = S::mul(vi, row[c]);
                if i == 0 || cand > best[c] {
                    best[c] = cand;
                    arg[c] = i;
                }
            }
        }
        (best, arg)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

/// `out = a ⋆ b` without allocating (out must be pre-shaped and is
/// overwritten). The ikj ordering keeps the inner loop contiguous.
pub fn matmul_into<S: Semiring>(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((out.rows, out.cols), (a.rows, b.cols));
    out.data.fill(S::zero());
    let n = b.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            if aik == S::zero() {
                continue; // annihilator: skip the whole row of b
            }
            let brow = &b.data[k * n..(k + 1) * n];
            for (o, &bkj) in orow.iter_mut().zip(brow) {
                *o = S::add(*o, S::mul(aik, bkj));
            }
        }
    }
}

/// Normalize `v` to sum 1 (linear domain). Returns the pre-normalization
/// sum; if the sum is zero the vector is left unchanged and 0 returned.
pub fn normalize_sum(v: &mut [f64]) -> f64 {
    let s: f64 = v.iter().sum();
    if s > 0.0 {
        v.iter_mut().for_each(|x| *x /= s);
    }
    s
}

/// Index of the maximum element (first maximizer on ties).
pub fn argmax(v: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate().skip(1) {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptestx::{gen, Runner};
    use crate::semiring::{MaxPlus, MaxTimes, Prob};

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-10 * (1.0 + a.abs().max(b.abs()))
    }

    fn mats_close(a: &Mat, b: &Mat) -> bool {
        a.rows() == b.rows()
            && a.cols() == b.cols()
            && a.data().iter().zip(b.data()).all(|(&x, &y)| close(x, y))
    }

    #[test]
    fn identity_is_neutral() {
        let mut runner = Runner::new("linalg-identity");
        runner.run(50, |r| {
            let d = 1 + r.below(6) as usize;
            let a = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let i = Mat::identity::<Prob>(d);
            assert!(mats_close(&a.matmul::<Prob>(&i), &a));
            assert!(mats_close(&i.matmul::<Prob>(&a), &a));
        });
    }

    #[test]
    fn matmul_associative_prob_and_tropical() {
        let mut runner = Runner::new("linalg-assoc");
        runner.run(50, |r| {
            let d = 2 + r.below(5) as usize;
            let a = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let b = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let c = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let l = a.matmul::<Prob>(&b).matmul::<Prob>(&c);
            let rr = a.matmul::<Prob>(&b.matmul::<Prob>(&c));
            assert!(mats_close(&l, &rr));
            let l = a.matmul::<MaxTimes>(&b).matmul::<MaxTimes>(&c);
            let rr = a.matmul::<MaxTimes>(&b.matmul::<MaxTimes>(&c));
            assert!(mats_close(&l, &rr));
        });
    }

    #[test]
    fn prob_matmul_known_values() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul::<Prob>(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn maxplus_matmul_known_values() {
        let a = Mat::from_vec(2, 2, vec![0.0, -1.0, -2.0, 0.0]);
        let b = Mat::from_vec(2, 2, vec![0.0, -3.0, -1.0, 0.0]);
        let c = a.matmul::<MaxPlus>(&b);
        // c[0,0] = max(0+0, -1+-1) = 0 ; c[0,1] = max(0-3, -1+0) = -1
        // c[1,0] = max(-2+0, 0-1) = -1 ; c[1,1] = max(-2-3, 0+0) = 0
        assert_eq!(c.data(), &[0.0, -1.0, -1.0, 0.0]);
    }

    #[test]
    fn rectangular_matmul_shapes() {
        let a = Mat::filled(2, 3, 1.0);
        let b = Mat::filled(3, 4, 2.0);
        let c = a.matmul::<Prob>(&b);
        assert_eq!((c.rows(), c.cols()), (2, 4));
        assert!(c.data().iter().all(|&v| close(v, 6.0)));
    }

    #[test]
    fn vecmat_matvec_match_matmul() {
        let mut runner = Runner::new("linalg-vec");
        runner.run(50, |r| {
            let d = 1 + r.below(6) as usize;
            let a = Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let v = gen::prob_vector(r, d);
            // v as 1×d matrix
            let vm = Mat::from_vec(1, d, v.clone());
            let via_mat = vm.matmul::<Prob>(&a);
            let direct = a.vecmat::<Prob>(&v);
            assert!(via_mat.data().iter().zip(&direct).all(|(&x, &y)| close(x, y)));
            let vm2 = Mat::from_vec(d, 1, v.clone());
            let via_mat2 = a.matmul::<Prob>(&vm2);
            let direct2 = a.matvec::<Prob>(&v);
            assert!(via_mat2.data().iter().zip(&direct2).all(|(&x, &y)| close(x, y)));
        });
    }

    #[test]
    fn vecmat_argmax_consistent() {
        let mut runner = Runner::new("linalg-argmax");
        runner.run(50, |r| {
            let d = 2 + r.below(5) as usize;
            let a = Mat::from_vec(
                d,
                d,
                (0..d * d).map(|_| r.uniform(-5.0, 0.0)).collect(),
            );
            let v: Vec<f64> = (0..d).map(|_| r.uniform(-5.0, 0.0)).collect();
            let (best, arg) = a.vecmat_argmax::<MaxPlus>(&v);
            let plain = a.transpose().matvec::<MaxPlus>(&v);
            for c in 0..d {
                assert!(close(best[c], plain[c]));
                assert!(close(v[arg[c]] + a[(arg[c], c)], best[c]));
            }
        });
    }

    #[test]
    fn zero_annihilator_shortcut_is_correct() {
        // matmul_into skips S::zero() entries; verify against a naive
        // product on a sparse matrix.
        let a = Mat::from_vec(2, 2, vec![0.0, 2.0, 0.0, 0.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 3.0, 4.0]);
        let c = a.matmul::<Prob>(&b);
        assert_eq!(c.data(), &[6.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    fn normalize_and_argmax_helpers() {
        let mut v = vec![1.0, 3.0];
        assert!(close(normalize_sum(&mut v), 4.0));
        assert!(close(v[0], 0.25) && close(v[1], 0.75));
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize_sum(&mut z), 0.0);
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1); // first maximizer
    }

    #[test]
    fn transpose_row_col() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = a.transpose();
        assert_eq!((t.rows(), t.cols()), (3, 2));
        assert_eq!(t.row(1), &[2.0, 5.0]);
        assert_eq!(a.col(2), vec![3.0, 6.0]);
    }
}

//! Durable session store — the persistence layer under the streaming
//! coordinator.
//!
//! The prefix-sum structure of the scan (paper §IV) makes a cold
//! session fully characterized by its observations plus the serialized
//! per-block summaries ([`Session::snapshot`](crate::engine::Session::snapshot)):
//! raw element chains are
//! deterministic functions of `(model, ys)`, so spilling a session to
//! disk and restoring it is *bit-identical* to never having evicted it
//! (`Engine::resume_session` + replayed appends — property-tested in
//! `engine::tests` and `coordinator::server::tests`).
//!
//! Two implementations sit behind [`SessionStore`]:
//!
//! * [`MemStore`] — an in-process map. Eviction works (resident RAM is
//!   freed; the spilled state lives in the store), crash recovery does
//!   not. The default, and the reference semantics for the trait.
//! * [`DiskStore`] — one append-ahead log file per session (std::fs
//!   only; the crate stays zero-dep). Appends are logged *before* they
//!   mutate the resident session, so startup replay recovers every
//!   acknowledged observation after a crash; periodic/spill-time
//!   checkpoints bound both log length and restore cost. See
//!   `store::disk` for the record format and crash-safety argument.
//!
//! Lifecycle (driven by the coordinator):
//!
//! ```text
//!   open ──▶ create(id, meta)
//!   append ─▶ log_append(id, ys)          (append-ahead, then push)
//!   evict ──▶ compact(id, meta, snapshot) + drop the resident Session
//!   touch ──▶ restore(id) ─▶ resume_session(snapshot) + replay appends
//!   close ──▶ remove(id)
//!   crash ──▶ max_id() seeds the id allocator; recover_meta() re-registers
//!             every stored session (lazily restored on first touch)
//! ```
//!
//! The disk format itself — framing, checksums, record kinds, the
//! compaction/rename protocol, torn-tail semantics, and the sharded
//! directory layout — is specified in `docs/STORE_FORMAT.md`.

pub mod disk;

pub use disk::{DiskStore, DEFAULT_GROUP_COMMIT_WINDOW, FORMAT_VERSION};

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::engine::{SessionKind, SessionOptions};
use crate::error::{Error, Result};
use crate::hmm::Hmm;
use crate::jsonx::Json;

/// Order-sensitive FNV-1a over a model's shape and parameter bit
/// patterns — the identity the store records alongside each session so
/// crash recovery can refuse to bind stored scan state to a *different*
/// model that was re-registered under the same name (snapshot summaries
/// are trusted, not re-verified; mixing them with rebuilt elements from
/// another model would silently corrupt results).
pub fn model_fingerprint(hmm: &Hmm) -> u64 {
    let mut h = crate::rng::FNV1A_OFFSET;
    let mut eat = |v: f64| {
        h = crate::rng::fnv1a_64(h, &v.to_bits().to_le_bytes());
    };
    eat(hmm.num_states() as f64);
    eat(hmm.num_symbols() as f64);
    for &v in hmm.transition().data() {
        eat(v);
    }
    for &v in hmm.emission().data() {
        eat(v);
    }
    for &v in hmm.prior() {
        eat(v);
    }
    h
}

// [`model_fingerprint`]'s linear-Gaussian sibling lives next to the
// model it hashes; re-exported here so store/recovery call sites read
// symmetrically with the discrete path.
pub use crate::kalman::lgssm_fingerprint;

/// Everything needed to re-create a session that is not resident:
/// which model it belongs to, how it was opened, and its serving lag.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionMeta {
    /// Model registry key.
    pub model: String,
    /// Options the session was opened with (block / track_map / kind).
    pub options: SessionOptions,
    /// Fixed-lag width appends report at (coordinator-level state).
    pub lag: usize,
    /// [`model_fingerprint`] of the parameters the session was opened
    /// against; `None` when unknown. Recovery skips sessions whose
    /// stored fingerprint disagrees with the registered model's.
    pub fingerprint: Option<u64>,
}

impl SessionMeta {
    /// Serialize for the store's durable `open` record.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("model".to_string(), Json::Str(self.model.clone()));
        obj.insert(
            "block".to_string(),
            self.options.block.map_or(Json::Null, |b| Json::Num(b as f64)),
        );
        obj.insert("track_map".to_string(), Json::Bool(self.options.track_map));
        obj.insert(
            "kind".to_string(),
            Json::Str(self.options.kind.name().to_string()),
        );
        obj.insert("lag".to_string(), Json::Num(self.lag as f64));
        if let Some(fp) = self.fingerprint {
            // Hex string: a u64 does not survive the f64 Num round-trip.
            obj.insert("model_fp".to_string(), Json::Str(format!("{fp:016x}")));
        }
        Json::Obj(obj)
    }

    /// Inverse of [`to_json`](Self::to_json); typed errors on missing
    /// or malformed fields.
    pub fn from_json(v: &Json) -> Result<SessionMeta> {
        let model = v
            .get("model")
            .as_str()
            .ok_or_else(|| Error::invalid_request("session meta: 'model'"))?
            .to_string();
        let block = match v.get("block") {
            Json::Null => None,
            b => Some(b.as_usize().ok_or_else(|| {
                Error::invalid_request("session meta: invalid 'block'")
            })?),
        };
        let track_map = v.get("track_map").as_bool().unwrap_or(false);
        let kind = match v.get("kind") {
            Json::Null => SessionKind::SumProduct,
            k => k.as_str().and_then(SessionKind::parse).ok_or_else(|| {
                Error::invalid_request("session meta: unknown 'kind'")
            })?,
        };
        let lag = v.get("lag").as_usize().unwrap_or(0);
        let fingerprint = match v.get("model_fp") {
            Json::Null => None,
            f => Some(
                f.as_str()
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
                    .ok_or_else(|| {
                        Error::invalid_request("session meta: invalid 'model_fp'")
                    })?,
            ),
        };
        Ok(SessionMeta {
            model,
            options: SessionOptions { block, track_map, kind },
            lag,
            fingerprint,
        })
    }
}

/// The stored state of one session: its meta, the latest checkpoint
/// snapshot (if any), and the observation chunks logged after it.
///
/// Restoring is `Engine::resume_session(snapshot)` (or a fresh
/// `open_session(meta.options)` when no checkpoint exists yet) followed
/// by pushing every chunk in `appends`, in order — bit-identical to the
/// live session by the snapshot/resume contract.
#[derive(Debug, Clone)]
pub struct StoredSession {
    /// The session's durable identity (model, options, lag).
    pub meta: SessionMeta,
    /// Latest [`Session::snapshot`](crate::engine::Session::snapshot)
    /// checkpoint, superseding everything logged before it.
    pub snapshot: Option<Json>,
    /// Observation chunks appended after the snapshot, oldest first.
    pub appends: Vec<Vec<u32>>,
}

impl StoredSession {
    /// Total observations held (snapshot + trailing appends).
    pub fn len(&self) -> usize {
        let base = self
            .snapshot
            .as_ref()
            .and_then(|s| crate::elements::serde::obs_len_from_json(s.get("ys")))
            .unwrap_or(0);
        base + self.appends.iter().map(Vec::len).sum::<usize>()
    }

    /// Whether no observations are held at all.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A durable (or at least spill-capable) store of streaming sessions.
///
/// Implementations must keep the restore contract exact: `restore`
/// after any interleaving of `create`/`log_append`/`spill`/`compact`
/// returns state from which the coordinator rebuilds a session
/// bit-identical to the live one. All methods take `&self` — stores are
/// shared across the coordinator's serve path.
pub trait SessionStore: Send + Sync {
    /// Implementation name (metrics / logs).
    fn name(&self) -> &'static str;

    /// Whether stored state survives the process. The coordinator skips
    /// the per-append write-ahead log (and periodic compaction) for
    /// non-durable stores — the spill-time snapshot already covers
    /// everything a same-process restore needs, so logging every chunk
    /// would only duplicate hot sessions' observations in RAM.
    fn durable(&self) -> bool {
        true
    }

    /// Register a new session (the durable "open" record). Overwrites
    /// any stale state under the same id.
    fn create(&self, id: u64, meta: &SessionMeta) -> Result<()>;

    /// Append-ahead log of one observation chunk: must be durable
    /// before the resident session applies it.
    fn log_append(&self, id: u64, ys: &[u32]) -> Result<()>;

    /// Persist a snapshot checkpoint *and* drop everything it
    /// supersedes, bounding stored size and restore cost — the spill
    /// write of the coordinator's eviction path. `meta` re-seeds the
    /// open record of the rewritten state (the caller holds it anyway —
    /// reading it back from the store would make compaction O(stored
    /// size)).
    fn compact(&self, id: u64, meta: &SessionMeta, snapshot: &Json) -> Result<()>;

    /// Read back everything needed to restore session `id`.
    fn restore(&self, id: u64) -> Result<StoredSession>;

    /// Forget session `id` entirely (close).
    fn remove(&self, id: u64) -> Result<()>;

    /// Enumerate every stored session — crash recovery. Sessions whose
    /// state cannot be read are skipped, never a hard error.
    fn recover(&self) -> Result<Vec<(u64, StoredSession)>>;

    /// Metadata-only enumeration for crash recovery: `(id, meta, length)`
    /// per stored session, without materializing snapshots or append
    /// chunks. `Coordinator::recover_sessions` re-registers sessions as
    /// *evicted* stubs, so this is all it needs — a store that can
    /// answer from headers alone (as [`DiskStore`] does) makes
    /// startup O(#sessions) instead of O(stored bytes). The default
    /// falls back to a full [`recover`](Self::recover). Unreadable
    /// sessions are skipped, never a hard error.
    fn recover_meta(&self) -> Result<Vec<(u64, SessionMeta, usize)>> {
        Ok(self
            .recover()?
            .into_iter()
            .map(|(id, s)| {
                let len = s.len();
                (id, s.meta, len)
            })
            .collect())
    }

    /// Highest session id the store holds state for (`None` when
    /// empty), metadata-only cheap. `Coordinator::new` seeds its id
    /// allocator from this so a fresh open can never collide with — and
    /// overwrite the durable log of — a stored session from a previous
    /// process, even before `recover_sessions` runs. The default suits
    /// stores that cannot outlive the process.
    fn max_id(&self) -> Result<Option<u64>> {
        Ok(None)
    }
}

/// In-memory [`SessionStore`]: the default spill target. Sessions
/// evicted here free their resident element chains (the point of
/// eviction) but do not survive the process.
#[derive(Default)]
pub struct MemStore {
    sessions: Mutex<BTreeMap<u64, StoredSession>>,
}

impl MemStore {
    /// An empty in-memory store.
    pub fn new() -> Self {
        Self::default()
    }
}

impl SessionStore for MemStore {
    fn name(&self) -> &'static str {
        "mem"
    }

    fn durable(&self) -> bool {
        false
    }

    fn create(&self, id: u64, meta: &SessionMeta) -> Result<()> {
        self.sessions.lock().unwrap().insert(
            id,
            StoredSession { meta: meta.clone(), snapshot: None, appends: Vec::new() },
        );
        Ok(())
    }

    fn log_append(&self, id: u64, ys: &[u32]) -> Result<()> {
        let mut sessions = self.sessions.lock().unwrap();
        let s = sessions
            .get_mut(&id)
            .ok_or_else(|| Error::invalid_request(format!("store: unknown session {id}")))?;
        s.appends.push(ys.to_vec());
        Ok(())
    }

    fn compact(&self, id: u64, meta: &SessionMeta, snapshot: &Json) -> Result<()> {
        let mut sessions = self.sessions.lock().unwrap();
        let s = sessions
            .get_mut(&id)
            .ok_or_else(|| Error::invalid_request(format!("store: unknown session {id}")))?;
        s.meta = meta.clone();
        s.snapshot = Some(snapshot.clone());
        s.appends.clear();
        Ok(())
    }

    fn restore(&self, id: u64) -> Result<StoredSession> {
        self.sessions
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::invalid_request(format!("store: unknown session {id}")))
    }

    fn remove(&self, id: u64) -> Result<()> {
        self.sessions.lock().unwrap().remove(&id);
        Ok(())
    }

    fn recover(&self) -> Result<Vec<(u64, StoredSession)>> {
        Ok(self
            .sessions
            .lock()
            .unwrap()
            .iter()
            .map(|(id, s)| (*id, s.clone()))
            .collect())
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    static NEXT: AtomicU64 = AtomicU64::new(0);

    /// Unique per-test scratch directory under the system temp dir (the
    /// CI test job points TMPDIR at the runner's scratch space).
    pub fn tempdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "hmm-scan-{tag}-{}-{}",
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).expect("create tempdir");
        dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> SessionMeta {
        SessionMeta {
            model: "ge".to_string(),
            options: SessionOptions {
                block: Some(32),
                track_map: true,
                kind: SessionKind::SumProduct,
            },
            lag: 16,
            // A value above 2^53 would corrupt under an f64 encoding —
            // the round-trip test below guards the hex-string choice.
            fingerprint: Some(0xDEAD_BEEF_CAFE_F00D),
        }
    }

    #[test]
    fn meta_json_round_trips() {
        let m = meta();
        let back = SessionMeta::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        // None block/fingerprint and bayes kind survive too.
        let m2 = SessionMeta {
            model: "x".into(),
            options: SessionOptions {
                block: None,
                track_map: false,
                kind: SessionKind::Bayes,
            },
            lag: 0,
            fingerprint: None,
        };
        assert_eq!(SessionMeta::from_json(&m2.to_json()).unwrap(), m2);
        // Missing model / unknown kind / bad fingerprint are typed errors.
        assert!(SessionMeta::from_json(&Json::Null).is_err());
        let bad = Json::parse(r#"{"model": "m", "kind": "nope"}"#).unwrap();
        assert!(SessionMeta::from_json(&bad).is_err());
        let bad_fp =
            Json::parse(r#"{"model": "m", "model_fp": "xyz"}"#).unwrap();
        assert!(SessionMeta::from_json(&bad_fp).is_err());
    }

    #[test]
    fn fingerprint_separates_models() {
        use crate::hmm::{gilbert_elliott, GeParams};
        let a = model_fingerprint(&gilbert_elliott(GeParams::default()));
        let b = model_fingerprint(&gilbert_elliott(GeParams {
            q0: 0.011,
            ..GeParams::default()
        }));
        assert_ne!(a, b, "parameter change must change the fingerprint");
        assert_eq!(a, model_fingerprint(&gilbert_elliott(GeParams::default())));
    }

    #[test]
    fn lgssm_fingerprint_separates_models() {
        use crate::kalman::Lgssm;
        let a = lgssm_fingerprint(&Lgssm::constant_velocity(0.1, 0.8, 0.5));
        let b = lgssm_fingerprint(&Lgssm::constant_velocity(0.1, 0.8, 0.6));
        assert_ne!(a, b, "parameter change must change the fingerprint");
        assert_eq!(
            a,
            lgssm_fingerprint(&Lgssm::constant_velocity(0.1, 0.8, 0.5))
        );
    }

    #[test]
    fn mem_store_lifecycle() {
        let store = MemStore::new();
        assert_eq!(store.name(), "mem");
        store.create(7, &meta()).unwrap();
        store.log_append(7, &[0, 1, 1]).unwrap();
        store.log_append(7, &[1]).unwrap();
        let s = store.restore(7).unwrap();
        assert_eq!(s.meta, meta());
        assert!(s.snapshot.is_none());
        assert_eq!(s.appends, vec![vec![0, 1, 1], vec![1]]);
        assert_eq!(s.len(), 4);

        // A compact checkpoint supersedes the appends (and refreshes
        // the meta); appends logged after it stack on top.
        let snap = Json::parse(r#"{"ys": [0, 1, 1, 1]}"#).unwrap();
        store.compact(7, &meta(), &snap).unwrap();
        let s = store.restore(7).unwrap();
        assert_eq!(s.snapshot.as_ref(), Some(&snap));
        assert!(s.appends.is_empty());
        assert_eq!(s.len(), 4);
        store.log_append(7, &[0, 0]).unwrap();
        assert_eq!(store.restore(7).unwrap().len(), 6);

        assert_eq!(store.recover().unwrap().len(), 1);
        // The default metadata-only scan agrees with the full one.
        let metas = store.recover_meta().unwrap();
        assert_eq!(metas.len(), 1);
        assert_eq!(metas[0].0, 7);
        assert_eq!(metas[0].1, meta());
        assert_eq!(metas[0].2, 6);
        store.remove(7).unwrap();
        assert!(store.restore(7).is_err());
        assert!(store.log_append(7, &[0]).is_err());
        assert!(store.recover().unwrap().is_empty());
    }
}

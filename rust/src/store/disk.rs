//! Disk-backed [`SessionStore`]: one append-ahead log file per session,
//! `std::fs` only.
//!
//! The on-disk format is **specified** in `docs/STORE_FORMAT.md`
//! (format version 3); what follows is the implementation-side summary.
//! Keep the two in sync — the spec is the contract, this file is one
//! reader/writer of it.
//!
//! ## File format (v2)
//!
//! `<dir>/<id mod 256:02x>/sess_<id:016x>.log` is a sequence of framed
//! records:
//!
//! ```text
//! ┌───────────────────────────────────────────────┬─────────────┬────┐
//! │ "llllllllllllllll cccccccccccccccc k          │   payload   │ \n │
//! │  nnnnnnnnnnnnnnnn\n"                          │ (len bytes) │    │
//! │ (len, fnv64, kind, obs-count — fixed-width)   │             │    │
//! └───────────────────────────────────────────────┴─────────────┴────┘
//! ```
//!
//! The 53-byte header carries the payload length and its FNV-1a 64
//! checksum (both fixed-width hex), a one-character record kind, and
//! the record's observation count (hex). The kind/count pair is what
//! makes **metadata-only recovery** possible: a scan that trusts the
//! framing can walk headers with `seek` and reconstruct each session's
//! observation count without parsing a single JSON body
//! ([`recover_meta`]). The payload is one compact-JSON record:
//!
//! * kind `o` (count 0) — `{"meta":{…},"type":"open","v":3}`, written
//!   once by [`create`]; `v` is the format-version byte readers use to
//!   reject logs written by a *future* format revision.
//! * kind `a` (count = chunk length) — `{"type":"append","ys":{…}}`,
//!   one per logged observation chunk; `ys` is the bit-packed hex
//!   object of `elements::serde::obs_to_json` (v2 wrote a decimal
//!   array — still readable). Appends to a log still stamped `"v":2`
//!   keep the decimal encoding so the stamp stays honest; compaction
//!   rewrites the log at the current version.
//! * kind `c` (count = snapshot length) — `{"snap":{…},"type":"ckpt"}`,
//!   a full [`Session::snapshot`] (v2 of the snapshot encoding: packed
//!   hex payloads), superseding every record before it.
//!
//! ## Crash safety
//!
//! Records are appended with a single `write_all` and parsed back
//! prefix-wise: the reader stops at the first truncated header, short
//! payload, checksum mismatch or unparsable JSON, and returns every
//! record before it. A crash mid-append therefore costs at most the
//! half-written tail record — and since the coordinator logs a chunk
//! *before* applying it to the resident session, every observation the
//! resident session ever held is a fully-framed, fsynced record.
//! [`compact`] rewrites the log as `open` + `ckpt` via a temp file and
//! an atomic rename (followed on unix by a directory fsync, so the
//! entry itself survives the crash; other targets have no portable
//! directory fsync and weaken that to best-effort), leaving either the
//! old or the new log, never a mix. File operations are serialized per
//! session id (sharded locks): same-id *writes* (append/compact/remove)
//! are mutually exclusive, while appends to different sessions proceed
//! concurrently. Note the group-commit ack happens *after* the id lock
//! is released, so a same-id `compact` can rename the log between an
//! append's write and its ack — the acked record then lives only on the
//! unlinked inode. The coordinator serializes same-session
//! append/compact under its slot lock, which closes that window; direct
//! store users issuing both concurrently for one session must provide
//! the same serialization.
//!
//! ## Group commit
//!
//! [`log_append`] acknowledges a chunk only after an `fsync` covering
//! its record — the append-ahead durability contract. Rather than one
//! fsync barrier per record, appends from concurrent sessions are
//! batched: the first appender to arrive becomes the batch *leader*,
//! sleeps a small deadline window ([`DEFAULT_GROUP_COMMIT_WINDOW`],
//! tunable via [`DiskStore::with_group_commit_window`]) so concurrent
//! appends can join, then fsyncs every dirty log once and wakes the
//! batch. A leader that is the lone registrant skips the window, so a
//! single-threaded caller (the serve loop serializes stream verbs)
//! keeps plain inline-fsync latency and the window only engages under
//! concurrent pressure. The durability contract is unchanged — no
//! append is acked before its covering sync — and the per-append sync
//! barrier is amortized across the fleet (the same deadline-window
//! idea the coordinator's decode batcher applies to PJRT dispatch);
//! per-*file* fsyncs stay floor-bounded at one per dirty log per
//! batch. A zero window disables batching and fsyncs inline per
//! record.
//!
//! [`create`]: SessionStore::create
//! [`compact`]: SessionStore::compact
//! [`log_append`]: SessionStore::log_append
//! [`recover_meta`]: SessionStore::recover_meta
//! [`Session::snapshot`]: crate::engine::Session::snapshot

use std::collections::BTreeMap;
use std::fs::{self, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::jsonx::Json;

use super::{SessionMeta, SessionStore, StoredSession};

/// Current on-disk format revision (see `docs/STORE_FORMAT.md`). Written
/// as `"v"` in every `open` record; readers reject logs whose recorded
/// version is newer than this. Version 3 packs append and checkpoint
/// payloads with the hex encodings of `elements::serde` (bit-packed
/// observation hex, hex-f64 element matrices — ~2× smaller logs);
/// version-2 decimal records remain readable because every payload
/// parser accepts both encodings.
pub const FORMAT_VERSION: usize = 3;

/// Header layout: 16 hex chars (payload length), space, 16 hex chars
/// (fnv64 checksum), space, 1 kind char (`o`/`a`/`c`), space, 16 hex
/// chars (record observation count), newline.
const HEADER_LEN: usize = 53;

/// Default group-commit deadline window: how long a batch leader waits
/// for concurrent appends to join before issuing the batch's fsyncs.
pub const DEFAULT_GROUP_COMMIT_WINDOW: Duration = Duration::from_micros(200);

/// The framing checksum: fresh-start FNV-1a 64 (`rng::fnv1a_64`).
fn fnv64(bytes: &[u8]) -> u64 {
    crate::rng::fnv1a_64(crate::rng::FNV1A_OFFSET, bytes)
}

fn frame(payload: &str, kind: u8, count: usize) -> Vec<u8> {
    let bytes = payload.as_bytes();
    let mut out = format!(
        "{:016x} {:016x} {} {:016x}\n",
        bytes.len(),
        fnv64(bytes),
        kind as char,
        count
    )
    .into_bytes();
    debug_assert_eq!(out.len(), HEADER_LEN);
    out.extend_from_slice(bytes);
    out.push(b'\n');
    out
}

fn parse_hex(bytes: &[u8]) -> Option<u64> {
    let s = std::str::from_utf8(bytes).ok()?;
    u64::from_str_radix(s, 16).ok()
}

/// One parsed frame header (the fixed 53-byte prefix of every record).
#[derive(Debug, Clone, Copy)]
struct FrameHeader {
    /// Payload byte length.
    len: usize,
    /// FNV-1a 64 checksum of the payload.
    sum: u64,
    /// Record kind: `b'o'` open, `b'a'` append, `b'c'` ckpt.
    kind: u8,
    /// Observation count this record contributes (0 / chunk / total).
    count: u64,
}

/// Parse one frame header; `None` on any structural violation (the
/// prefix-valid readers treat that as the crash tail).
fn parse_header(h: &[u8]) -> Option<FrameHeader> {
    if h.len() < HEADER_LEN {
        return None;
    }
    if h[16] != b' ' || h[33] != b' ' || h[35] != b' ' || h[52] != b'\n' {
        return None;
    }
    let kind = h[34];
    if !matches!(kind, b'o' | b'a' | b'c') {
        return None;
    }
    let len = usize::try_from(parse_hex(&h[0..16])?).ok()?;
    let sum = parse_hex(&h[17..33])?;
    let count = parse_hex(&h[36..52])?;
    Some(FrameHeader { len, sum, kind, count })
}

/// Parse the valid record prefix of a log image; everything after the
/// first framing violation (the crash tail) is ignored. Returns the
/// records plus the byte length of the valid prefix (what a torn-tail
/// repair truncates back to).
fn parse_records_prefix(data: &[u8]) -> (Vec<Json>, usize) {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos + HEADER_LEN <= data.len() {
        let Some(h) = parse_header(&data[pos..pos + HEADER_LEN]) else {
            break;
        };
        let start = pos + HEADER_LEN;
        let Some(end) = start.checked_add(h.len) else { break };
        if end >= data.len() || data[end] != b'\n' {
            break; // truncated payload / missing terminator
        }
        let payload = &data[start..end];
        if fnv64(payload) != h.sum {
            break; // torn write
        }
        let Ok(text) = std::str::from_utf8(payload) else { break };
        let Ok(record) = Json::parse(text) else { break };
        out.push(record);
        pos = end + 1;
    }
    (out, pos)
}

/// The record sequence of a log image (prefix-valid; see
/// [`parse_records_prefix`]).
fn parse_records(data: &[u8]) -> Vec<Json> {
    parse_records_prefix(data).0
}

/// Fold a record sequence into [`StoredSession`] form. The first record
/// must be `open` (with a supported format version); a `ckpt`
/// supersedes everything before it.
fn fold_records(records: &[Json]) -> Result<StoredSession> {
    let first = records
        .first()
        .ok_or_else(|| Error::invalid_request("session log: empty"))?;
    if first.get("type").as_str() != Some("open") {
        return Err(Error::invalid_request(
            "session log: first record is not 'open'",
        ));
    }
    check_version(first)?;
    let meta = SessionMeta::from_json(first.get("meta"))?;
    let mut stored = StoredSession { meta, snapshot: None, appends: Vec::new() };
    for record in &records[1..] {
        match record.get("type").as_str() {
            Some("append") => {
                // v3 writes the bit-packed hex object, v2 wrote a plain
                // decimal array — `obs_from_json` reads both.
                let ys = match record.get("ys") {
                    Json::Null => {
                        return Err(Error::invalid_request(
                            "session log: append without 'ys'",
                        ))
                    }
                    v => crate::elements::serde::obs_from_json(v)?,
                };
                stored.appends.push(ys);
            }
            Some("ckpt") => {
                stored.snapshot = Some(record.get("snap").clone());
                stored.appends.clear();
            }
            _ => {
                return Err(Error::invalid_request(
                    "session log: unknown record type",
                ))
            }
        }
    }
    Ok(stored)
}

/// Reject logs written by a future format revision. A missing `"v"`
/// means version 1 — note that real v1 *logs* never get this far (their
/// 34-byte frames fail v2 header parsing, so they read as empty and are
/// skipped by recovery; see the version-2 break in
/// `docs/STORE_FORMAT.md`): the lenient default exists for v2-framed
/// images whose open record omits the field (hand-built or repaired
/// logs).
fn check_version(open_record: &Json) -> Result<()> {
    let v = open_record.get("v").as_usize().unwrap_or(1);
    if v > FORMAT_VERSION {
        return Err(Error::invalid_request(format!(
            "session log: format version {v} is newer than supported \
             {FORMAT_VERSION}"
        )));
    }
    Ok(())
}

/// Number of id-sharded file-op locks (see `DiskStore::locks`).
const LOCK_SHARDS: usize = 16;

/// Number of directory shards the store fans session logs across
/// (`<dir>/<id mod 256:02x>/`), keeping any one directory's entry list
/// small at fleet scale.
const DIR_SHARDS: u64 = 256;

/// `true` for the two-lowercase-hex shard directory names `open`
/// creates (`00`…`ff`).
fn is_shard_name(name: &str) -> bool {
    name.len() == 2
        && name
            .bytes()
            .all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
}

/// Group-commit batch state (see the module docs): which logs have
/// unsynced appends, which batch is accepting writers, and which batch
/// the last completed sync covers.
struct CommitQueue {
    /// Dirty logs of the currently-forming batch: `(session id, file
    /// handle)`, one entry per registered write. Entries are *not*
    /// deduplicated by id: a same-id writer may hold a different inode
    /// (append racing a compact's rename outside the coordinator's
    /// serialization), and its ack must cover *its* handle. Acks gate
    /// appends, so in practice a session contributes one entry per
    /// batch anyway.
    pending: Vec<(u64, Arc<fs::File>)>,
    /// Id of the batch currently accepting writers.
    next_batch: u64,
    /// Highest batch whose fsyncs have completed (acks released).
    synced_batch: u64,
    /// Whether a leader is currently collecting or syncing a batch
    /// (batches are strictly serialized — see the ack-ordering note on
    /// `DiskStore::group_sync`).
    leader: bool,
    /// Batches whose fsync failed, by id — their waiters get an error
    /// instead of an ack. fsync failures are rare and near-fatal, so
    /// this map is not pruned.
    failed: BTreeMap<u64, String>,
}

/// Append-ahead-log session store under a sharded directory tree.
pub struct DiskStore {
    dir: PathBuf,
    /// Per-id shard locks. Same-session append/compact/remove must be
    /// mutually exclusive (an append racing a compact's rename would
    /// land on the unlinked old inode and vanish); different sessions
    /// touch different files, so they only share a lock by shard-hash
    /// accident.
    locks: Vec<Mutex<()>>,
    /// Group-commit deadline window; zero = fsync inline per append.
    window: Duration,
    commit: Mutex<CommitQueue>,
    commit_done: Condvar,
    /// fsync syscalls issued to ack appends (inline or batched).
    log_syncs: AtomicU64,
    /// Group-commit batches completed (each covering ≥ 1 log).
    sync_batches: AtomicU64,
    /// Append records acked across all completed syncs.
    synced_appends: AtomicU64,
    /// Append records durably written (equals acked appends absent
    /// fsync failures).
    appends_logged: AtomicU64,
    /// Log bytes read back (restore + recovery scans) — the counter the
    /// metadata-only recovery path is measured against.
    bytes_read: AtomicU64,
    /// Cached `"v"` stamp per open log. Append writers match the log's
    /// recorded version (a v2-stamped log keeps receiving decimal
    /// append records until a compaction rewrites it at
    /// [`FORMAT_VERSION`]), so the stamp always describes every record
    /// in its log — the property the version-detection gate rests on.
    log_versions: Mutex<BTreeMap<u64, usize>>,
    /// Per-sync-batch hook `(files synced, records acked)` — the
    /// coordinator wires its metrics in here.
    sync_observer: Option<Box<dyn Fn(usize, usize) + Send + Sync>>,
    /// Per-append hook with the time the appender spent blocked on its
    /// covering fsync (inline or group-commit rendezvous). Invoked on
    /// the appending thread, so the coordinator's tracing hook can
    /// attribute the wait to the ambient request span.
    wait_observer: Option<Box<dyn Fn(Duration) + Send + Sync>>,
}

impl DiskStore {
    /// Open (creating if needed) a store rooted at `dir`, with the
    /// default group-commit window.
    ///
    /// Besides creating the [`DIR_SHARDS`] shard directories, opening
    /// sweeps temp files orphaned by a crash between tmp-write and
    /// rename (a create-crash session was never acknowledged, and a
    /// compact-crash left the original log intact — either way the tmp
    /// is dead weight) and relocates any legacy flat-layout
    /// `sess_*.log` found at the root into its shard directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<DiskStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        for shard in 0..DIR_SHARDS {
            fs::create_dir_all(dir.join(format!("{shard:02x}")))?;
        }
        for entry in fs::read_dir(&dir)? {
            let entry = entry?;
            let path = entry.path();
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if path.is_dir() && is_shard_name(name) {
                for sub in fs::read_dir(&path)? {
                    let sub = sub?;
                    let sub_name = sub.file_name();
                    let Some(sub_name) = sub_name.to_str() else { continue };
                    if sub_name.starts_with("sess_") && sub_name.ends_with(".tmp")
                    {
                        let _ = fs::remove_file(sub.path());
                    }
                }
            } else if name.starts_with("sess_") && name.ends_with(".tmp") {
                let _ = fs::remove_file(&path);
            } else if let Some(id) = parse_session_filename(name) {
                // Legacy flat layout (pre-sharding): adopt the log into
                // its shard so every read path finds it at `path_for`.
                let shard = dir.join(format!("{:02x}", id % DIR_SHARDS));
                let _ = fs::rename(&path, shard.join(name));
            }
        }
        let locks = (0..LOCK_SHARDS).map(|_| Mutex::new(())).collect();
        Ok(DiskStore {
            dir,
            locks,
            window: DEFAULT_GROUP_COMMIT_WINDOW,
            commit: Mutex::new(CommitQueue {
                pending: Vec::new(),
                next_batch: 1,
                synced_batch: 0,
                leader: false,
                failed: BTreeMap::new(),
            }),
            commit_done: Condvar::new(),
            log_syncs: AtomicU64::new(0),
            sync_batches: AtomicU64::new(0),
            synced_appends: AtomicU64::new(0),
            appends_logged: AtomicU64::new(0),
            bytes_read: AtomicU64::new(0),
            log_versions: Mutex::new(BTreeMap::new()),
            sync_observer: None,
            wait_observer: None,
        })
    }

    /// Replace the group-commit deadline window (builder-style; call
    /// before sharing the store). `Duration::ZERO` disables batching —
    /// every append fsyncs inline, the pre-group-commit behavior.
    pub fn with_group_commit_window(mut self, window: Duration) -> DiskStore {
        self.window = window;
        self
    }

    /// Install a per-sync-batch observer `(files synced, records
    /// acked)`; call before sharing the store. The coordinator uses
    /// this to feed its sync-batch metrics.
    pub fn set_sync_observer(
        &mut self,
        observer: impl Fn(usize, usize) + Send + Sync + 'static,
    ) {
        self.sync_observer = Some(Box::new(observer));
    }

    /// Install a per-append sync-wait observer; call before sharing the
    /// store. It receives, on the appending thread, the time each
    /// [`log_append`](SessionStore::log_append) spent blocked on the
    /// fsync covering its record — the coordinator uses this to
    /// attribute group-commit waits to request trace spans.
    pub fn set_wait_observer(
        &mut self,
        observer: impl Fn(Duration) + Send + Sync + 'static,
    ) {
        self.wait_observer = Some(Box::new(observer));
    }

    /// The store's root directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// fsync syscalls issued to ack appends so far — the denominator of
    /// the group-commit amortization claim (`benches/streaming.rs`).
    pub fn log_syncs(&self) -> u64 {
        self.log_syncs.load(Ordering::Relaxed)
    }

    /// Completed group-commit batches (each covering ≥ 1 log).
    pub fn sync_batches(&self) -> u64 {
        self.sync_batches.load(Ordering::Relaxed)
    }

    /// Append records durably written so far.
    pub fn appends_logged(&self) -> u64 {
        self.appends_logged.load(Ordering::Relaxed)
    }

    /// Append records acked across all completed sync batches.
    pub fn synced_appends(&self) -> u64 {
        self.synced_appends.load(Ordering::Relaxed)
    }

    /// Log bytes read back so far (restores + recovery scans). The
    /// metadata-only recovery test asserts this stays far below the
    /// stored byte total.
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.load(Ordering::Relaxed)
    }

    /// Shard directory for session `id` (`<dir>/<id mod 256:02x>`).
    fn shard_dir(&self, id: u64) -> PathBuf {
        self.dir.join(format!("{:02x}", id % DIR_SHARDS))
    }

    /// The log path for session `id` (exposed for tests/observability;
    /// layout is `<dir>/<shard>/sess_<id:016x>.log`).
    pub fn path_for(&self, id: u64) -> PathBuf {
        self.shard_dir(id).join(format!("sess_{id:016x}.log"))
    }

    fn lock_for(&self, id: u64) -> std::sync::MutexGuard<'_, ()> {
        self.locks[(id % LOCK_SHARDS as u64) as usize].lock().unwrap()
    }

    /// fsync the directory holding `path` so a just-created/renamed log
    /// entry survives a crash — file-content fsync alone does not cover
    /// the directory metadata on POSIX. Non-unix targets have no
    /// portable directory-fsync, so there this is a no-op and the
    /// entry-survives-crash guarantee weakens to best-effort (the log
    /// contents themselves are still fsynced).
    fn sync_parent(&self, _path: &Path) -> Result<()> {
        #[cfg(unix)]
        {
            if let Some(parent) = _path.parent() {
                fs::File::open(parent)?.sync_all()?;
            }
        }
        Ok(())
    }

    /// Count a completed sync point and notify the observer.
    fn note_sync(&self, files: usize, records: usize) {
        self.log_syncs.fetch_add(files as u64, Ordering::Relaxed);
        self.sync_batches.fetch_add(1, Ordering::Relaxed);
        self.synced_appends.fetch_add(records as u64, Ordering::Relaxed);
        if let Some(observer) = &self.sync_observer {
            observer(files, records);
        }
    }

    /// Report one append's sync wait to the wait observer (no-op
    /// without one).
    fn note_wait(&self, elapsed: Duration) {
        if let Some(observer) = &self.wait_observer {
            observer(elapsed);
        }
    }

    /// Group-commit rendezvous: register `file` as dirty for session
    /// `id`, then block until a completed fsync covers the write.
    ///
    /// Batches are strictly serialized (one leader at a time), which is
    /// what makes the ack ordering sound: a writer that registers while
    /// batch *k* is collecting is covered by batch *k*'s sync; one that
    /// registers after batch *k* drained joins batch *k + 1* and waits
    /// for the next sync. Either way no ack is released before an fsync
    /// issued *after* the write completed.
    fn group_sync(&self, id: u64, file: Arc<fs::File>) -> Result<()> {
        let mut q = self.commit.lock().unwrap();
        let my_batch = q.next_batch;
        q.pending.push((id, file));
        loop {
            if q.synced_batch >= my_batch {
                if let Some(msg) = q.failed.get(&my_batch) {
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::Other,
                        format!("group-commit fsync failed: {msg}"),
                    )));
                }
                return Ok(());
            }
            if q.leader {
                q = self.commit_done.wait(q).unwrap();
                continue;
            }
            // Become the leader for my batch: collect joiners for the
            // deadline window, drain, sync, publish. A lone registrant
            // skips the window — waiting gains nothing when no one else
            // has a write in flight, so a single-threaded caller (e.g.
            // the serve loop, which serializes stream verbs) keeps the
            // old inline-fsync latency; under concurrent pressure the
            // queue is non-empty by the time leadership is free and the
            // window engages.
            let solo = q.pending.len() <= 1;
            q.leader = true;
            drop(q);
            if !solo && !self.window.is_zero() {
                std::thread::sleep(self.window);
            }
            let mut q2 = self.commit.lock().unwrap();
            let batch = q2.next_batch;
            q2.next_batch += 1;
            let files = std::mem::take(&mut q2.pending);
            drop(q2);
            let mut failure: Option<String> = None;
            let mut synced_files = 0usize;
            for (_, f) in &files {
                match f.sync_all() {
                    Ok(()) => synced_files += 1,
                    Err(e) => {
                        failure = Some(e.to_string());
                        break;
                    }
                }
            }
            if failure.is_none() {
                self.note_sync(synced_files, files.len());
            } else {
                // Count the fsyncs that did happen; the batch acked
                // nothing, so it contributes no records.
                self.log_syncs.fetch_add(synced_files as u64, Ordering::Relaxed);
            }
            let mut q2 = self.commit.lock().unwrap();
            q2.synced_batch = batch;
            q2.leader = false;
            if let Some(msg) = failure {
                q2.failed.insert(batch, msg);
            }
            self.commit_done.notify_all();
            q = q2;
            // Loop re-checks: `batch == my_batch` (serialized batches),
            // so the next iteration acks or reports the failure.
        }
    }

    /// Frame and append one record, acking only after a covering fsync
    /// (inline when the group-commit window is zero, batched otherwise).
    fn append_record(&self, id: u64, payload: &str, count: usize) -> Result<()> {
        let framed = frame(payload, b'a', count);
        let guard = self.lock_for(id);
        let path = self.path_for(id);
        let file = OpenOptions::new().append(true).open(&path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                Error::invalid_request(format!("store: unknown session {id}"))
            } else {
                Error::Io(e)
            }
        })?;
        let file = Arc::new(file);
        let len_before = file.metadata()?.len();
        if let Err(e) = (&*file).write_all(&framed) {
            // Roll the torn tail back (best-effort): leaving partial
            // frame bytes mid-log would hide every later acknowledged
            // record from the prefix-valid reader.
            let _ = file.set_len(len_before);
            return Err(Error::Io(e));
        }
        if self.window.is_zero() {
            // Inline fsync: the pre-group-commit behavior, still under
            // the id lock.
            let t0 = Instant::now();
            if let Err(e) = file.sync_all() {
                let _ = file.set_len(len_before);
                return Err(Error::Io(e));
            }
            self.note_wait(t0.elapsed());
            self.note_sync(1, 1);
            self.appends_logged.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        // Release the id lock before the rendezvous — holding it across
        // the deadline window would serialize 1/LOCK_SHARDS of the
        // fleet behind one sleeping appender.
        drop(guard);
        let t0 = Instant::now();
        if let Err(e) = self.group_sync(id, Arc::clone(&file)) {
            // Best-effort rollback, only while our frame is still the
            // log tail (a concurrent same-id writer may have appended
            // after us; truncating under it would eat its record).
            let _guard = self.lock_for(id);
            if let Ok(m) = file.metadata() {
                if m.len() == len_before + framed.len() as u64 {
                    let _ = file.set_len(len_before);
                }
            }
            return Err(e);
        }
        self.note_wait(t0.elapsed());
        self.appends_logged.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn read_stored_at(&self, id: u64, path: &Path) -> Result<StoredSession> {
        let data = fs::read(path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                Error::invalid_request(format!("store: unknown session {id}"))
            } else {
                Error::Io(e)
            }
        })?;
        self.bytes_read.fetch_add(data.len() as u64, Ordering::Relaxed);
        let (records, valid_len) = parse_records_prefix(&data);
        if valid_len < data.len() {
            self.repair_tail(id, path, data.len() as u64, valid_len as u64);
        }
        fold_records(&records)
    }

    /// Truncate a crash-torn tail (bytes past the valid record prefix)
    /// so appends acked *after* recovery land on a clean tail — written
    /// behind torn garbage they would be invisible to every
    /// prefix-valid reader until the next compaction. Best-effort,
    /// under the id lock, and only while the file still has the length
    /// the caller read: a concurrent append means the tail is no longer
    /// ours to judge.
    fn repair_tail(&self, id: u64, path: &Path, read_len: u64, valid_len: u64) {
        let _guard = self.lock_for(id);
        let Ok(file) = OpenOptions::new().write(true).open(path) else {
            return;
        };
        if let Ok(m) = file.metadata() {
            if m.len() == read_len {
                let _ = file.set_len(valid_len);
                let _ = file.sync_all();
            }
        }
    }

    fn read_stored(&self, id: u64) -> Result<StoredSession> {
        self.read_stored_at(id, &self.path_for(id))
    }

    /// The `"v"` stamp of session `id`'s log, cached after one read.
    /// Unknown/unreadable logs report the current [`FORMAT_VERSION`]
    /// (they cannot be parsed by any reader, so the append encoding is
    /// moot — and the subsequent open-file error is the real signal).
    fn log_format_version(&self, id: u64) -> usize {
        if let Some(&v) = self.log_versions.lock().unwrap().get(&id) {
            return v;
        }
        let v = self
            .read_log_version(&self.path_for(id))
            .unwrap_or(FORMAT_VERSION);
        self.log_versions.lock().unwrap().insert(id, v);
        v
    }

    /// Read the open record's `"v"` field (one header + one payload
    /// read); `None` when the log is missing or its open record is
    /// unreadable.
    fn read_log_version(&self, path: &Path) -> Option<usize> {
        let mut file = fs::File::open(path).ok()?;
        let mut header = [0u8; HEADER_LEN];
        file.read_exact(&mut header).ok()?;
        let h = parse_header(&header)?;
        if h.kind != b'o' {
            return None;
        }
        let mut buf = vec![0u8; h.len];
        file.read_exact(&mut buf).ok()?;
        self.bytes_read
            .fetch_add((HEADER_LEN + h.len) as u64, Ordering::Relaxed);
        if fnv64(&buf) != h.sum {
            return None;
        }
        let record = Json::parse(std::str::from_utf8(&buf).ok()?).ok()?;
        Some(record.get("v").as_usize().unwrap_or(1))
    }

    /// Enumerate `(id, log path)` for every stored session: the shard
    /// directories plus any legacy flat-layout stragglers at the root.
    /// The single walk both directory scans (`recover*`, `max_id`) go
    /// through — if they ever diverged, `max_id` could under-seed the
    /// id allocator and re-open the log-overwrite hazard it exists to
    /// prevent.
    fn scan_ids(&self) -> Result<Vec<(u64, PathBuf)>> {
        let mut out = Vec::new();
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let path = entry.path();
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if path.is_dir() && is_shard_name(name) {
                for sub in fs::read_dir(&path)? {
                    let sub = sub?;
                    let sub_name = sub.file_name();
                    let Some(id) =
                        sub_name.to_str().and_then(parse_session_filename)
                    else {
                        continue;
                    };
                    out.push((id, sub.path()));
                }
            } else if let Some(id) = parse_session_filename(name) {
                out.push((id, path));
            }
        }
        out.sort_by_key(|(id, _)| *id);
        out.dedup_by_key(|(id, _)| *id);
        Ok(out)
    }

    /// Read one payload back and verify it against its header; `false`
    /// on any checksum/terminator violation (the torn tail).
    fn payload_checks_out(
        &self,
        file: &mut fs::File,
        offset: u64,
        header: FrameHeader,
    ) -> bool {
        let mut buf = vec![0u8; header.len + 1];
        if file.seek(SeekFrom::Start(offset + HEADER_LEN as u64)).is_err() {
            return false;
        }
        if file.read_exact(&mut buf).is_err() {
            return false;
        }
        self.bytes_read.fetch_add(buf.len() as u64, Ordering::Relaxed);
        buf[header.len] == b'\n' && fnv64(&buf[..header.len]) == header.sum
    }

    /// Metadata-only read of one log: the session's meta (from the open
    /// record — the only payload parsed) and its observation count
    /// (from the frame headers' kind/count accounting). Cost is
    /// O(#records) seeks + two payload reads, independent of the stored
    /// byte volume; torn tails are dropped by validating backwards from
    /// the last framed record.
    fn read_meta_at(&self, id: u64, path: &Path) -> Result<(SessionMeta, usize)> {
        let mut file = fs::File::open(path)?;
        let file_len = file.metadata()?.len();
        let mut header = [0u8; HEADER_LEN];
        // Walk the frame headers, skipping payload bytes via seek.
        // (offset, header, running observation total after the record)
        let mut walked: Vec<(u64, FrameHeader, usize)> = Vec::new();
        let mut pos = 0u64;
        let mut total = 0usize;
        while pos + HEADER_LEN as u64 <= file_len {
            if file.seek(SeekFrom::Start(pos)).is_err() {
                break;
            }
            if file.read_exact(&mut header).is_err() {
                break;
            }
            self.bytes_read.fetch_add(HEADER_LEN as u64, Ordering::Relaxed);
            let Some(h) = parse_header(&header) else { break };
            let end = pos + HEADER_LEN as u64 + h.len as u64;
            if end >= file_len {
                break; // truncated payload / missing terminator
            }
            if walked.is_empty() && h.kind != b'o' {
                break;
            }
            total = match h.kind {
                b'a' => total + h.count as usize,
                b'c' => h.count as usize,
                _ => 0, // b'o'
            };
            walked.push((pos, h, total));
            pos = end + 1;
        }
        // The tail may be torn mid-payload with an intact header:
        // validate backwards until a checksummed record holds.
        let mut last_valid = None;
        for i in (0..walked.len()).rev() {
            let (offset, h, _) = walked[i];
            if self.payload_checks_out(&mut file, offset, h) {
                last_valid = Some(i);
                break;
            }
        }
        let Some(last) = last_valid else {
            return Err(Error::invalid_request("session log: empty"));
        };
        // Parse the open record — the only JSON body this path reads.
        let (open_offset, open_header, _) = walked[0];
        let mut buf = vec![0u8; open_header.len];
        file.seek(SeekFrom::Start(open_offset + HEADER_LEN as u64))?;
        file.read_exact(&mut buf)?;
        self.bytes_read.fetch_add(buf.len() as u64, Ordering::Relaxed);
        if fnv64(&buf) != open_header.sum {
            return Err(Error::invalid_request("session log: torn open record"));
        }
        let text = std::str::from_utf8(&buf)
            .map_err(|_| Error::invalid_request("session log: non-utf8 open"))?;
        let record = Json::parse(text)?;
        if record.get("type").as_str() != Some("open") {
            return Err(Error::invalid_request(
                "session log: first record is not 'open'",
            ));
        }
        check_version(&record)?;
        let meta = SessionMeta::from_json(record.get("meta"))?;
        // Repair a crash-torn tail while we know exactly where the
        // valid prefix ends — recovery is where torn tails originate,
        // and leaving them would hide post-recovery appends.
        let (last_offset, last_header, _) = walked[last];
        let valid_end =
            last_offset + (HEADER_LEN + last_header.len + 1) as u64;
        if valid_end < file_len {
            drop(file);
            self.repair_tail(id, path, file_len, valid_end);
        }
        Ok((meta, walked[last].2))
    }
}

/// Inverse of `path_for`'s file naming: `sess_<id:016x>.log` → id.
fn parse_session_filename(name: &str) -> Option<u64> {
    let hex = name.strip_prefix("sess_")?.strip_suffix(".log")?;
    u64::from_str_radix(hex, 16).ok()
}

fn open_record(meta: &SessionMeta) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("type".to_string(), Json::Str("open".to_string()));
    obj.insert("v".to_string(), Json::Num(FORMAT_VERSION as f64));
    obj.insert("meta".to_string(), meta.to_json());
    Json::Obj(obj).to_string_compact()
}

fn ckpt_record(snapshot: &Json) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("type".to_string(), Json::Str("ckpt".to_string()));
    obj.insert("snap".to_string(), snapshot.clone());
    Json::Obj(obj).to_string_compact()
}

/// Observation count a snapshot holds (`"ys"` length, either encoding)
/// — the ckpt record's header count, so metadata scans never parse the
/// body.
fn snapshot_len(snapshot: &Json) -> usize {
    crate::elements::serde::obs_len_from_json(snapshot.get("ys")).unwrap_or(0)
}

impl SessionStore for DiskStore {
    fn name(&self) -> &'static str {
        "disk"
    }

    fn create(&self, id: u64, meta: &SessionMeta) -> Result<()> {
        let _guard = self.lock_for(id);
        let path = self.path_for(id);
        let tmp = path.with_extension("tmp");
        {
            let mut file = fs::File::create(&tmp)?;
            file.write_all(&frame(&open_record(meta), b'o', 0))?;
            file.sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        self.log_versions.lock().unwrap().insert(id, FORMAT_VERSION);
        self.sync_parent(&path)
    }

    fn log_append(&self, id: u64, ys: &[u32]) -> Result<()> {
        // Match the log's recorded format version: a v2-stamped log
        // keeps receiving decimal append records (so a pre-v3 reader
        // stays able to parse everything its stamp claims) until a
        // compaction rewrites the whole log at the current version.
        let ys_json = if self.log_format_version(id) >= 3 {
            crate::elements::serde::obs_to_json(ys)
        } else {
            Json::Arr(ys.iter().map(|&y| Json::Num(y as f64)).collect())
        };
        let mut obj = BTreeMap::new();
        obj.insert("type".to_string(), Json::Str("append".to_string()));
        obj.insert("ys".to_string(), ys_json);
        self.append_record(id, &Json::Obj(obj).to_string_compact(), ys.len())
    }

    fn compact(&self, id: u64, meta: &SessionMeta, snapshot: &Json) -> Result<()> {
        // Atomically replace the log with its minimal equivalent. The
        // lock spans the existence check through the rename: a
        // concurrent same-id log_append cannot land in between (it would
        // be dropped from the rewrite), and a removed session cannot be
        // resurrected by a racing compact.
        let _guard = self.lock_for(id);
        let path = self.path_for(id);
        if !path.exists() {
            return Err(Error::invalid_request(format!(
                "store: unknown session {id}"
            )));
        }
        let tmp = path.with_extension("tmp");
        {
            let mut file = fs::File::create(&tmp)?;
            file.write_all(&frame(&open_record(meta), b'o', 0))?;
            file.write_all(&frame(
                &ckpt_record(snapshot),
                b'c',
                snapshot_len(snapshot),
            ))?;
            file.sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        // The rewrite stamps the current version — later appends may
        // use the current encodings.
        self.log_versions.lock().unwrap().insert(id, FORMAT_VERSION);
        self.sync_parent(&path)
    }

    fn restore(&self, id: u64) -> Result<StoredSession> {
        self.read_stored(id)
    }

    fn remove(&self, id: u64) -> Result<()> {
        let _guard = self.lock_for(id);
        self.log_versions.lock().unwrap().remove(&id);
        match fs::remove_file(self.path_for(id)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(Error::Io(e)),
        }
    }

    fn recover(&self) -> Result<Vec<(u64, StoredSession)>> {
        let mut out = Vec::new();
        for (id, path) in self.scan_ids()? {
            // Unreadable logs are skipped (their valid prefix may still
            // be recovered on a later restore attempt), never fatal to
            // the rest of the fleet.
            if let Ok(stored) = self.read_stored_at(id, &path) {
                out.push((id, stored));
            }
        }
        Ok(out)
    }

    fn recover_meta(&self) -> Result<Vec<(u64, SessionMeta, usize)>> {
        let mut out = Vec::new();
        for (id, path) in self.scan_ids()? {
            if let Ok((meta, len)) = self.read_meta_at(id, &path) {
                out.push((id, meta, len));
            }
        }
        Ok(out)
    }

    fn max_id(&self) -> Result<Option<u64>> {
        // Filename scan only — no log is opened or parsed, so this is
        // safe to run on every coordinator construction.
        Ok(self.scan_ids()?.last().map(|(id, _)| *id))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::tempdir;
    use super::*;
    use crate::engine::{SessionKind, SessionOptions};

    fn meta() -> SessionMeta {
        SessionMeta {
            model: "ge".to_string(),
            options: SessionOptions {
                block: Some(16),
                track_map: false,
                kind: SessionKind::SumProduct,
            },
            lag: 8,
            fingerprint: Some(0x0123_4567_89AB_CDEF),
        }
    }

    #[test]
    fn frame_round_trip_and_checksum() {
        let rec = r#"{"type":"open","meta":{}}"#;
        let framed = frame(rec, b'o', 0);
        assert_eq!(framed.len(), HEADER_LEN + rec.len() + 1);
        let parsed = parse_records(&framed);
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].get("type").as_str(), Some("open"));

        // The header's kind/count round-trip too.
        let h = parse_header(&framed[..HEADER_LEN]).unwrap();
        assert_eq!((h.kind, h.count, h.len), (b'o', 0, rec.len()));
        let ap = frame(r#"{"type":"append","ys":[0,1]}"#, b'a', 2);
        let h = parse_header(&ap[..HEADER_LEN]).unwrap();
        assert_eq!((h.kind, h.count), (b'a', 2));
        // An unknown kind char is a framing violation.
        let mut bad_kind = framed.clone();
        bad_kind[34] = b'x';
        assert!(parse_records(&bad_kind).is_empty());

        // A flipped payload byte fails the checksum → record dropped.
        let mut corrupt = framed.clone();
        corrupt[HEADER_LEN + 2] ^= 0x01;
        assert!(parse_records(&corrupt).is_empty());

        // Truncations anywhere in the record drop it cleanly.
        for cut in [1, HEADER_LEN - 1, HEADER_LEN + 3, framed.len() - 1] {
            assert!(parse_records(&framed[..cut]).is_empty(), "cut={cut}");
        }
    }

    #[test]
    fn disk_store_lifecycle() {
        let dir = tempdir("disk-lifecycle");
        let store = DiskStore::open(&dir).unwrap();
        assert_eq!(store.name(), "disk");
        store.create(3, &meta()).unwrap();
        store.log_append(3, &[0, 1, 1]).unwrap();
        store.log_append(3, &[1, 0]).unwrap();

        // Sharded layout: the log lives under its id's shard directory.
        assert!(store.path_for(3).starts_with(dir.join("03")));
        assert!(store.path_for(3).exists());

        let s = store.restore(3).unwrap();
        assert_eq!(s.meta, meta());
        assert!(s.snapshot.is_none());
        assert_eq!(s.appends, vec![vec![0, 1, 1], vec![1, 0]]);
        assert_eq!(s.len(), 5);

        // A compact checkpoint supersedes prior records; appends logged
        // after it stack on top…
        let snap = Json::parse(r#"{"ys": [0, 1, 1, 1, 0]}"#).unwrap();
        store.compact(3, &meta(), &snap).unwrap();
        store.log_append(3, &[1]).unwrap();
        let s = store.restore(3).unwrap();
        assert_eq!(s.snapshot.as_ref(), Some(&snap));
        assert_eq!(s.appends, vec![vec![1]]);
        assert_eq!(s.len(), 6);

        // …and a re-compact rewrites the file to its minimal form.
        let size_before = fs::metadata(store.path_for(3)).unwrap().len();
        let snap2 = Json::parse(r#"{"ys": [0, 1, 1, 1, 0, 1]}"#).unwrap();
        store.compact(3, &meta(), &snap2).unwrap();
        let size_after = fs::metadata(store.path_for(3)).unwrap().len();
        assert!(size_after < size_before, "{size_after} !< {size_before}");
        let s = store.restore(3).unwrap();
        assert_eq!(s.meta, meta(), "compact must re-seed the open meta");
        assert_eq!(s.snapshot.as_ref(), Some(&snap2));
        assert!(s.appends.is_empty());
        // Compacting a removed/unknown session is a typed error, not a
        // silent resurrection.
        assert!(store.compact(77, &meta(), &snap2).is_err());

        // recover() enumerates sessions; foreign files / bad ids skip.
        store.create(9, &meta()).unwrap();
        fs::write(dir.join("README"), b"not a log").unwrap();
        fs::write(dir.join("sess_zzzz.log"), b"bad id").unwrap();
        fs::write(dir.join("0a").join("notes.txt"), b"in-shard junk").unwrap();
        let all = store.recover().unwrap();
        assert_eq!(all.iter().map(|(id, _)| *id).collect::<Vec<_>>(), vec![3, 9]);
        // max_id sees every stored session without reading a single log.
        assert_eq!(store.max_id().unwrap(), Some(9));

        store.remove(3).unwrap();
        store.remove(3).unwrap(); // idempotent
        assert!(store.restore(3).is_err());
        assert!(store.log_append(3, &[0]).is_err());
        assert_eq!(store.recover().unwrap().len(), 1);

        // Temp files orphaned by a crashed create/compact are swept the
        // next time the store opens; live logs are untouched.
        let orphan = dir.join("aa").join("sess_00000000000000aa.tmp");
        fs::write(&orphan, b"orphan").unwrap();
        let reopened = DiskStore::open(&dir).unwrap();
        assert!(!orphan.exists(), "tmp orphan must be swept at open");
        assert_eq!(reopened.recover().unwrap().len(), 1);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn inline_fsync_mode_still_works() {
        // A zero window disables group commit: one fsync per append,
        // same durable result.
        let dir = tempdir("disk-inline");
        let store = DiskStore::open(&dir)
            .unwrap()
            .with_group_commit_window(Duration::ZERO);
        store.create(1, &meta()).unwrap();
        store.log_append(1, &[0, 1]).unwrap();
        store.log_append(1, &[1]).unwrap();
        assert_eq!(store.log_syncs(), 2, "inline mode syncs per append");
        assert_eq!(store.appends_logged(), 2);
        assert_eq!(store.restore(1).unwrap().len(), 3);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mid_log_ckpt_record_supersedes_prefix() {
        // The reader must honor a checkpoint record wherever it appears
        // in the log (robustness for hand-repaired or future layouts),
        // even though today's writers only ever place it right after the
        // open record.
        let mut image = Vec::new();
        image.extend_from_slice(&frame(&open_record(&meta()), b'o', 0));
        image.extend_from_slice(&frame(r#"{"type":"append","ys":[0,1]}"#, b'a', 2));
        image.extend_from_slice(&frame(
            r#"{"type":"ckpt","snap":{"ys":[0,1,1]}}"#,
            b'c',
            3,
        ));
        image.extend_from_slice(&frame(r#"{"type":"append","ys":[1]}"#, b'a', 1));
        let stored = fold_records(&parse_records(&image)).unwrap();
        assert_eq!(stored.meta, meta());
        assert_eq!(
            stored.snapshot.as_ref().map(|s| s.get("ys").as_arr().unwrap().len()),
            Some(3)
        );
        assert_eq!(stored.appends, vec![vec![1]]);
        assert_eq!(stored.len(), 4);
    }

    #[test]
    fn truncated_tail_keeps_fully_logged_appends() {
        // The crash test: cut the log mid-record and verify every
        // fully-framed append survives.
        let dir = tempdir("disk-truncate");
        let store = DiskStore::open(&dir).unwrap();
        store.create(1, &meta()).unwrap();
        for k in 0..5u32 {
            store.log_append(1, &[k % 2, (k + 1) % 2, k % 2]).unwrap();
        }
        let path = store.path_for(1);
        let full = fs::read(&path).unwrap();

        // Truncate into the last record (simulated crash mid-write):
        // every cut here is shorter than one framed append record.
        for cut in [1usize, 10, 30] {
            fs::write(&path, &full[..full.len() - cut]).unwrap();
            let s = store.restore(1).unwrap();
            assert_eq!(s.appends.len(), 4, "cut={cut}");
            assert_eq!(s.len(), 12, "cut={cut}");
            // The metadata-only scan agrees with the full parse.
            let metas = store.recover_meta().unwrap();
            assert_eq!(metas.len(), 1, "cut={cut}");
            assert_eq!(metas[0].2, 12, "cut={cut}");
        }

        // Garbage appended after valid records is ignored the same way.
        let mut garbage = full.clone();
        garbage.extend_from_slice(b"0000000000000bad ");
        fs::write(&path, &garbage).unwrap();
        assert_eq!(store.restore(1).unwrap().appends.len(), 5);
        assert_eq!(store.recover_meta().unwrap()[0].2, 15);

        // A log truncated into its *open* record is unreadable — both
        // recovery scans skip it instead of failing the fleet.
        fs::write(&path, &full[..10]).unwrap();
        assert!(store.restore(1).is_err());
        assert!(store.recover().unwrap().is_empty());
        assert!(store.recover_meta().unwrap().is_empty());
        fs::remove_dir_all(&dir).ok();
    }

    /// A crash-torn tail is truncated away by the recovery-path reads,
    /// so appends acked *after* recovery land on a clean tail — not
    /// behind garbage that would hide them from prefix-valid readers.
    #[test]
    fn append_after_torn_tail_recovery_stays_visible() {
        let dir = tempdir("disk-repair");
        let store = DiskStore::open(&dir).unwrap();
        store.create(2, &meta()).unwrap();
        store.log_append(2, &[0, 1]).unwrap();
        let path = store.path_for(2);
        // Crash mid-append: a half-written frame at the tail.
        let torn = frame(r#"{"type":"append","ys":[1,1,1]}"#, b'a', 3);
        let mut bytes = fs::read(&path).unwrap();
        let valid_len = bytes.len();
        bytes.extend_from_slice(&torn[..20]);
        fs::write(&path, &bytes).unwrap();

        // The restore read repairs the tail back to the valid prefix…
        assert_eq!(store.restore(2).unwrap().len(), 2);
        assert_eq!(fs::metadata(&path).unwrap().len() as usize, valid_len);
        // …so a post-recovery append is visible to every reader.
        store.log_append(2, &[0]).unwrap();
        assert_eq!(store.restore(2).unwrap().len(), 3);
        assert_eq!(store.recover_meta().unwrap()[0].2, 3);

        // The metadata-only scan repairs too (a fresh torn tail).
        let mut bytes = fs::read(&path).unwrap();
        let valid_len = bytes.len();
        bytes.extend_from_slice(&torn[..40]);
        fs::write(&path, &bytes).unwrap();
        assert_eq!(store.recover_meta().unwrap()[0].2, 3);
        assert_eq!(fs::metadata(&path).unwrap().len() as usize, valid_len);
        fs::remove_dir_all(&dir).ok();
    }

    /// The group-commit durability property: an append is acked only
    /// after a covering fsync, so a crash (byte truncation) at *any*
    /// offset keeps every record that was fully framed before the cut —
    /// acked appends are only ever lost to cuts that also ate their
    /// frame, which the ack ordering guarantees never happens for a
    /// sync the appender waited on. Cuts mid-batch lose only the
    /// unacked tail records.
    #[test]
    fn acked_appends_survive_any_truncation() {
        let dir = tempdir("disk-acked");
        let store = DiskStore::open(&dir).unwrap();
        store.create(5, &meta()).unwrap();
        let path = store.path_for(5);
        // File length after create, then after each acked append:
        // every boundary is a valid crash-recovery state.
        let mut bounds = vec![fs::metadata(&path).unwrap().len() as usize];
        let mut chunks: Vec<Vec<u32>> = Vec::new();
        for k in 0..6u32 {
            let chunk: Vec<u32> = (0..=k).map(|j| j % 2).collect();
            store.log_append(5, &chunk).unwrap();
            bounds.push(fs::metadata(&path).unwrap().len() as usize);
            chunks.push(chunk);
        }
        let full = fs::read(&path).unwrap();
        assert_eq!(*bounds.last().unwrap(), full.len());

        let mut runner = crate::proptestx::Runner::new("store-acked-truncate");
        runner.run(60, |rng| {
            let cut = (rng.next_u64() as usize) % (full.len() + 1);
            fs::write(&path, &full[..cut]).unwrap();
            // Records fully framed before the cut: appends whose
            // post-append boundary fits inside it.
            let expect = bounds[1..].iter().filter(|&&b| b <= cut).count();
            if cut < bounds[0] {
                // Cut into the open record: the log is unreadable, the
                // session is skipped, nothing was ever acked from it.
                assert!(store.restore(5).is_err());
                return;
            }
            let s = store.restore(5).unwrap();
            assert_eq!(s.appends.len(), expect, "cut={cut}");
            assert_eq!(&s.appends[..], &chunks[..expect], "cut={cut}");
        });
        // Exhaustive sweep over every record boundary for good measure.
        for (i, &b) in bounds.iter().enumerate() {
            fs::write(&path, &full[..b]).unwrap();
            let s = store.restore(5).unwrap();
            assert_eq!(s.appends.len(), i.min(chunks.len()));
        }
        fs::remove_dir_all(&dir).ok();
    }

    /// Concurrent appends inside one deadline window share sync points:
    /// with a generous window, barrier-started rounds of 8 concurrent
    /// appends must complete in fewer sync batches than appends (once
    /// any leader sees a second registrant it sleeps the window, and 8
    /// live threads cannot serialize perfectly across 4 rounds) — and
    /// every acked record must be durably present.
    #[test]
    fn group_commit_batches_concurrent_appends() {
        let dir = tempdir("disk-group");
        let store = std::sync::Arc::new(
            DiskStore::open(&dir)
                .unwrap()
                .with_group_commit_window(Duration::from_millis(50)),
        );
        let n = 8u64;
        let rounds = 4u32;
        for id in 0..n {
            store.create(id, &meta()).unwrap();
        }
        let barrier = std::sync::Arc::new(std::sync::Barrier::new(n as usize));
        std::thread::scope(|scope| {
            for id in 0..n {
                let store = std::sync::Arc::clone(&store);
                let barrier = std::sync::Arc::clone(&barrier);
                scope.spawn(move || {
                    for _ in 0..rounds {
                        barrier.wait();
                        store.log_append(id, &[id as u32 % 2, 1]).unwrap();
                    }
                });
            }
        });
        let total = n * rounds as u64;
        assert_eq!(store.appends_logged(), total);
        assert_eq!(store.synced_appends(), total);
        // Per-file fsyncs are floor-bounded at one per dirty log per
        // batch; what batching amortizes is the number of sync *points*
        // — the barriers appends wait on.
        assert_eq!(store.log_syncs(), total);
        assert!(
            store.sync_batches() < total,
            "{total} concurrent appends took {} sync batches — group \
             commit never batched",
            store.sync_batches()
        );
        for id in 0..n {
            assert_eq!(
                store.restore(id).unwrap().len(),
                2 * rounds as usize,
                "id={id}"
            );
        }
        fs::remove_dir_all(&dir).ok();
    }

    /// The metadata-only recovery scan reads frame headers plus two
    /// payloads per log — not the stored bodies. With fat appends the
    /// byte-read counter must stay far below the stored volume, while
    /// the recovered (meta, len) agree exactly with a full parse.
    #[test]
    fn recover_meta_reads_headers_not_bodies() {
        let dir = tempdir("disk-meta-scan");
        let store = DiskStore::open(&dir).unwrap();
        // Fat enough that packed (v3) bodies still dwarf the 53-byte
        // frame headers the metadata scan reads.
        let big: Vec<u32> = (0..8000).map(|k| k % 2).collect();
        for id in [2u64, 7, 11] {
            store.create(id, &meta()).unwrap();
            for _ in 0..12 {
                store.log_append(id, &big).unwrap();
            }
            // Keep the log tail small: the scan's backwards validation
            // reads the last payload, and the point of this test is
            // that it reads nothing else.
            store.log_append(id, &[0, 1, 1]).unwrap();
        }
        // One session also carries a checkpoint (superseding count).
        let snap = Json::parse(r#"{"ys": [0, 1, 1]}"#).unwrap();
        store.compact(7, &meta(), &snap).unwrap();
        store.log_append(7, &[1, 1]).unwrap();

        let stored_bytes: u64 = [2u64, 7, 11]
            .iter()
            .map(|&id| fs::metadata(store.path_for(id)).unwrap().len())
            .sum();
        let before = store.bytes_read();
        let metas = store.recover_meta().unwrap();
        let scan_bytes = store.bytes_read() - before;

        assert_eq!(metas.len(), 3);
        let full = store.recover().unwrap();
        for ((id_m, meta_m, len_m), (id_f, stored)) in
            metas.iter().zip(full.iter())
        {
            assert_eq!(id_m, id_f);
            assert_eq!(meta_m, &stored.meta);
            assert_eq!(*len_m, stored.len(), "id={id_m}");
        }
        assert_eq!(metas[1].2, 5, "ckpt(3) + append(2)");
        assert!(
            scan_bytes * 5 < stored_bytes,
            "metadata scan read {scan_bytes} of {stored_bytes} stored bytes \
             — that is a body read, not a header walk"
        );
        fs::remove_dir_all(&dir).ok();
    }

    /// A version-2 log (decimal append arrays + decimal snapshot
    /// payloads — the pre-compression encoding) reads back exactly: the
    /// payload parsers accept both encodings, so the v3 bump never
    /// strands old stores.
    #[test]
    fn v2_decimal_log_stays_readable() {
        let dir = tempdir("disk-v2-compat");
        let store = DiskStore::open(&dir).unwrap();
        let open = format!(
            r#"{{"meta":{},"type":"open","v":2}}"#,
            meta().to_json().to_string_compact()
        );
        let snap_decimal = Json::parse(r#"{"ys": [0, 1, 1]}"#).unwrap();
        let mut image = Vec::new();
        image.extend_from_slice(&frame(&open, b'o', 0));
        image.extend_from_slice(&frame(
            &format!(
                r#"{{"snap":{},"type":"ckpt"}}"#,
                snap_decimal.to_string_compact()
            ),
            b'c',
            3,
        ));
        image.extend_from_slice(&frame(r#"{"type":"append","ys":[1,0]}"#, b'a', 2));
        fs::write(store.path_for(6), &image).unwrap();

        let s = store.restore(6).unwrap();
        assert_eq!(s.meta, meta());
        assert_eq!(s.snapshot.as_ref(), Some(&snap_decimal));
        assert_eq!(s.appends, vec![vec![1, 0]]);
        assert_eq!(s.len(), 5);
        let metas = store.recover_meta().unwrap();
        assert_eq!((metas[0].0, metas[0].2), (6, 5));

        // New appends match the log's recorded version — a v2 log keeps
        // receiving *decimal* records, so its "v":2 stamp stays an
        // honest description of every record (a rolled-back v2 reader
        // can still parse the whole log).
        store.log_append(6, &[0, 0, 1]).unwrap();
        let bytes = fs::read(store.path_for(6)).unwrap();
        let text = String::from_utf8_lossy(&bytes);
        assert!(
            text.contains(r#""ys":[0,0,1]"#),
            "append to a v2 log must use the decimal encoding"
        );
        let s = store.restore(6).unwrap();
        assert_eq!(s.appends, vec![vec![1, 0], vec![0, 0, 1]]);
        assert_eq!(s.len(), 8);

        // Compaction rewrites the log at the current version; appends
        // after it use the packed encoding.
        store.compact(6, &meta(), &snap_decimal).unwrap();
        store.log_append(6, &[1, 1, 0, 0]).unwrap();
        let text =
            String::from_utf8_lossy(&fs::read(store.path_for(6)).unwrap())
                .into_owned();
        assert!(text.contains(r#""v":3"#), "compaction must re-stamp");
        assert!(
            text.contains(r#""ys":{"#),
            "append after compaction must use the packed encoding"
        );
        assert_eq!(store.restore(6).unwrap().len(), 7);
        fs::remove_dir_all(&dir).ok();
    }

    /// The packed append encoding is materially smaller than the decimal
    /// arrays it replaced (the size claim behind the v3 bump; the full
    /// snapshot ratio is measured in `benches/streaming.rs`).
    #[test]
    fn packed_appends_shrink_the_log() {
        let ys: Vec<u32> = (0..512).map(|k| k % 2).collect();
        let packed = {
            let mut obj = BTreeMap::new();
            obj.insert("type".to_string(), Json::Str("append".to_string()));
            obj.insert("ys".to_string(), crate::elements::serde::obs_to_json(&ys));
            frame(&Json::Obj(obj).to_string_compact(), b'a', ys.len()).len()
        };
        let decimal = {
            let mut obj = BTreeMap::new();
            obj.insert("type".to_string(), Json::Str("append".to_string()));
            obj.insert(
                "ys".to_string(),
                Json::Arr(ys.iter().map(|&y| Json::Num(y as f64)).collect()),
            );
            frame(&Json::Obj(obj).to_string_compact(), b'a', ys.len()).len()
        };
        assert!(
            packed * 2 < decimal,
            "packed append record {packed} bytes !< half of decimal {decimal}"
        );
    }

    #[test]
    fn future_format_version_is_rejected() {
        let dir = tempdir("disk-future");
        let store = DiskStore::open(&dir).unwrap();
        let record = format!(
            r#"{{"meta":{},"type":"open","v":99}}"#,
            meta().to_json().to_string_compact()
        );
        fs::write(store.path_for(4), frame(&record, b'o', 0)).unwrap();
        assert!(store.restore(4).is_err(), "future version must not parse");
        assert!(store.recover().unwrap().is_empty());
        assert!(store.recover_meta().unwrap().is_empty());
        // …but the id still seeds the allocator: never overwrite it.
        assert_eq!(store.max_id().unwrap(), Some(4));
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_flat_layout_is_adopted_into_shards() {
        let dir = tempdir("disk-legacy");
        // A pre-sharding store left its log at the root.
        fs::create_dir_all(&dir).unwrap();
        let mut image = Vec::new();
        image.extend_from_slice(&frame(&open_record(&meta()), b'o', 0));
        image.extend_from_slice(&frame(r#"{"type":"append","ys":[1,0]}"#, b'a', 2));
        fs::write(dir.join("sess_0000000000000012.log"), &image).unwrap();

        let store = DiskStore::open(&dir).unwrap();
        assert!(
            !dir.join("sess_0000000000000012.log").exists(),
            "legacy log must be relocated"
        );
        assert!(store.path_for(0x12).exists());
        assert_eq!(store.restore(0x12).unwrap().len(), 2);
        assert_eq!(store.max_id().unwrap(), Some(0x12));
        fs::remove_dir_all(&dir).ok();
    }
}

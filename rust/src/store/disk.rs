//! Disk-backed [`SessionStore`]: one append-ahead log file per session,
//! `std::fs` only.
//!
//! ## File format
//!
//! `<dir>/sess_<id:016x>.log` is a sequence of framed records:
//!
//! ```text
//! ┌────────────────────────────────┬─────────────┬────┐
//! │ "llllllllllllllll cccccccccccc │   payload   │ \n │
//! │  cccc\n"  (len, fnv64 — hex)   │ (len bytes) │    │
//! └────────────────────────────────┴─────────────┴────┘
//! ```
//!
//! The 34-byte header carries the payload length and its FNV-1a 64
//! checksum, both as fixed-width hex; the payload is one compact-JSON
//! record:
//!
//! * `{"type":"open","meta":{…}}` — written once by [`create`];
//! * `{"type":"append","ys":[…]}` — one per logged observation chunk;
//! * `{"type":"ckpt","snap":{…}}` — a full [`Session::snapshot`],
//!   superseding every record before it.
//!
//! ## Crash safety
//!
//! Records are appended with a single `write_all` + fsync and parsed
//! back prefix-wise: the reader stops at the first truncated header,
//! short payload, checksum mismatch or unparsable JSON, and returns
//! every record before it. A crash mid-append therefore costs at most
//! the half-written tail record — and since the coordinator logs a
//! chunk *before* applying it to the resident session, every
//! observation the resident session ever held is a fully-framed,
//! fsynced record. [`compact`] rewrites the log as `open` + `ckpt` via
//! a temp file and an atomic rename (followed on unix by a directory
//! fsync, so the entry itself survives the crash; other targets have no
//! portable directory fsync and weaken that to best-effort), leaving
//! either the old or the new log, never a mix. File operations are serialized per session id
//! (sharded locks): same-id append/compact/remove are mutually
//! exclusive, while appends to different sessions fsync concurrently.
//!
//! [`create`]: SessionStore::create
//! [`compact`]: SessionStore::compact
//! [`Session::snapshot`]: crate::engine::Session::snapshot

use std::collections::BTreeMap;
use std::fs::{self, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::jsonx::Json;

use super::{SessionMeta, SessionStore, StoredSession};

/// Header layout: 16 hex chars (length), space, 16 hex chars (fnv64),
/// newline.
const HEADER_LEN: usize = 34;

/// The framing checksum: fresh-start FNV-1a 64 (`rng::fnv1a_64`).
fn fnv64(bytes: &[u8]) -> u64 {
    crate::rng::fnv1a_64(crate::rng::FNV1A_OFFSET, bytes)
}

fn frame(payload: &str) -> Vec<u8> {
    let bytes = payload.as_bytes();
    let mut out =
        format!("{:016x} {:016x}\n", bytes.len(), fnv64(bytes)).into_bytes();
    out.extend_from_slice(bytes);
    out.push(b'\n');
    out
}

fn parse_hex(bytes: &[u8]) -> Option<u64> {
    let s = std::str::from_utf8(bytes).ok()?;
    u64::from_str_radix(s, 16).ok()
}

/// Parse the valid record prefix of a log image; everything after the
/// first framing violation (the crash tail) is ignored.
fn parse_records(data: &[u8]) -> Vec<Json> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos + HEADER_LEN <= data.len() {
        let header = &data[pos..pos + HEADER_LEN];
        if header[16] != b' ' || header[33] != b'\n' {
            break;
        }
        let (Some(len), Some(sum)) =
            (parse_hex(&header[0..16]), parse_hex(&header[17..33]))
        else {
            break;
        };
        let start = pos + HEADER_LEN;
        let Some(end) = start.checked_add(len as usize) else { break };
        if end >= data.len() || data[end] != b'\n' {
            break; // truncated payload / missing terminator
        }
        let payload = &data[start..end];
        if fnv64(payload) != sum {
            break; // torn write
        }
        let Ok(text) = std::str::from_utf8(payload) else { break };
        let Ok(record) = Json::parse(text) else { break };
        out.push(record);
        pos = end + 1;
    }
    out
}

/// Fold a record sequence into [`StoredSession`] form. The first record
/// must be `open`; a `ckpt` supersedes everything before it.
fn fold_records(records: &[Json]) -> Result<StoredSession> {
    let first = records
        .first()
        .ok_or_else(|| Error::invalid_request("session log: empty"))?;
    if first.get("type").as_str() != Some("open") {
        return Err(Error::invalid_request(
            "session log: first record is not 'open'",
        ));
    }
    let meta = SessionMeta::from_json(first.get("meta"))?;
    let mut stored = StoredSession { meta, snapshot: None, appends: Vec::new() };
    for record in &records[1..] {
        match record.get("type").as_str() {
            Some("append") => {
                let ys = record
                    .get("ys")
                    .as_arr()
                    .ok_or_else(|| {
                        Error::invalid_request("session log: append without 'ys'")
                    })?
                    .iter()
                    .map(|v| {
                        v.as_usize().and_then(|u| u32::try_from(u).ok()).ok_or_else(
                            || Error::invalid_request("session log: bad symbol"),
                        )
                    })
                    .collect::<Result<Vec<u32>>>()?;
                stored.appends.push(ys);
            }
            Some("ckpt") => {
                stored.snapshot = Some(record.get("snap").clone());
                stored.appends.clear();
            }
            _ => {
                return Err(Error::invalid_request(
                    "session log: unknown record type",
                ))
            }
        }
    }
    Ok(stored)
}

/// Number of id-sharded file-op locks (see `DiskStore::locks`).
const LOCK_SHARDS: usize = 16;

/// Append-ahead-log session store under a single directory.
pub struct DiskStore {
    dir: PathBuf,
    /// Per-id shard locks. Same-session append/compact/remove must be
    /// mutually exclusive (an append racing a compact's rename would
    /// land on the unlinked old inode and vanish); different sessions
    /// touch different files, so they only share a lock by shard-hash
    /// accident — per-append fsyncs do not serialize fleet-wide.
    locks: Vec<Mutex<()>>,
}

impl DiskStore {
    /// Open (creating if needed) a store rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<DiskStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        // Sweep temp files orphaned by a crash between tmp-write and
        // rename: a create-crash session was never acknowledged, and a
        // compact-crash left the original log intact — either way the
        // tmp is dead weight that would otherwise accumulate forever.
        for entry in fs::read_dir(&dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if name.starts_with("sess_") && name.ends_with(".tmp") {
                let _ = fs::remove_file(entry.path());
            }
        }
        let locks = (0..LOCK_SHARDS).map(|_| Mutex::new(())).collect();
        Ok(DiskStore { dir, locks })
    }

    /// The store's root directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_for(&self, id: u64) -> PathBuf {
        self.dir.join(format!("sess_{id:016x}.log"))
    }

    fn lock_for(&self, id: u64) -> std::sync::MutexGuard<'_, ()> {
        self.locks[(id % LOCK_SHARDS as u64) as usize].lock().unwrap()
    }

    /// fsync the store directory so a just-created/renamed log entry
    /// survives a crash — file-content fsync alone does not cover the
    /// directory metadata on POSIX. Non-unix targets have no portable
    /// directory-fsync, so there this is a no-op and the
    /// entry-survives-crash guarantee weakens to best-effort (the log
    /// contents themselves are still fsynced).
    fn sync_dir(&self) -> Result<()> {
        #[cfg(unix)]
        fs::File::open(&self.dir)?.sync_all()?;
        Ok(())
    }

    fn append_record(&self, id: u64, payload: &str) -> Result<()> {
        let _guard = self.lock_for(id);
        let path = self.path_for(id);
        let mut file = OpenOptions::new().append(true).open(&path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                Error::invalid_request(format!("store: unknown session {id}"))
            } else {
                Error::Io(e)
            }
        })?;
        // fsync: the append-ahead durability argument (module docs) rests
        // on the record reaching stable storage before the resident
        // session applies it — `flush` alone stops at the page cache.
        // Group commit across sessions is a ROADMAP follow-on.
        let len_before = file.metadata()?.len();
        if let Err(e) =
            file.write_all(&frame(payload)).and_then(|()| file.sync_all())
        {
            // Roll the torn tail back (best-effort): leaving partial
            // frame bytes mid-log would hide every later acknowledged
            // record from the prefix-valid reader.
            let _ = file.set_len(len_before);
            return Err(Error::Io(e));
        }
        Ok(())
    }

    fn read_stored(&self, id: u64) -> Result<StoredSession> {
        let path = self.path_for(id);
        let data = fs::read(&path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                Error::invalid_request(format!("store: unknown session {id}"))
            } else {
                Error::Io(e)
            }
        })?;
        fold_records(&parse_records(&data))
    }
}

/// Inverse of `path_for`'s naming scheme: `sess_<id:016x>.log` → id.
/// The single definition both directory scans (`recover`, `max_id`) go
/// through — if they ever diverged, `max_id` could under-seed the id
/// allocator and re-open the log-overwrite hazard it exists to prevent.
fn parse_session_filename(name: &str) -> Option<u64> {
    let hex = name.strip_prefix("sess_")?.strip_suffix(".log")?;
    u64::from_str_radix(hex, 16).ok()
}

fn open_record(meta: &SessionMeta) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("type".to_string(), Json::Str("open".to_string()));
    obj.insert("meta".to_string(), meta.to_json());
    Json::Obj(obj).to_string_compact()
}

fn ckpt_record(snapshot: &Json) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("type".to_string(), Json::Str("ckpt".to_string()));
    obj.insert("snap".to_string(), snapshot.clone());
    Json::Obj(obj).to_string_compact()
}

impl SessionStore for DiskStore {
    fn name(&self) -> &'static str {
        "disk"
    }

    fn create(&self, id: u64, meta: &SessionMeta) -> Result<()> {
        let _guard = self.lock_for(id);
        let path = self.path_for(id);
        let tmp = path.with_extension("tmp");
        {
            let mut file = fs::File::create(&tmp)?;
            file.write_all(&frame(&open_record(meta)))?;
            file.sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        self.sync_dir()
    }

    fn log_append(&self, id: u64, ys: &[u32]) -> Result<()> {
        let mut obj = BTreeMap::new();
        obj.insert("type".to_string(), Json::Str("append".to_string()));
        obj.insert(
            "ys".to_string(),
            Json::Arr(ys.iter().map(|&y| Json::Num(y as f64)).collect()),
        );
        self.append_record(id, &Json::Obj(obj).to_string_compact())
    }

    fn compact(&self, id: u64, meta: &SessionMeta, snapshot: &Json) -> Result<()> {
        // Atomically replace the log with its minimal equivalent. The
        // lock spans the existence check through the rename: a
        // concurrent same-id log_append cannot land in between (it would
        // be dropped from the rewrite), and a removed session cannot be
        // resurrected by a racing compact.
        let _guard = self.lock_for(id);
        let path = self.path_for(id);
        if !path.exists() {
            return Err(Error::invalid_request(format!(
                "store: unknown session {id}"
            )));
        }
        let tmp = path.with_extension("tmp");
        {
            let mut file = fs::File::create(&tmp)?;
            file.write_all(&frame(&open_record(meta)))?;
            file.write_all(&frame(&ckpt_record(snapshot)))?;
            file.sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        self.sync_dir()
    }

    fn restore(&self, id: u64) -> Result<StoredSession> {
        self.read_stored(id)
    }

    fn remove(&self, id: u64) -> Result<()> {
        let _guard = self.lock_for(id);
        match fs::remove_file(self.path_for(id)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(Error::Io(e)),
        }
    }

    fn recover(&self) -> Result<Vec<(u64, StoredSession)>> {
        let mut out = Vec::new();
        for entry in fs::read_dir(&self.dir)? {
            let name = entry?.file_name();
            let Some(id) = name.to_str().and_then(parse_session_filename) else {
                continue;
            };
            // Unreadable logs are skipped (their valid prefix may still
            // be recovered on a later restore attempt), never fatal to
            // the rest of the fleet.
            if let Ok(stored) = self.read_stored(id) {
                out.push((id, stored));
            }
        }
        out.sort_by_key(|(id, _)| *id);
        Ok(out)
    }

    fn max_id(&self) -> Result<Option<u64>> {
        // Filename scan only — no log is opened or parsed, so this is
        // safe to run on every coordinator construction.
        let mut max = None;
        for entry in fs::read_dir(&self.dir)? {
            let name = entry?.file_name();
            if let Some(id) = name.to_str().and_then(parse_session_filename) {
                max = Some(max.map_or(id, |m: u64| m.max(id)));
            }
        }
        Ok(max)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::tempdir;
    use super::*;
    use crate::engine::{SessionKind, SessionOptions};

    fn meta() -> SessionMeta {
        SessionMeta {
            model: "ge".to_string(),
            options: SessionOptions {
                block: Some(16),
                track_map: false,
                kind: SessionKind::SumProduct,
            },
            lag: 8,
            fingerprint: Some(0x0123_4567_89AB_CDEF),
        }
    }

    #[test]
    fn frame_round_trip_and_checksum() {
        let rec = r#"{"type":"open","meta":{}}"#;
        let framed = frame(rec);
        assert_eq!(framed.len(), HEADER_LEN + rec.len() + 1);
        let parsed = parse_records(&framed);
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].get("type").as_str(), Some("open"));

        // A flipped payload byte fails the checksum → record dropped.
        let mut corrupt = framed.clone();
        corrupt[HEADER_LEN + 2] ^= 0x01;
        assert!(parse_records(&corrupt).is_empty());

        // Truncations anywhere in the record drop it cleanly.
        for cut in [1, HEADER_LEN - 1, HEADER_LEN + 3, framed.len() - 1] {
            assert!(parse_records(&framed[..cut]).is_empty(), "cut={cut}");
        }
    }

    #[test]
    fn disk_store_lifecycle() {
        let dir = tempdir("disk-lifecycle");
        let store = DiskStore::open(&dir).unwrap();
        assert_eq!(store.name(), "disk");
        store.create(3, &meta()).unwrap();
        store.log_append(3, &[0, 1, 1]).unwrap();
        store.log_append(3, &[1, 0]).unwrap();

        let s = store.restore(3).unwrap();
        assert_eq!(s.meta, meta());
        assert!(s.snapshot.is_none());
        assert_eq!(s.appends, vec![vec![0, 1, 1], vec![1, 0]]);
        assert_eq!(s.len(), 5);

        // A compact checkpoint supersedes prior records; appends logged
        // after it stack on top…
        let snap = Json::parse(r#"{"ys": [0, 1, 1, 1, 0]}"#).unwrap();
        store.compact(3, &meta(), &snap).unwrap();
        store.log_append(3, &[1]).unwrap();
        let s = store.restore(3).unwrap();
        assert_eq!(s.snapshot.as_ref(), Some(&snap));
        assert_eq!(s.appends, vec![vec![1]]);
        assert_eq!(s.len(), 6);

        // …and a re-compact rewrites the file to its minimal form.
        let size_before = fs::metadata(store.path_for(3)).unwrap().len();
        let snap2 = Json::parse(r#"{"ys": [0, 1, 1, 1, 0, 1]}"#).unwrap();
        store.compact(3, &meta(), &snap2).unwrap();
        let size_after = fs::metadata(store.path_for(3)).unwrap().len();
        assert!(size_after < size_before, "{size_after} !< {size_before}");
        let s = store.restore(3).unwrap();
        assert_eq!(s.meta, meta(), "compact must re-seed the open meta");
        assert_eq!(s.snapshot.as_ref(), Some(&snap2));
        assert!(s.appends.is_empty());
        // Compacting a removed/unknown session is a typed error, not a
        // silent resurrection.
        assert!(store.compact(77, &meta(), &snap2).is_err());

        // recover() enumerates sessions; unknown ids / foreign files skip.
        store.create(9, &meta()).unwrap();
        fs::write(dir.join("README"), b"not a log").unwrap();
        fs::write(dir.join("sess_zzzz.log"), b"bad id").unwrap();
        let all = store.recover().unwrap();
        assert_eq!(all.iter().map(|(id, _)| *id).collect::<Vec<_>>(), vec![3, 9]);
        // max_id sees every stored session without reading a single log.
        assert_eq!(store.max_id().unwrap(), Some(9));

        store.remove(3).unwrap();
        store.remove(3).unwrap(); // idempotent
        assert!(store.restore(3).is_err());
        assert!(store.log_append(3, &[0]).is_err());
        assert_eq!(store.recover().unwrap().len(), 1);

        // Temp files orphaned by a crashed create/compact are swept the
        // next time the store opens; live logs are untouched.
        let orphan = dir.join("sess_00000000000000aa.tmp");
        fs::write(&orphan, b"orphan").unwrap();
        let reopened = DiskStore::open(&dir).unwrap();
        assert!(!orphan.exists(), "tmp orphan must be swept at open");
        assert_eq!(reopened.recover().unwrap().len(), 1);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mid_log_ckpt_record_supersedes_prefix() {
        // The reader must honor a checkpoint record wherever it appears
        // in the log (robustness for hand-repaired or future layouts),
        // even though today's writers only ever place it right after the
        // open record.
        let mut image = Vec::new();
        image.extend_from_slice(&frame(&open_record(&meta())));
        image.extend_from_slice(&frame(
            r#"{"type":"append","ys":[0,1]}"#,
        ));
        image.extend_from_slice(&frame(
            r#"{"type":"ckpt","snap":{"ys":[0,1,1]}}"#,
        ));
        image.extend_from_slice(&frame(
            r#"{"type":"append","ys":[1]}"#,
        ));
        let stored = fold_records(&parse_records(&image)).unwrap();
        assert_eq!(stored.meta, meta());
        assert_eq!(
            stored.snapshot.as_ref().map(|s| s.get("ys").as_arr().unwrap().len()),
            Some(3)
        );
        assert_eq!(stored.appends, vec![vec![1]]);
        assert_eq!(stored.len(), 4);
    }

    #[test]
    fn truncated_tail_keeps_fully_logged_appends() {
        // The satellite crash test: cut the log mid-record and verify
        // every fully-framed append survives.
        let dir = tempdir("disk-truncate");
        let store = DiskStore::open(&dir).unwrap();
        store.create(1, &meta()).unwrap();
        for k in 0..5u32 {
            store.log_append(1, &[k % 2, (k + 1) % 2, k % 2]).unwrap();
        }
        let path = store.path_for(1);
        let full = fs::read(&path).unwrap();

        // Truncate into the last record (simulated crash mid-write):
        // every cut here is shorter than one framed append record.
        for cut in [1usize, 10, 30] {
            fs::write(&path, &full[..full.len() - cut]).unwrap();
            let s = store.restore(1).unwrap();
            assert_eq!(s.appends.len(), 4, "cut={cut}");
            assert_eq!(s.len(), 12, "cut={cut}");
        }

        // Garbage appended after valid records is ignored the same way.
        let mut garbage = full.clone();
        garbage.extend_from_slice(b"0000000000000bad ");
        fs::write(&path, &garbage).unwrap();
        assert_eq!(store.restore(1).unwrap().appends.len(), 5);

        // A log truncated into its *open* record is unreadable — recover
        // skips it instead of failing the fleet.
        fs::write(&path, &full[..10]).unwrap();
        assert!(store.restore(1).is_err());
        assert!(store.recover().unwrap().is_empty());
        fs::remove_dir_all(&dir).ok();
    }
}

//! Deterministic replay: fold a timeline into the registry view it
//! describes.
//!
//! Replay is a pure left-fold over [`TimelineRecord`]s — no clocks, no
//! I/O — so the same log always reconstructs the same state, and
//! `--until SEQ` answers "what did the coordinator look like at
//! sequence N" exactly. The reconstructed view carries what a live
//! `Stat` reports (per-session model / length / residency, plus the
//! open- and resident-session counts) and the cluster router's
//! per-worker placement map; the end-to-end tests assert both against
//! the live services at the same sequence number.

use std::collections::{BTreeMap, BTreeSet};

use super::event::TimelineEvent;
use super::log::TimelineRecord;

/// Reconstructed per-session state (what a live `Stat` reports).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionView {
    /// Model registry key the session is bound to.
    pub model: String,
    /// Observations the session holds.
    pub len: usize,
    /// Whether the session is resident in RAM (vs evicted to the
    /// store).
    pub resident: bool,
}

/// The fold result: registry view plus connection/control counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReplayState {
    /// Open sessions by id.
    pub sessions: BTreeMap<u64, SessionView>,
    /// Cluster placements: session id → worker address (router
    /// timelines only).
    pub placements: BTreeMap<u64, String>,
    /// Connection ids currently open.
    pub open_conns: BTreeSet<u64>,
    /// Connections accepted so far.
    pub conns_opened: u64,
    /// Connections ended so far.
    pub conns_closed: u64,
    /// Connections refused at admission.
    pub conns_refused: u64,
    /// Requests shed with a typed reject frame.
    pub rejects: u64,
    /// Drains begun (server shutdowns + router worker drains).
    pub drains: u64,
    /// Completed migrations (cutovers).
    pub migrations: u64,
    /// Sessions re-registered by crash recovery.
    pub recovered: u64,
    /// Records folded in.
    pub events: u64,
    /// Sequence number of the last folded record (0 if none).
    pub last_seq: u64,
}

impl ReplayState {
    /// Sessions currently open.
    pub fn open_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Sessions currently resident in RAM.
    pub fn resident_sessions(&self) -> usize {
        self.sessions.values().filter(|s| s.resident).count()
    }

    fn session(&mut self, id: u64) -> &mut SessionView {
        self.sessions.entry(id).or_insert_with(|| SessionView {
            model: String::new(),
            len: 0,
            resident: false,
        })
    }

    fn apply(&mut self, event: &TimelineEvent) {
        match event {
            TimelineEvent::SessionOpen { session, model, len } => {
                self.sessions.insert(
                    *session,
                    SessionView {
                        model: model.clone(),
                        len: *len,
                        resident: true,
                    },
                );
            }
            TimelineEvent::Append { session, len, .. } => {
                let s = self.session(*session);
                s.len = *len;
                s.resident = true;
            }
            TimelineEvent::Spill { session, len } => {
                let s = self.session(*session);
                s.len = *len;
                s.resident = false;
            }
            TimelineEvent::Restore { session, len } => {
                let s = self.session(*session);
                s.len = *len;
                s.resident = true;
            }
            TimelineEvent::SessionClose { session }
            | TimelineEvent::Release { session } => {
                self.sessions.remove(session);
                self.placements.remove(session);
            }
            TimelineEvent::Recover { session, model, len } => {
                self.sessions.insert(
                    *session,
                    SessionView {
                        model: model.clone(),
                        len: *len,
                        resident: false,
                    },
                );
                self.recovered += 1;
            }
            TimelineEvent::ConnOpen { conn } => {
                self.open_conns.insert(*conn);
                self.conns_opened += 1;
            }
            TimelineEvent::ConnClose { conn } => {
                self.open_conns.remove(conn);
                self.conns_closed += 1;
            }
            TimelineEvent::ConnRefuse => self.conns_refused += 1,
            TimelineEvent::Reject { .. } => self.rejects += 1,
            TimelineEvent::Drain { .. } => self.drains += 1,
            TimelineEvent::Place { session, worker } => {
                self.placements.insert(*session, worker.clone());
            }
            TimelineEvent::MigrateBegin { .. }
            | TimelineEvent::MigrateVerify { .. } => {}
            TimelineEvent::MigrateCutover { session, to, .. } => {
                self.placements.insert(*session, to.clone());
                self.migrations += 1;
            }
        }
    }
}

/// Fold `records` (in order) into the registry view, stopping after the
/// record with sequence number `until` when given (`None` folds
/// everything).
pub fn replay(records: &[TimelineRecord], until: Option<u64>) -> ReplayState {
    let mut state = ReplayState::default();
    for record in records {
        if let Some(limit) = until {
            if record.seq > limit {
                break;
            }
        }
        state.apply(&record.event);
        state.events += 1;
        state.last_seq = record.seq;
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq: u64, event: TimelineEvent) -> TimelineRecord {
        TimelineRecord { seq, ts_ms: seq, event }
    }

    fn sample() -> Vec<TimelineRecord> {
        vec![
            rec(1, TimelineEvent::ConnOpen { conn: 1 }),
            rec(
                2,
                TimelineEvent::SessionOpen {
                    session: 10,
                    model: "ge".to_string(),
                    len: 0,
                },
            ),
            rec(3, TimelineEvent::Append { session: 10, appended: 8, len: 8 }),
            rec(4, TimelineEvent::Spill { session: 10, len: 8 }),
            rec(
                5,
                TimelineEvent::SessionOpen {
                    session: 11,
                    model: "cv".to_string(),
                    len: 0,
                },
            ),
            rec(6, TimelineEvent::Restore { session: 10, len: 8 }),
            rec(7, TimelineEvent::Append { session: 10, appended: 4, len: 12 }),
            rec(8, TimelineEvent::SessionClose { session: 11 }),
            rec(9, TimelineEvent::ConnClose { conn: 1 }),
        ]
    }

    #[test]
    fn fold_reconstructs_the_registry_view() {
        let state = replay(&sample(), None);
        assert_eq!(state.events, 9);
        assert_eq!(state.last_seq, 9);
        assert_eq!(state.open_sessions(), 1);
        assert_eq!(state.resident_sessions(), 1);
        let s = &state.sessions[&10];
        assert_eq!(s.model, "ge");
        assert_eq!(s.len, 12);
        assert!(s.resident);
        assert!(state.open_conns.is_empty());
        assert_eq!((state.conns_opened, state.conns_closed), (1, 1));
    }

    #[test]
    fn until_stops_at_the_requested_sequence() {
        // At seq 4 session 10 is spilled and session 11 not yet open.
        let state = replay(&sample(), Some(4));
        assert_eq!(state.last_seq, 4);
        assert_eq!(state.open_sessions(), 1);
        assert_eq!(state.resident_sessions(), 0);
        assert_eq!(state.sessions[&10].len, 8);
        assert_eq!(state.open_conns.len(), 1);
        // Until beyond the log folds everything.
        assert_eq!(replay(&sample(), Some(99)), replay(&sample(), None));
    }

    #[test]
    fn placements_follow_migration_cutover() {
        let records = vec![
            rec(
                1,
                TimelineEvent::Place {
                    session: 5,
                    worker: "a:1".to_string(),
                },
            ),
            rec(
                2,
                TimelineEvent::MigrateBegin {
                    session: 5,
                    from: "a:1".to_string(),
                    to: "b:2".to_string(),
                },
            ),
            rec(
                3,
                TimelineEvent::MigrateVerify {
                    session: 5,
                    to: "b:2".to_string(),
                },
            ),
            rec(
                4,
                TimelineEvent::MigrateCutover {
                    session: 5,
                    from: "a:1".to_string(),
                    to: "b:2".to_string(),
                },
            ),
        ];
        // Mid-migration the route still points at the source.
        let mid = replay(&records, Some(3));
        assert_eq!(mid.placements[&5], "a:1");
        assert_eq!(mid.migrations, 0);
        let done = replay(&records, None);
        assert_eq!(done.placements[&5], "b:2");
        assert_eq!(done.migrations, 1);
        // Close drops the placement.
        let mut all = records;
        all.push(rec(5, TimelineEvent::SessionClose { session: 5 }));
        assert!(replay(&all, None).placements.is_empty());
    }

    #[test]
    fn recover_registers_evicted_sessions() {
        let records = vec![
            rec(
                1,
                TimelineEvent::Recover {
                    session: 3,
                    model: "ge".to_string(),
                    len: 40,
                },
            ),
            rec(2, TimelineEvent::Restore { session: 3, len: 40 }),
        ];
        let state = replay(&records, Some(1));
        assert_eq!(state.recovered, 1);
        assert!(!state.sessions[&3].resident);
        let state = replay(&records, None);
        assert!(state.sessions[&3].resident);
    }
}

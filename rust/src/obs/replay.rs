//! Deterministic replay: fold a timeline into the registry view it
//! describes.
//!
//! Replay is a pure left-fold over [`TimelineRecord`]s — no clocks, no
//! I/O — so the same log always reconstructs the same state, and
//! `--until SEQ` answers "what did the coordinator look like at
//! sequence N" exactly. The reconstructed view carries what a live
//! `Stat` reports (per-session model / length / residency, plus the
//! open- and resident-session counts) and the cluster router's
//! per-worker placement map; the end-to-end tests assert both against
//! the live services at the same sequence number.

use std::collections::{BTreeMap, BTreeSet};

use super::event::TimelineEvent;
use super::log::TimelineRecord;

/// Reconstructed per-session state (what a live `Stat` reports).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionView {
    /// Model registry key the session is bound to.
    pub model: String,
    /// Observations the session holds.
    pub len: usize,
    /// Whether the session is resident in RAM (vs evicted to the
    /// store).
    pub resident: bool,
}

/// The fold result: registry view plus connection/control counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReplayState {
    /// Open sessions by id.
    pub sessions: BTreeMap<u64, SessionView>,
    /// Cluster placements: session id → worker address (router
    /// timelines only).
    pub placements: BTreeMap<u64, String>,
    /// Connection ids currently open.
    pub open_conns: BTreeSet<u64>,
    /// Connections accepted so far.
    pub conns_opened: u64,
    /// Connections ended so far.
    pub conns_closed: u64,
    /// Connections refused at admission.
    pub conns_refused: u64,
    /// Requests shed with a typed reject frame.
    pub rejects: u64,
    /// Drains begun (server shutdowns + router worker drains).
    pub drains: u64,
    /// Completed migrations (cutovers).
    pub migrations: u64,
    /// Sessions re-registered by crash recovery.
    pub recovered: u64,
    /// Trace spans begun but not yet ended, keyed `(trace, span)` →
    /// stage label. Non-empty at end of log means torn traces (crash,
    /// SIGKILL, or a dropped `span-end` record).
    pub open_spans: BTreeMap<(u64, u64), String>,
    /// `span-begin` records folded in.
    pub spans_begun: u64,
    /// `span-end` records folded in.
    pub spans_closed: u64,
    /// Records folded in.
    pub events: u64,
    /// Sequence number of the last folded record (0 if none).
    pub last_seq: u64,
}

impl ReplayState {
    /// Sessions currently open.
    pub fn open_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Sessions currently resident in RAM.
    pub fn resident_sessions(&self) -> usize {
        self.sessions.values().filter(|s| s.resident).count()
    }

    /// Trace ids with at least one span still open — the replay-level
    /// torn-trace invariant: every `span-begin` is eventually closed by
    /// a `span-end`, or the trace is flagged here.
    pub fn torn_traces(&self) -> BTreeSet<u64> {
        self.open_spans.keys().map(|(trace, _)| *trace).collect()
    }

    fn session(&mut self, id: u64) -> &mut SessionView {
        self.sessions.entry(id).or_insert_with(|| SessionView {
            model: String::new(),
            len: 0,
            resident: false,
        })
    }

    fn apply(&mut self, event: &TimelineEvent) {
        match event {
            TimelineEvent::SessionOpen { session, model, len } => {
                self.sessions.insert(
                    *session,
                    SessionView {
                        model: model.clone(),
                        len: *len,
                        resident: true,
                    },
                );
            }
            TimelineEvent::Append { session, len, .. } => {
                let s = self.session(*session);
                s.len = *len;
                s.resident = true;
            }
            TimelineEvent::Spill { session, len } => {
                let s = self.session(*session);
                s.len = *len;
                s.resident = false;
            }
            TimelineEvent::Restore { session, len } => {
                let s = self.session(*session);
                s.len = *len;
                s.resident = true;
            }
            TimelineEvent::SessionClose { session }
            | TimelineEvent::Release { session } => {
                self.sessions.remove(session);
                self.placements.remove(session);
            }
            TimelineEvent::Recover { session, model, len } => {
                self.sessions.insert(
                    *session,
                    SessionView {
                        model: model.clone(),
                        len: *len,
                        resident: false,
                    },
                );
                self.recovered += 1;
            }
            TimelineEvent::ConnOpen { conn } => {
                self.open_conns.insert(*conn);
                self.conns_opened += 1;
            }
            TimelineEvent::ConnClose { conn } => {
                self.open_conns.remove(conn);
                self.conns_closed += 1;
            }
            TimelineEvent::ConnRefuse => self.conns_refused += 1,
            TimelineEvent::Reject { .. } => self.rejects += 1,
            TimelineEvent::Drain { .. } => self.drains += 1,
            TimelineEvent::Place { session, worker } => {
                self.placements.insert(*session, worker.clone());
            }
            TimelineEvent::MigrateBegin { .. }
            | TimelineEvent::MigrateVerify { .. } => {}
            TimelineEvent::MigrateCutover { session, to, .. } => {
                self.placements.insert(*session, to.clone());
                self.migrations += 1;
            }
            TimelineEvent::SpanBegin { trace, span, stage, .. } => {
                self.open_spans.insert((*trace, *span), stage.clone());
                self.spans_begun += 1;
            }
            TimelineEvent::SpanEnd { trace, span, .. } => {
                self.open_spans.remove(&(*trace, *span));
                self.spans_closed += 1;
            }
        }
    }
}

/// Fold `records` (in order) into the registry view, stopping after the
/// record with sequence number `until` when given (`None` folds
/// everything).
pub fn replay(records: &[TimelineRecord], until: Option<u64>) -> ReplayState {
    let mut state = ReplayState::default();
    for record in records {
        if let Some(limit) = until {
            if record.seq > limit {
                break;
            }
        }
        state.apply(&record.event);
        state.events += 1;
        state.last_seq = record.seq;
    }
    state
}

/// One record of a merged cluster timeline, tagged with the name of the
/// timeline (process) it came from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MergedRecord {
    /// Source timeline name (the merge tool uses the directory path).
    pub source: String,
    /// The record itself (its `seq` is per-source, not global).
    pub record: TimelineRecord,
}

/// Fold N timelines' records into one causally-ordered view.
///
/// The order is a pure function of the record *multiset* — sorted by
/// `(ts_ms, source, seq)` — so any shuffling or partitioning of the
/// inputs (segments read in any grouping, sources listed in any order)
/// yields the identical merged sequence. Within one source the sort key
/// degenerates to `seq`, so per-process causal order is preserved
/// exactly; across sources the coarse wall clock is the best available
/// order (spans are additionally linked by ids, which do not depend on
/// the merge order at all). Duplicate records (the same `(source,
/// seq)` appearing in two input slices) collapse to one.
pub fn merge_records(sources: &[(String, Vec<TimelineRecord>)]) -> Vec<MergedRecord> {
    let mut out: Vec<MergedRecord> = Vec::new();
    for (source, records) in sources {
        out.extend(records.iter().map(|record| MergedRecord {
            source: source.clone(),
            record: record.clone(),
        }));
    }
    out.sort_by(|a, b| {
        (a.record.ts_ms, &a.source, a.record.seq).cmp(&(
            b.record.ts_ms,
            &b.source,
            b.record.seq,
        ))
    });
    out.dedup_by(|a, b| a.source == b.source && a.record.seq == b.record.seq);
    out
}

/// One stage span as seen by the merge tool.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanView {
    /// Which timeline (process) emitted the span.
    pub source: String,
    /// Span id.
    pub span: u64,
    /// Parent span id (0 = trace root; the parent may live in another
    /// process' timeline — that is the point).
    pub parent: u64,
    /// Stage label.
    pub stage: String,
    /// Stage latency in µs; `None` while unclosed (torn).
    pub us: Option<u64>,
    /// Slow-request flag from the `span-end` record.
    pub slow: bool,
    /// Stage annotation (e.g. kernel counter deltas).
    pub detail: String,
}

/// All spans of one trace across every merged timeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceView {
    /// Trace id.
    pub trace: u64,
    /// Spans in merged order (begin order).
    pub spans: Vec<SpanView>,
    /// True when any span never closed (crash / dropped record).
    pub torn: bool,
    /// True when any span carries the slow-request flag.
    pub slow: bool,
}

impl TraceView {
    /// Indices of `spans` whose parent is `parent` (0 for roots),
    /// preserving begin order — the tree-printer's child iterator.
    pub fn children_of(&self, parent: u64) -> Vec<usize> {
        self.spans
            .iter()
            .enumerate()
            .filter(|(_, s)| s.parent == parent && s.span != parent)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Group a merged timeline's span records into per-trace views, ordered
/// by each trace's first appearance. Deterministic for a deterministic
/// input order (use [`merge_records`]).
pub fn trace_views(merged: &[MergedRecord]) -> Vec<TraceView> {
    let mut out: Vec<TraceView> = Vec::new();
    let mut by_trace: BTreeMap<u64, usize> = BTreeMap::new();
    for mr in merged {
        match &mr.record.event {
            TimelineEvent::SpanBegin { trace, span, parent, stage } => {
                let idx = *by_trace.entry(*trace).or_insert_with(|| {
                    out.push(TraceView {
                        trace: *trace,
                        spans: Vec::new(),
                        torn: false,
                        slow: false,
                    });
                    out.len() - 1
                });
                out[idx].spans.push(SpanView {
                    source: mr.source.clone(),
                    span: *span,
                    parent: *parent,
                    stage: stage.clone(),
                    us: None,
                    slow: false,
                    detail: String::new(),
                });
            }
            TimelineEvent::SpanEnd { trace, span, stage, us, slow, detail } => {
                let idx = *by_trace.entry(*trace).or_insert_with(|| {
                    out.push(TraceView {
                        trace: *trace,
                        spans: Vec::new(),
                        torn: false,
                        slow: false,
                    });
                    out.len() - 1
                });
                let view = &mut out[idx];
                match view
                    .spans
                    .iter_mut()
                    .find(|s| s.span == *span && s.us.is_none())
                {
                    Some(s) => {
                        s.us = Some(*us);
                        s.slow = *slow;
                        s.detail = detail.clone();
                    }
                    None => {
                        // End without a begin: the begin record was
                        // dropped or its segment lost — keep the
                        // latency but flag the trace torn.
                        view.torn = true;
                        view.spans.push(SpanView {
                            source: mr.source.clone(),
                            span: *span,
                            parent: 0,
                            stage: stage.clone(),
                            us: Some(*us),
                            slow: *slow,
                            detail: detail.clone(),
                        });
                    }
                }
                if *slow {
                    view.slow = true;
                }
            }
            _ => {}
        }
    }
    for view in &mut out {
        if view.spans.iter().any(|s| s.us.is_none()) {
            view.torn = true;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq: u64, event: TimelineEvent) -> TimelineRecord {
        TimelineRecord { seq, ts_ms: seq, event }
    }

    fn sample() -> Vec<TimelineRecord> {
        vec![
            rec(1, TimelineEvent::ConnOpen { conn: 1 }),
            rec(
                2,
                TimelineEvent::SessionOpen {
                    session: 10,
                    model: "ge".to_string(),
                    len: 0,
                },
            ),
            rec(3, TimelineEvent::Append { session: 10, appended: 8, len: 8 }),
            rec(4, TimelineEvent::Spill { session: 10, len: 8 }),
            rec(
                5,
                TimelineEvent::SessionOpen {
                    session: 11,
                    model: "cv".to_string(),
                    len: 0,
                },
            ),
            rec(6, TimelineEvent::Restore { session: 10, len: 8 }),
            rec(7, TimelineEvent::Append { session: 10, appended: 4, len: 12 }),
            rec(8, TimelineEvent::SessionClose { session: 11 }),
            rec(9, TimelineEvent::ConnClose { conn: 1 }),
        ]
    }

    #[test]
    fn fold_reconstructs_the_registry_view() {
        let state = replay(&sample(), None);
        assert_eq!(state.events, 9);
        assert_eq!(state.last_seq, 9);
        assert_eq!(state.open_sessions(), 1);
        assert_eq!(state.resident_sessions(), 1);
        let s = &state.sessions[&10];
        assert_eq!(s.model, "ge");
        assert_eq!(s.len, 12);
        assert!(s.resident);
        assert!(state.open_conns.is_empty());
        assert_eq!((state.conns_opened, state.conns_closed), (1, 1));
    }

    #[test]
    fn until_stops_at_the_requested_sequence() {
        // At seq 4 session 10 is spilled and session 11 not yet open.
        let state = replay(&sample(), Some(4));
        assert_eq!(state.last_seq, 4);
        assert_eq!(state.open_sessions(), 1);
        assert_eq!(state.resident_sessions(), 0);
        assert_eq!(state.sessions[&10].len, 8);
        assert_eq!(state.open_conns.len(), 1);
        // Until beyond the log folds everything.
        assert_eq!(replay(&sample(), Some(99)), replay(&sample(), None));
    }

    #[test]
    fn placements_follow_migration_cutover() {
        let records = vec![
            rec(
                1,
                TimelineEvent::Place {
                    session: 5,
                    worker: "a:1".to_string(),
                },
            ),
            rec(
                2,
                TimelineEvent::MigrateBegin {
                    session: 5,
                    from: "a:1".to_string(),
                    to: "b:2".to_string(),
                },
            ),
            rec(
                3,
                TimelineEvent::MigrateVerify {
                    session: 5,
                    to: "b:2".to_string(),
                },
            ),
            rec(
                4,
                TimelineEvent::MigrateCutover {
                    session: 5,
                    from: "a:1".to_string(),
                    to: "b:2".to_string(),
                },
            ),
        ];
        // Mid-migration the route still points at the source.
        let mid = replay(&records, Some(3));
        assert_eq!(mid.placements[&5], "a:1");
        assert_eq!(mid.migrations, 0);
        let done = replay(&records, None);
        assert_eq!(done.placements[&5], "b:2");
        assert_eq!(done.migrations, 1);
        // Close drops the placement.
        let mut all = records;
        all.push(rec(5, TimelineEvent::SessionClose { session: 5 }));
        assert!(replay(&all, None).placements.is_empty());
    }

    fn span_begin(trace: u64, span: u64, parent: u64, stage: &str) -> TimelineEvent {
        TimelineEvent::SpanBegin {
            trace,
            span,
            parent,
            stage: stage.to_string(),
        }
    }

    fn span_end(trace: u64, span: u64, stage: &str, us: u64) -> TimelineEvent {
        TimelineEvent::SpanEnd {
            trace,
            span,
            stage: stage.to_string(),
            us,
            slow: false,
            detail: String::new(),
        }
    }

    #[test]
    fn spans_fold_and_torn_traces_surface() {
        let records = vec![
            rec(1, span_begin(7, 1, 0, "execute")),
            rec(2, span_begin(7, 2, 1, "checkout")),
            rec(3, span_end(7, 2, "checkout", 40)),
            rec(4, span_begin(9, 5, 0, "execute")),
            rec(5, span_end(7, 1, "execute", 90)),
        ];
        // Mid-log: both roots open.
        let mid = replay(&records, Some(2));
        assert_eq!(mid.spans_begun, 2);
        assert_eq!(mid.spans_closed, 0);
        assert_eq!(mid.open_spans[&(7, 1)], "execute");
        assert_eq!(mid.torn_traces().into_iter().collect::<Vec<_>>(), vec![7]);
        // Full log: trace 7 closed cleanly, trace 9 is torn.
        let done = replay(&records, None);
        assert_eq!((done.spans_begun, done.spans_closed), (4, 2));
        assert_eq!(done.torn_traces().into_iter().collect::<Vec<_>>(), vec![9]);
    }

    #[test]
    fn until_folds_correctly_across_a_migration_span() {
        // A migration wrapped in its router-originated `migrate` span:
        // time travel into the middle sees the span open and the route
        // still on the source; past the end, everything is closed over.
        let records = vec![
            rec(1, TimelineEvent::Place { session: 5, worker: "a:1".into() }),
            rec(2, span_begin(0xabc, 3, 0, "migrate")),
            rec(
                3,
                TimelineEvent::MigrateBegin {
                    session: 5,
                    from: "a:1".into(),
                    to: "b:2".into(),
                },
            ),
            rec(
                4,
                TimelineEvent::MigrateVerify { session: 5, to: "b:2".into() },
            ),
            rec(
                5,
                TimelineEvent::MigrateCutover {
                    session: 5,
                    from: "a:1".into(),
                    to: "b:2".into(),
                },
            ),
            rec(6, span_end(0xabc, 3, "migrate", 1500)),
        ];
        for until in 2..=4 {
            let mid = replay(&records, Some(until));
            assert_eq!(mid.placements[&5], "a:1", "until {until}");
            assert_eq!(mid.migrations, 0);
            assert!(mid.torn_traces().contains(&0xabc));
        }
        let cutover = replay(&records, Some(5));
        assert_eq!(cutover.placements[&5], "b:2");
        assert_eq!(cutover.migrations, 1);
        assert!(cutover.torn_traces().contains(&0xabc), "span still open");
        let done = replay(&records, None);
        assert_eq!(done.placements[&5], "b:2");
        assert!(done.torn_traces().is_empty());
        assert_eq!((done.spans_begun, done.spans_closed), (1, 1));
    }

    #[test]
    fn recover_registers_evicted_sessions() {
        let records = vec![
            rec(
                1,
                TimelineEvent::Recover {
                    session: 3,
                    model: "ge".to_string(),
                    len: 40,
                },
            ),
            rec(2, TimelineEvent::Restore { session: 3, len: 40 }),
        ];
        let state = replay(&records, Some(1));
        assert_eq!(state.recovered, 1);
        assert!(!state.sessions[&3].resident);
        let state = replay(&records, None);
        assert!(state.sessions[&3].resident);
    }

    fn trec(seq: u64, ts_ms: u64, event: TimelineEvent) -> TimelineRecord {
        TimelineRecord { seq, ts_ms, event }
    }

    /// Three small process timelines with overlapping timestamps and a
    /// cross-process trace (router span parents worker spans).
    fn cluster_sources() -> Vec<(String, Vec<TimelineRecord>)> {
        let router = vec![
            trec(1, 100, TimelineEvent::ConnOpen { conn: 1 }),
            trec(2, 100, span_begin(0x77, 0x10, 0, "execute")),
            trec(3, 105, span_begin(0x77, 0x11, 0x10, "checkout")),
            trec(4, 106, span_end(0x77, 0x11, "checkout", 900)),
            trec(5, 140, span_end(0x77, 0x10, "execute", 40_000)),
        ];
        let worker_a = vec![
            trec(1, 107, span_begin(0x77, 0x20, 0x10, "admission")),
            trec(2, 107, span_end(0x77, 0x20, "admission", 30)),
            trec(3, 108, span_begin(0x77, 0x21, 0x10, "execute")),
            trec(4, 130, span_end(0x77, 0x21, "execute", 22_000)),
        ];
        let worker_b = vec![
            trec(1, 100, TimelineEvent::SessionOpen {
                session: 4,
                model: "ge".into(),
                len: 0,
            }),
            trec(2, 120, span_begin(0x99, 0x30, 0, "execute")),
        ];
        vec![
            ("router".to_string(), router),
            ("worker_a".to_string(), worker_a),
            ("worker_b".to_string(), worker_b),
        ]
    }

    #[test]
    fn merge_is_deterministic_under_shuffling_and_partitioning() {
        let sources = cluster_sources();
        let canonical = merge_records(&sources);
        // Sanity: per-source order is preserved in the merge.
        for (name, records) in &sources {
            let seqs: Vec<u64> = canonical
                .iter()
                .filter(|m| &m.source == name)
                .map(|m| m.record.seq)
                .collect();
            assert_eq!(
                seqs,
                records.iter().map(|r| r.seq).collect::<Vec<_>>()
            );
        }
        let mut runner = crate::proptestx::Runner::new("obs-merge-determinism");
        runner.run(64, |rng| {
            // Split every source into random contiguous partitions,
            // then shuffle the full partition list — simulating
            // segments read in arbitrary groupings and orders.
            let mut parts: Vec<(String, Vec<TimelineRecord>)> = Vec::new();
            for (name, records) in &sources {
                let mut rest = records.clone();
                while !rest.is_empty() {
                    let take =
                        (rng.next_u64() as usize % rest.len()) + 1;
                    let tail = rest.split_off(take.min(rest.len()));
                    parts.push((name.clone(), rest));
                    rest = tail;
                }
            }
            for i in (1..parts.len()).rev() {
                let j = rng.next_u64() as usize % (i + 1);
                parts.swap(i, j);
            }
            assert_eq!(merge_records(&parts), canonical);
        });
    }

    #[test]
    fn trace_views_link_spans_across_processes() {
        let merged = merge_records(&cluster_sources());
        let views = trace_views(&merged);
        assert_eq!(views.len(), 2);

        let t77 = &views[0];
        assert_eq!(t77.trace, 0x77);
        assert!(!t77.torn);
        assert!(!t77.slow);
        assert_eq!(t77.spans.len(), 4);
        // The router's execute span is the root; its children include
        // the checkout span (same process) and both worker spans
        // (cross-process parent links).
        let roots = t77.children_of(0);
        assert_eq!(roots.len(), 1);
        let root = &t77.spans[roots[0]];
        assert_eq!((root.span, root.stage.as_str()), (0x10, "execute"));
        assert_eq!(root.source, "router");
        assert_eq!(root.us, Some(40_000));
        let kids = t77.children_of(0x10);
        let kid_sources: Vec<&str> =
            kids.iter().map(|&i| t77.spans[i].source.as_str()).collect();
        assert_eq!(kid_sources, vec!["router", "worker_a", "worker_a"]);

        // Trace 0x99 never closed (worker_b was killed): torn.
        let t99 = &views[1];
        assert_eq!(t99.trace, 0x99);
        assert!(t99.torn);
        assert_eq!(t99.spans[0].us, None);
    }

    #[test]
    fn trace_views_flag_slow_and_orphan_ends() {
        let merged = vec![
            MergedRecord {
                source: "w".into(),
                record: trec(
                    1,
                    10,
                    TimelineEvent::SpanEnd {
                        trace: 5,
                        span: 9,
                        stage: "execute".into(),
                        us: 70,
                        slow: true,
                        detail: "spec_d4=2".into(),
                    },
                ),
            },
        ];
        let views = trace_views(&merged);
        assert_eq!(views.len(), 1);
        assert!(views[0].torn, "end without begin is torn");
        assert!(views[0].slow);
        assert_eq!(views[0].spans[0].us, Some(70));
        assert_eq!(views[0].spans[0].detail, "spec_d4=2");
    }
}

//! Request-scoped tracing spans: ambient trace context plus the
//! [`StageSpan`] guard that times one stage and emits the
//! `span-begin` / `span-end` [`TimelineEvent`] pair.
//!
//! ## Design
//!
//! Trace context travels two ways:
//!
//! * **Across processes** it rides the wire — `net::wire` carries an
//!   additive `trace` field (`{trace_id, parent_span}`, protocol v4)
//!   that `NetClient` stamps and `NetServer` reads.
//! * **Within a process** it is *ambient*: a thread-local
//!   `(trace_id, span_id)` pair set by [`with_span`] around the
//!   request's execute path. Downstream layers (cluster router pool
//!   checkout, store append, group-commit sync wait) read the ambient
//!   context with [`current`] instead of threading ids through every
//!   call signature — the `WireService` trait stays untouched.
//!
//! Ids are fnv64 values derived from a per-process seed plus a
//! process-wide counter; id `0` is reserved as "no trace" / "no
//! parent", so an untraced call path emits nothing. Emission goes
//! through the same bounded non-blocking [`Timeline`] channel as every
//! other event — a span can be dropped under load but can never block
//! the hot path.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{Timeline, TimelineEvent};
use crate::rng::{fnv1a_64, FNV1A_OFFSET};

thread_local! {
    /// Ambient `(trace_id, span_id)` for the request this thread is
    /// currently executing; `(0, 0)` when untraced.
    static CURRENT: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
}

/// Process-wide counter mixed into every generated id.
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Lazily-derived per-process seed (pid + boot time) so two processes
/// started in the same nanosecond still draw disjoint id streams.
static PROCESS_SEED: AtomicU64 = AtomicU64::new(0);

fn process_seed() -> u64 {
    let seed = PROCESS_SEED.load(Ordering::Relaxed);
    if seed != 0 {
        return seed;
    }
    let pid = std::process::id() as u64;
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0x9e37_79b9_7f4a_7c15);
    let mixed = fnv1a_64(FNV1A_OFFSET, &pid.to_le_bytes());
    let seed = fnv1a_64(mixed, &nanos.to_le_bytes()).max(1);
    // First writer wins; losers re-read so every thread agrees.
    let _ = PROCESS_SEED.compare_exchange(
        0,
        seed,
        Ordering::Relaxed,
        Ordering::Relaxed,
    );
    PROCESS_SEED.load(Ordering::Relaxed)
}

/// A fresh non-zero trace/span id: fnv64 over (process seed, counter).
pub fn fresh_id() -> u64 {
    let n = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    fnv1a_64(process_seed(), &n.to_le_bytes()).max(1)
}

/// The ambient `(trace_id, span_id)`; `(0, 0)` when untraced.
pub fn current() -> (u64, u64) {
    CURRENT.with(|c| c.get())
}

/// Run `f` with `(trace, span)` as the ambient context, restoring the
/// previous context afterwards (panic-safe via a drop guard).
pub fn with_span<T>(trace: u64, span: u64, f: impl FnOnce() -> T) -> T {
    struct Restore((u64, u64));
    impl Drop for Restore {
        fn drop(&mut self) {
            CURRENT.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(CURRENT.with(|c| c.replace((trace, span))));
    f()
}

/// An open stage span: created by one of the `begin*` constructors,
/// closed by [`finish`](StageSpan::finish) (which emits the `span-end`
/// record and returns the elapsed microseconds).
///
/// Every constructor tolerates a missing timeline or an untraced
/// context by producing an inert span — callers never branch.
#[derive(Debug)]
pub struct StageSpan {
    timeline: Option<Arc<Timeline>>,
    trace: u64,
    id: u64,
    stage: &'static str,
    t0: Instant,
}

impl StageSpan {
    /// Open a span under the ambient context (parent = ambient span).
    /// Inert when there is no ambient trace or no timeline.
    pub fn begin(timeline: Option<&Arc<Timeline>>, stage: &'static str) -> StageSpan {
        let (trace, parent) = current();
        StageSpan::begin_under(timeline, trace, parent, stage)
    }

    /// Open a span under an explicit `(trace, parent)` — the network
    /// server uses this with the wire-propagated context before any
    /// ambient context exists on the handler thread.
    pub fn begin_under(
        timeline: Option<&Arc<Timeline>>,
        trace: u64,
        parent: u64,
        stage: &'static str,
    ) -> StageSpan {
        let timeline = match timeline {
            Some(tl) if trace != 0 => Some(Arc::clone(tl)),
            _ => None,
        };
        let id = if timeline.is_some() { fresh_id() } else { 0 };
        if let Some(tl) = &timeline {
            tl.record(TimelineEvent::SpanBegin {
                trace,
                span: id,
                parent,
                stage: stage.to_string(),
            });
        }
        StageSpan { timeline, trace, id, stage, t0: Instant::now() }
    }

    /// Open a span under the ambient context if one exists, otherwise
    /// originate a fresh trace rooted at this span — used by flows the
    /// router starts itself (administrative drains, live migration).
    pub fn begin_root(
        timeline: Option<&Arc<Timeline>>,
        stage: &'static str,
    ) -> StageSpan {
        let (trace, parent) = current();
        let trace = if trace != 0 { trace } else { fresh_id() };
        StageSpan::begin_under(timeline, trace, parent, stage)
    }

    /// This span's id (0 when inert).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The trace id this span belongs to (0 when inert).
    pub fn trace(&self) -> u64 {
        self.trace
    }

    /// Run `f` with this span as the ambient context, so nested
    /// [`begin`](StageSpan::begin) calls become its children.
    pub fn enter<T>(&self, f: impl FnOnce() -> T) -> T {
        with_span(self.trace, self.id, f)
    }

    /// Close the span: emit `span-end` and return the elapsed µs.
    pub fn finish(self) -> u64 {
        self.finish_with(false, String::new())
    }

    /// Close the span with a slow-request flag and a detail annotation
    /// (e.g. kernel counter deltas on an `execute` span).
    pub fn finish_with(self, slow: bool, detail: String) -> u64 {
        let us = self.t0.elapsed().as_micros() as u64;
        if let Some(tl) = &self.timeline {
            tl.record(TimelineEvent::SpanEnd {
                trace: self.trace,
                span: self.id,
                stage: self.stage.to_string(),
                us,
                slow,
                detail,
            });
        }
        us
    }
}

/// Emit a closed `span-begin`/`span-end` pair for a stage measured
/// out-of-band (the group-commit sync wait is timed inside the store,
/// which has no span to hold open). Parent is the ambient span; inert
/// when untraced.
pub fn annotate(
    timeline: Option<&Arc<Timeline>>,
    stage: &'static str,
    elapsed: Duration,
) {
    let (trace, parent) = current();
    let (Some(tl), true) = (timeline, trace != 0) else {
        return;
    };
    let id = fresh_id();
    tl.record(TimelineEvent::SpanBegin {
        trace,
        span: id,
        parent,
        stage: stage.to_string(),
    });
    tl.record(TimelineEvent::SpanEnd {
        trace,
        span: id,
        stage: stage.to_string(),
        us: elapsed.as_micros() as u64,
        slow: false,
        detail: String::new(),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::read_events;
    use crate::store::testutil::tempdir;

    #[test]
    fn ids_are_unique_and_nonzero() {
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..10_000 {
            let id = fresh_id();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "duplicate id {id:#x}");
        }
    }

    #[test]
    fn ambient_context_nests_and_restores() {
        assert_eq!(current(), (0, 0));
        with_span(7, 1, || {
            assert_eq!(current(), (7, 1));
            with_span(7, 2, || assert_eq!(current(), (7, 2)));
            assert_eq!(current(), (7, 1));
        });
        assert_eq!(current(), (0, 0));
    }

    #[test]
    fn ambient_context_restores_across_panics() {
        let caught = std::panic::catch_unwind(|| {
            with_span(9, 3, || panic!("boom"));
        });
        assert!(caught.is_err());
        assert_eq!(current(), (0, 0));
    }

    #[test]
    fn spans_emit_paired_records_with_parent_links() {
        let dir = tempdir("span_pairs");
        let tl = Arc::new(Timeline::open(&dir).unwrap());
        let root = StageSpan::begin_under(Some(&tl), 42, 0, "execute");
        let (child_id, root_id) = root.enter(|| {
            let child = StageSpan::begin(Some(&tl), "checkout");
            let id = child.id();
            child.finish();
            (id, current().1)
        });
        assert_eq!(root_id, root.id());
        let root_id = root.id();
        root.finish_with(true, "spec_d4=3".into());
        tl.flush();

        let records = read_events(&dir).unwrap();
        let events: Vec<_> = records.into_iter().map(|r| r.event).collect();
        assert_eq!(events.len(), 4);
        match &events[0] {
            TimelineEvent::SpanBegin { trace, span, parent, stage } => {
                assert_eq!((*trace, *span, *parent), (42, root_id, 0));
                assert_eq!(stage, "execute");
            }
            other => panic!("expected root span-begin, got {other:?}"),
        }
        match &events[1] {
            TimelineEvent::SpanBegin { trace, span, parent, stage } => {
                assert_eq!((*trace, *span, *parent), (42, child_id, root_id));
                assert_eq!(stage, "checkout");
            }
            other => panic!("expected child span-begin, got {other:?}"),
        }
        match &events[3] {
            TimelineEvent::SpanEnd { span, slow, detail, .. } => {
                assert_eq!(*span, root_id);
                assert!(*slow);
                assert_eq!(detail, "spec_d4=3");
            }
            other => panic!("expected root span-end, got {other:?}"),
        }
    }

    #[test]
    fn untraced_and_timeline_less_spans_are_inert() {
        let dir = tempdir("span_inert");
        let tl = Arc::new(Timeline::open(&dir).unwrap());
        // No ambient trace: nothing recorded even with a timeline.
        assert_eq!(current(), (0, 0));
        let s = StageSpan::begin(Some(&tl), "queue");
        assert_eq!(s.id(), 0);
        s.finish();
        annotate(Some(&tl), "sync-wait", Duration::from_micros(5));
        // Traced but no timeline: still inert, still safe.
        with_span(5, 1, || {
            let s = StageSpan::begin(None, "queue");
            assert_eq!(s.id(), 0);
            s.finish();
        });
        tl.flush();
        assert_eq!(read_events(&dir).unwrap().len(), 0);
        assert_eq!(tl.last_seq(), 0);
    }

    #[test]
    fn annotate_emits_a_closed_pair_under_the_ambient_span() {
        let dir = tempdir("span_annotate");
        let tl = Arc::new(Timeline::open(&dir).unwrap());
        with_span(11, 99, || {
            annotate(Some(&tl), "sync-wait", Duration::from_micros(250));
        });
        tl.flush();
        let events: Vec<_> = read_events(&dir)
            .unwrap()
            .into_iter()
            .map(|r| r.event)
            .collect();
        assert_eq!(events.len(), 2);
        let TimelineEvent::SpanBegin { trace, span, parent, stage } = &events[0]
        else {
            panic!("expected span-begin, got {:?}", events[0]);
        };
        assert_eq!((*trace, *parent), (11, 99));
        assert_eq!(stage, "sync-wait");
        let TimelineEvent::SpanEnd { span: end_span, us, .. } = &events[1]
        else {
            panic!("expected span-end, got {:?}", events[1]);
        };
        assert_eq!(end_span, span);
        assert_eq!(*us, 250);
    }
}

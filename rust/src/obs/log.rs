//! Segmented, crash-safe timeline log: the durable side of the
//! observability tier.
//!
//! ## On-disk format
//!
//! A timeline directory holds numbered segments
//! `tl_<segment:08x>.log`, each a sequence of framed records using the
//! session store's framing idiom (`docs/STORE_FORMAT.md`): a fixed
//! 53-byte header — 16 hex chars payload length, space, 16 hex chars
//! FNV-1a 64 checksum, space, one kind char (`e` for event), space,
//! 16 hex chars sequence number, newline — followed by the compact-JSON
//! payload and a terminating newline. The payload is the flat
//! [`TimelineEvent`] object plus two writer-stamped fields: `"seq"`
//! (monotonic across segments, starts at 1) and `"ts"` (coarse
//! wall-clock milliseconds since the unix epoch). Carrying the sequence
//! number in the header too means a scan can walk a timeline with
//! `seek` alone, exactly like the store's metadata-only recovery.
//!
//! ## Crash safety
//!
//! Readers are prefix-valid: [`read_events`] stops at the first framing
//! violation (truncated header, short payload, checksum mismatch,
//! unparsable JSON, non-monotonic sequence) and returns every record
//! before it — a crash mid-append costs at most the half-written tail
//! record. [`Timeline::open`] repairs a torn tail by truncating the
//! last segment back to its valid prefix before resuming, and resumes
//! the sequence counter from the last durable record.
//!
//! ## The serve path never stalls
//!
//! [`Timeline::record`] is a bounded `try_send` onto a channel drained
//! by a dedicated writer thread — it never blocks and never touches the
//! filesystem. When the channel is full the event is *dropped* and
//! counted ([`Timeline::dropped`]); replay then reflects the recorded
//! prefix, which is the honest trade for never adding fsync latency to
//! an append. The writer thread batches every queued event it can drain
//! into one `write_all` + `sync_all` per wakeup — the same group-commit
//! amortization the session store applies to appends.

use std::fmt;
use std::fs::{self, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::error::{Error, Result};
use crate::jsonx::Json;

use super::event::TimelineEvent;

/// Header layout (mirrors the session store): 16 hex chars payload
/// length, space, 16 hex chars fnv64 checksum, space, 1 kind char,
/// space, 16 hex chars sequence number, newline.
const HEADER_LEN: usize = 53;

/// The single record kind a timeline segment holds.
const EVENT_KIND: u8 = b'e';

/// Rotate to a fresh segment once the current one crosses this size.
const SEGMENT_BYTES: u64 = 4 << 20;

/// Bounded depth of the emit channel; events beyond it are dropped
/// (counted) rather than ever blocking the serve path.
const CHANNEL_DEPTH: usize = 1024;

/// The framing checksum: fresh-start FNV-1a 64 (`rng::fnv1a_64`).
fn fnv64(bytes: &[u8]) -> u64 {
    crate::rng::fnv1a_64(crate::rng::FNV1A_OFFSET, bytes)
}

fn frame(payload: &str, seq: u64) -> Vec<u8> {
    let bytes = payload.as_bytes();
    let mut out = format!(
        "{:016x} {:016x} {} {:016x}\n",
        bytes.len(),
        fnv64(bytes),
        EVENT_KIND as char,
        seq
    )
    .into_bytes();
    debug_assert_eq!(out.len(), HEADER_LEN);
    out.extend_from_slice(bytes);
    out.push(b'\n');
    out
}

fn parse_hex(bytes: &[u8]) -> Option<u64> {
    let s = std::str::from_utf8(bytes).ok()?;
    u64::from_str_radix(s, 16).ok()
}

/// One parsed frame header (the fixed 53-byte prefix of every record).
#[derive(Debug, Clone, Copy)]
struct FrameHeader {
    /// Payload byte length.
    len: usize,
    /// FNV-1a 64 checksum of the payload.
    sum: u64,
    /// Sequence number (also stamped inside the payload).
    seq: u64,
}

/// Parse one frame header; `None` on any structural violation (the
/// prefix-valid readers treat that as the crash tail).
fn parse_header(h: &[u8]) -> Option<FrameHeader> {
    if h.len() < HEADER_LEN {
        return None;
    }
    if h[16] != b' ' || h[33] != b' ' || h[35] != b' ' || h[52] != b'\n' {
        return None;
    }
    if h[34] != EVENT_KIND {
        return None;
    }
    let len = usize::try_from(parse_hex(&h[0..16])?).ok()?;
    let sum = parse_hex(&h[17..33])?;
    let seq = parse_hex(&h[36..52])?;
    Some(FrameHeader { len, sum, seq })
}

/// One timeline record: the writer-stamped ordering fields plus the
/// decoded event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimelineRecord {
    /// Monotonic sequence number (1-based, contiguous across segments).
    pub seq: u64,
    /// Coarse wall-clock milliseconds since the unix epoch at emit.
    pub ts_ms: u64,
    /// The recorded state transition.
    pub event: TimelineEvent,
}

/// Parse the valid record prefix of one segment image; everything after
/// the first framing violation (the crash tail) is ignored. Returns the
/// records plus the byte length of the valid prefix (what a torn-tail
/// repair truncates back to).
fn parse_segment_prefix(data: &[u8]) -> (Vec<TimelineRecord>, usize) {
    let mut out: Vec<TimelineRecord> = Vec::new();
    let mut pos = 0usize;
    while pos + HEADER_LEN <= data.len() {
        let Some(h) = parse_header(&data[pos..pos + HEADER_LEN]) else {
            break;
        };
        let start = pos + HEADER_LEN;
        let Some(end) = start.checked_add(h.len) else { break };
        if end >= data.len() || data[end] != b'\n' {
            break; // truncated payload / missing terminator
        }
        let payload = &data[start..end];
        if fnv64(payload) != h.sum {
            break; // torn write
        }
        let Ok(text) = std::str::from_utf8(payload) else { break };
        let Ok(json) = Json::parse(text) else { break };
        let Some(record) = record_from_json(&json) else { break };
        if record.seq != h.seq {
            break; // header/payload disagree — treat as tail
        }
        if let Some(prev) = out.last() {
            if record.seq <= prev.seq {
                break; // sequence must be strictly monotonic
            }
        }
        out.push(record);
        pos = end + 1;
    }
    (out, pos)
}

fn record_from_json(json: &Json) -> Option<TimelineRecord> {
    let seq = json.get("seq").as_usize()? as u64;
    let ts_ms = json.get("ts").as_usize()? as u64;
    let event = TimelineEvent::from_json(json).ok()?;
    Some(TimelineRecord { seq, ts_ms, event })
}

/// Segment file name for index `n` (`tl_<n:08x>.log`).
fn segment_name(n: u64) -> String {
    format!("tl_{n:08x}.log")
}

/// Parse a segment file name back to its index.
fn segment_index(name: &str) -> Option<u64> {
    let hex = name.strip_prefix("tl_")?.strip_suffix(".log")?;
    if hex.len() != 8 {
        return None;
    }
    u64::from_str_radix(hex, 16).ok()
}

/// Sorted indices of the segments present in a timeline directory.
fn list_segments(dir: &Path) -> Result<Vec<u64>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        if let Some(idx) =
            entry.file_name().to_str().and_then(segment_index)
        {
            out.push(idx);
        }
    }
    out.sort_unstable();
    Ok(out)
}

/// Read every decodable record in a timeline directory, in sequence
/// order: segments ascending, each prefix-valid. A framing violation
/// ends the stream — segments after a torn one are unreachable history
/// and are not read (only the live tail segment can legitimately be
/// torn, so in practice this is "everything up to the crash point").
pub fn read_events(dir: impl AsRef<Path>) -> Result<Vec<TimelineRecord>> {
    let dir = dir.as_ref();
    if !dir.is_dir() {
        return Err(Error::invalid_request(format!(
            "timeline directory not found: {}",
            dir.display()
        )));
    }
    let mut out = Vec::new();
    for idx in list_segments(dir)? {
        let data = fs::read(dir.join(segment_name(idx)))?;
        let (mut records, valid) = parse_segment_prefix(&data);
        // Cross-segment monotonicity: a segment that restarts the
        // sequence is not a continuation of this timeline.
        if let (Some(prev), Some(first)) = (
            out.last().map(|r: &TimelineRecord| r.seq),
            records.first().map(|r| r.seq),
        ) {
            if first <= prev {
                break;
            }
        }
        let torn = valid < data.len();
        out.append(&mut records);
        if torn {
            break;
        }
    }
    Ok(out)
}

/// What the writer thread receives: an event stamped with its emit-time
/// coarse timestamp, or a flush barrier. Tests can additionally park
/// the writer (`Stall`) to force the bounded channel to fill.
enum TlMsg {
    Event(TimelineEvent, u64),
    Flush(mpsc::Sender<()>),
    #[cfg(test)]
    Stall(mpsc::Receiver<()>),
}

/// Handle to a live timeline: cheap, non-blocking [`record`] from any
/// thread; one background writer owns the segment files. Share it as
/// `Arc<Timeline>` between the coordinator, the network server, and the
/// cluster router — their events interleave under one monotonic
/// sequence.
///
/// [`record`]: Timeline::record
pub struct Timeline {
    tx: Option<mpsc::SyncSender<TlMsg>>,
    dropped: AtomicU64,
    last_seq: Arc<AtomicU64>,
    dir: PathBuf,
    join: Option<thread::JoinHandle<()>>,
}

impl fmt::Debug for Timeline {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Timeline")
            .field("dir", &self.dir)
            .field("last_seq", &self.last_seq.load(Ordering::Relaxed))
            .field("dropped", &self.dropped.load(Ordering::Relaxed))
            .finish()
    }
}

/// The writer thread's file state: current segment handle plus its
/// byte length (for rotation).
struct SegmentWriter {
    dir: PathBuf,
    segment: u64,
    file: Option<fs::File>,
    written: u64,
}

impl SegmentWriter {
    /// Append one framed record, rotating first if the current segment
    /// is full. Returns whether the (best-effort) write succeeded.
    fn append(&mut self, buf: &[u8]) -> bool {
        if self.file.is_some() && self.written >= SEGMENT_BYTES {
            self.sync();
            self.segment += 1;
            self.file = None;
            self.written = 0;
        }
        if self.file.is_none() {
            let path = self.dir.join(segment_name(self.segment));
            let opened = OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .and_then(|f| {
                    self.written = f.metadata()?.len();
                    Ok(f)
                });
            match opened {
                Ok(f) => {
                    self.file = Some(f);
                    sync_parent(&path);
                }
                Err(_) => return false,
            }
        }
        let Some(file) = self.file.as_mut() else { return false };
        match file.write_all(buf) {
            Ok(()) => {
                self.written += buf.len() as u64;
                true
            }
            Err(_) => false,
        }
    }

    /// Fsync the current segment (the group-commit barrier).
    fn sync(&mut self) {
        if let Some(file) = &self.file {
            let _ = file.sync_all();
        }
    }
}

/// Directory-entry durability for a freshly created segment (unix: fsync
/// the parent directory; no portable equivalent elsewhere).
fn sync_parent(_path: &Path) {
    #[cfg(unix)]
    {
        if let Some(parent) = _path.parent() {
            if let Ok(dir) = fs::File::open(parent) {
                let _ = dir.sync_all();
            }
        }
    }
}

impl Timeline {
    /// Open (or resume) the timeline in `dir`, creating the directory
    /// if needed. A torn tail record left by a crash is truncated away;
    /// the sequence counter resumes after the last durable record.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Arc<Timeline>> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        let segments = list_segments(&dir)?;
        let (mut segment, mut seq, mut written) = (0u64, 0u64, 0u64);
        if let Some(&last) = segments.last() {
            segment = last;
            let path = dir.join(segment_name(last));
            let data = fs::read(&path)?;
            let (records, valid) = parse_segment_prefix(&data);
            if valid < data.len() {
                // Torn tail: repair in place, exactly like the store's
                // recovery sweep.
                let f = OpenOptions::new().write(true).open(&path)?;
                f.set_len(valid as u64)?;
                f.sync_all()?;
            }
            written = valid as u64;
            seq = records.last().map(|r| r.seq).unwrap_or(0);
            if seq == 0 && segments.len() > 1 {
                // Last segment empty/unreadable: resume after the one
                // before it.
                for &idx in segments.iter().rev().skip(1) {
                    let data = fs::read(dir.join(segment_name(idx)))?;
                    let (records, _) = parse_segment_prefix(&data);
                    if let Some(r) = records.last() {
                        seq = r.seq;
                        break;
                    }
                }
            }
        }
        let (tx, rx) = mpsc::sync_channel::<TlMsg>(CHANNEL_DEPTH);
        let last_seq = Arc::new(AtomicU64::new(seq));
        let thread_seq = Arc::clone(&last_seq);
        let mut writer =
            SegmentWriter { dir: dir.clone(), segment, file: None, written };
        let join = thread::Builder::new()
            .name("hmm-scan-timeline".to_string())
            .spawn(move || {
                let mut seq = seq;
                loop {
                    let first = match rx.recv() {
                        Ok(msg) => msg,
                        Err(_) => break,
                    };
                    let mut batch = Vec::new();
                    let mut flushes = Vec::new();
                    let mut sort = |msg: TlMsg| match msg {
                        TlMsg::Event(ev, ts) => batch.push((ev, ts)),
                        TlMsg::Flush(done) => flushes.push(done),
                        #[cfg(test)]
                        TlMsg::Stall(hold) => {
                            // Park until the test releases (or drops)
                            // the sender — upstream records now pile
                            // into the bounded channel.
                            let _ = hold.recv();
                        }
                    };
                    sort(first);
                    while let Ok(msg) = rx.try_recv() {
                        sort(msg);
                    }
                    let mut wrote = false;
                    for (event, ts_ms) in batch {
                        seq += 1;
                        let Json::Obj(mut obj) = event.to_json() else {
                            unreachable!("events serialize as objects")
                        };
                        obj.insert("seq".to_string(), Json::Num(seq as f64));
                        obj.insert("ts".to_string(), Json::Num(ts_ms as f64));
                        let payload = Json::Obj(obj).to_string_compact();
                        wrote |= writer.append(&frame(&payload, seq));
                    }
                    if wrote {
                        writer.sync();
                    }
                    thread_seq.store(seq, Ordering::SeqCst);
                    for done in flushes {
                        let _ = done.send(());
                    }
                }
                writer.sync();
            })
            .map_err(|e| {
                Error::coordinator(format!("timeline writer spawn: {e}"))
            })?;
        Ok(Arc::new(Timeline {
            tx: Some(tx),
            dropped: AtomicU64::new(0),
            last_seq,
            dir,
            join: Some(join),
        }))
    }

    /// Record one event. Non-blocking: if the bounded channel is full
    /// the event is dropped and counted instead of stalling the caller.
    pub fn record(&self, event: TimelineEvent) {
        let ts = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let tx = self.tx.as_ref().expect("timeline channel live until drop");
        if tx.try_send(TlMsg::Event(event, ts)).is_err() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Block until every event recorded before this call is framed and
    /// fsynced. Test/shutdown barrier — never on the serve path.
    pub fn flush(&self) {
        let (done_tx, done_rx) = mpsc::channel();
        let tx = self.tx.as_ref().expect("timeline channel live until drop");
        if tx.send(TlMsg::Flush(done_tx)).is_ok() {
            let _ = done_rx.recv();
        }
    }

    /// Sequence number of the last durably written record (0 before any
    /// event lands). Exact after [`flush`](Timeline::flush).
    pub fn last_seq(&self) -> u64 {
        self.last_seq.load(Ordering::SeqCst)
    }

    /// Events dropped because the bounded channel was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Segment files currently present in the timeline directory
    /// (scrape gauge; one `read_dir` per call, never on the emit path).
    pub fn segments(&self) -> u64 {
        list_segments(&self.dir).map(|v| v.len() as u64).unwrap_or(0)
    }

    /// The timeline directory this handle writes to.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Test hook: park the writer thread until the returned sender is
    /// signalled or dropped, so records pile into the bounded channel
    /// and the drop counter can be driven deterministically.
    #[cfg(test)]
    pub(crate) fn stall(&self) -> mpsc::Sender<()> {
        let (hold_tx, hold_rx) = mpsc::channel();
        let tx = self.tx.as_ref().expect("timeline channel live until drop");
        let _ = tx.send(TlMsg::Stall(hold_rx));
        hold_tx
    }
}

// Manual: the writer handle and channel ends aren't printable state.
impl std::fmt::Debug for Timeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Timeline")
            .field("dir", &self.dir)
            .field("last_seq", &self.last_seq())
            .field("dropped", &self.dropped())
            .finish_non_exhaustive()
    }
}

impl Drop for Timeline {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// Build the framed bytes of a record exactly as the writer thread
/// does — the torn-tail tests cut real frames, not approximations.
#[cfg(test)]
pub(crate) fn framed_record(
    event: &TimelineEvent,
    seq: u64,
    ts_ms: u64,
) -> Vec<u8> {
    let Json::Obj(mut obj) = event.to_json() else {
        unreachable!("events serialize as objects")
    };
    obj.insert("seq".to_string(), Json::Num(seq as f64));
    obj.insert("ts".to_string(), Json::Num(ts_ms as f64));
    let payload = Json::Obj(obj).to_string_compact();
    frame(&payload, seq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptestx::Runner;

    fn events(n: usize) -> Vec<TimelineEvent> {
        (0..n)
            .map(|i| match i % 4 {
                0 => TimelineEvent::SessionOpen {
                    session: i as u64,
                    model: "ge".to_string(),
                    len: 0,
                },
                1 => TimelineEvent::Append {
                    session: i as u64 - 1,
                    appended: 8,
                    len: 8 * (i / 4 + 1),
                },
                2 => TimelineEvent::Spill { session: i as u64 - 2, len: 8 },
                _ => TimelineEvent::ConnOpen { conn: i as u64 },
            })
            .collect()
    }

    #[test]
    fn write_flush_read_round_trip() {
        let dir = crate::store::testutil::tempdir("obs-roundtrip");
        let evs = events(17);
        {
            let tl = Timeline::open(&dir).unwrap();
            for ev in &evs {
                tl.record(ev.clone());
            }
            tl.flush();
            assert_eq!(tl.last_seq(), evs.len() as u64);
            assert_eq!(tl.dropped(), 0);
        }
        let records = read_events(&dir).unwrap();
        assert_eq!(records.len(), evs.len());
        for (i, (rec, ev)) in records.iter().zip(&evs).enumerate() {
            assert_eq!(rec.seq, i as u64 + 1);
            assert_eq!(&rec.event, ev);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reopen_resumes_the_sequence() {
        let dir = crate::store::testutil::tempdir("obs-resume");
        {
            let tl = Timeline::open(&dir).unwrap();
            for ev in events(5) {
                tl.record(ev);
            }
            tl.flush();
        }
        {
            let tl = Timeline::open(&dir).unwrap();
            assert_eq!(tl.last_seq(), 5);
            tl.record(TimelineEvent::Drain { target: "server".to_string() });
            tl.flush();
            assert_eq!(tl.last_seq(), 6);
        }
        let records = read_events(&dir).unwrap();
        assert_eq!(records.len(), 6);
        assert_eq!(records.last().unwrap().seq, 6);
        assert_eq!(
            records.last().unwrap().event,
            TimelineEvent::Drain { target: "server".to_string() }
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_recovers_longest_valid_prefix_at_every_offset() {
        // Satellite: mirror of the store's torn-tail property tests.
        // Build a segment of K framed records, then truncate at every
        // byte offset of the tail record — the reader must recover
        // exactly the first K-1 records, and `open` must repair the
        // file back to that prefix.
        let dir = crate::store::testutil::tempdir("obs-torn");
        std::fs::create_dir_all(&dir).unwrap();
        let evs = events(6);
        let mut full = Vec::new();
        let mut boundaries = vec![0usize];
        for (i, ev) in evs.iter().enumerate() {
            full.extend_from_slice(&framed_record(ev, i as u64 + 1, 1000 + i as u64));
            boundaries.push(full.len());
        }
        let tail_start = boundaries[evs.len() - 1];
        let path = dir.join(segment_name(0));
        for cut in tail_start..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let records = read_events(&dir).unwrap();
            assert_eq!(
                records.len(),
                evs.len() - 1,
                "cut at byte {cut} must keep exactly the valid prefix"
            );
            assert_eq!(records.last().unwrap().seq, evs.len() as u64 - 1);
        }
        // The undamaged image reads in full.
        std::fs::write(&path, &full).unwrap();
        assert_eq!(read_events(&dir).unwrap().len(), evs.len());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn random_cuts_recover_a_valid_prefix() {
        // Randomized companion to the exhaustive tail sweep: a cut
        // anywhere in the image recovers the longest record prefix that
        // fits under the cut, and reopening repairs + resumes from it.
        let dir = crate::store::testutil::tempdir("obs-torn-prop");
        std::fs::create_dir_all(&dir).unwrap();
        let evs = events(9);
        let mut full = Vec::new();
        let mut boundaries = vec![0usize];
        for (i, ev) in evs.iter().enumerate() {
            full.extend_from_slice(&framed_record(ev, i as u64 + 1, i as u64));
            boundaries.push(full.len());
        }
        let path = dir.join(segment_name(0));
        let mut runner = Runner::new("obs-timeline-torn-tail");
        runner.run(64, |rng| {
            let cut = (rng.next_u64() as usize) % (full.len() + 1);
            let expect =
                boundaries.iter().filter(|&&b| b > 0 && b <= cut).count();
            std::fs::write(&path, &full[..cut]).unwrap();
            let records = read_events(&dir).unwrap();
            assert_eq!(records.len(), expect, "cut at byte {cut}");
            // Reopen: the torn tail is truncated and the sequence
            // resumes exactly after the surviving prefix.
            {
                let tl = Timeline::open(&dir).unwrap();
                assert_eq!(tl.last_seq(), expect as u64);
                tl.record(TimelineEvent::ConnRefuse);
                tl.flush();
            }
            let records = read_events(&dir).unwrap();
            assert_eq!(records.len(), expect + 1);
            assert_eq!(records.last().unwrap().seq, expect as u64 + 1);
            assert_eq!(records.last().unwrap().event, TimelineEvent::ConnRefuse);
        });
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_checksum_ends_the_stream() {
        let dir = crate::store::testutil::tempdir("obs-corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let evs = events(4);
        let mut full = Vec::new();
        let mut starts = Vec::new();
        for (i, ev) in evs.iter().enumerate() {
            starts.push(full.len());
            full.extend_from_slice(&framed_record(ev, i as u64 + 1, 0));
        }
        // Flip one payload byte of record 3 (0-indexed 2): records
        // 1..=2 survive, 3 and 4 are gone.
        let mut bad = full.clone();
        bad[starts[2] + HEADER_LEN] ^= 0x01;
        std::fs::write(dir.join(segment_name(0)), &bad).unwrap();
        let records = read_events(&dir).unwrap();
        assert_eq!(records.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stalled_writer_counts_drops_and_recovers() {
        let dir = crate::store::testutil::tempdir("obs-stall");
        let tl = Timeline::open(&dir).unwrap();
        assert_eq!(tl.segments(), 0);
        let release = tl.stall();
        // With the writer parked, at most CHANNEL_DEPTH records queue;
        // the rest must be dropped (counted), never blocking us here.
        for _ in 0..(CHANNEL_DEPTH * 3) {
            tl.record(TimelineEvent::ConnRefuse);
        }
        assert!(tl.dropped() > 0, "channel never filled");
        drop(release);
        tl.flush();
        let written = read_events(&dir).unwrap().len();
        assert_eq!(written as u64 + tl.dropped(), (CHANNEL_DEPTH * 3) as u64);
        assert_eq!(tl.last_seq(), written as u64);
        assert_eq!(tl.segments(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segments_rotate_and_read_in_order() {
        // Drive rotation through the real writer by writing two
        // segments' worth of records via the private segment API, then
        // confirm read order. (SEGMENT_BYTES is large; simulate the
        // boundary by writing segment files directly with continuing
        // sequence numbers, as rotation does.)
        let dir = crate::store::testutil::tempdir("obs-segments");
        std::fs::create_dir_all(&dir).unwrap();
        let evs = events(8);
        let mut seg0 = Vec::new();
        let mut seg1 = Vec::new();
        for (i, ev) in evs.iter().enumerate() {
            let buf = framed_record(ev, i as u64 + 1, 0);
            if i < 5 {
                seg0.extend_from_slice(&buf);
            } else {
                seg1.extend_from_slice(&buf);
            }
        }
        std::fs::write(dir.join(segment_name(0)), &seg0).unwrap();
        std::fs::write(dir.join(segment_name(1)), &seg1).unwrap();
        let records = read_events(&dir).unwrap();
        assert_eq!(records.len(), 8);
        assert!(records.windows(2).all(|w| w[1].seq == w[0].seq + 1));
        // A reopened timeline resumes after the last segment's tail.
        let tl = Timeline::open(&dir).unwrap();
        assert_eq!(tl.last_seq(), 8);
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Timeline event vocabulary: every session / connection / cluster
//! state transition the serving stack records.
//!
//! Events are deliberately *flat* — one kind string plus a handful of
//! scalar fields — so a record stays a single short compact-JSON line
//! and the replay fold (`obs::replay`) never needs to interpret nested
//! payloads. The JSON encoding is part of the timeline's on-disk
//! contract, specified in `docs/OBSERVABILITY.md`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::jsonx::Json;

/// One recorded state transition.
///
/// The coordinator emits the session-lifecycle kinds (`open`, `append`,
/// `spill`, `restore`, `close`, `release`, `recover`), the network
/// server the connection kinds (`conn-open`, `conn-close`,
/// `conn-refuse`, `reject`, `drain`), and the cluster router the
/// placement kinds (`place`, `migrate-begin`, `migrate-verify`,
/// `migrate-cutover`, plus its own `close`/`reject`/`drain`). Replay
/// folds any mix — a server and its fronting network layer share one
/// timeline.
///
/// Every traced process additionally emits the request-tracing pair
/// (`span-begin`, `span-end`); `obs::replay::merge_records` joins them
/// across processes by trace id (`docs/OBSERVABILITY.md` §Tracing).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TimelineEvent {
    /// A session was opened (or imported) and is resident.
    SessionOpen {
        /// Session id.
        session: u64,
        /// Model registry key the session is bound to.
        model: String,
        /// Observations held at open (> 0 only for imports).
        len: usize,
    },
    /// An observation chunk was appended (the session is resident).
    Append {
        /// Session id.
        session: u64,
        /// Observations in this chunk.
        appended: usize,
        /// Observations held after the append.
        len: usize,
    },
    /// A session's element chain was spilled to the session store.
    Spill {
        /// Session id.
        session: u64,
        /// Observations covered by the spill checkpoint.
        len: usize,
    },
    /// An evicted session was restored into RAM.
    Restore {
        /// Session id.
        session: u64,
        /// Observations held after the restore.
        len: usize,
    },
    /// A session was closed (finished) and removed everywhere.
    SessionClose {
        /// Session id.
        session: u64,
    },
    /// A session was released without finishing (migration source).
    Release {
        /// Session id.
        session: u64,
    },
    /// Crash recovery re-registered a stored session (evicted).
    Recover {
        /// Session id.
        session: u64,
        /// Model registry key the session is bound to.
        model: String,
        /// Observations the store holds for it.
        len: usize,
    },
    /// A client connection was accepted.
    ConnOpen {
        /// Server-assigned connection id.
        conn: u64,
    },
    /// A client connection ended (either side).
    ConnClose {
        /// Server-assigned connection id.
        conn: u64,
    },
    /// A connection was refused (admission control or drain).
    ConnRefuse,
    /// A request was shed with a typed reject frame.
    Reject {
        /// What was saturated (drain, quota, deadline, worker pool…).
        msg: String,
    },
    /// A drain began (`target` = `"server"`, or a worker address for a
    /// cluster-router administrative drain).
    Drain {
        /// What is draining.
        target: String,
    },
    /// The cluster router placed a session on a worker.
    Place {
        /// Session id.
        session: u64,
        /// Worker address the session now lives on.
        worker: String,
    },
    /// A live migration started (route lock held).
    MigrateBegin {
        /// Session id.
        session: u64,
        /// Source worker address.
        from: String,
        /// Destination worker address.
        to: String,
    },
    /// The migrated copy verified (length + model match) on the target.
    MigrateVerify {
        /// Session id.
        session: u64,
        /// Destination worker address.
        to: String,
    },
    /// The route cut over to the destination worker.
    MigrateCutover {
        /// Session id.
        session: u64,
        /// Source worker address.
        from: String,
        /// Destination worker address (the new home).
        to: String,
    },
    /// A traced request stage started on this process.
    ///
    /// Trace/span ids are fnv64 values; they are encoded as 16-hex-digit
    /// strings on the wire and in the timeline because the compact-JSON
    /// number type is an f64 (53 bits of integer precision).
    SpanBegin {
        /// Trace id shared by every span of one end-to-end request.
        trace: u64,
        /// This span's id (unique within the trace).
        span: u64,
        /// Parent span id (0 for a trace root).
        parent: u64,
        /// Stage label (`admission`, `queue`, `execute`, `checkout`,
        /// `store-append`, `sync-wait`, `migrate`).
        stage: String,
    },
    /// A traced request stage finished.
    SpanEnd {
        /// Trace id shared by every span of one end-to-end request.
        trace: u64,
        /// The span id opened by the matching [`SpanBegin`](Self::SpanBegin).
        span: u64,
        /// Stage label (mirrors the begin record for self-contained reads).
        stage: String,
        /// Stage latency in microseconds.
        us: u64,
        /// Whether the owning request exceeded the `--slow-ms` threshold
        /// (encoded only when true — additive-field rules).
        slow: bool,
        /// Optional stage annotation, e.g. kernel counter deltas for an
        /// `execute` span (encoded only when non-empty).
        detail: String,
    },
}

impl TimelineEvent {
    /// Stable kind string (the record's `"ev"` field).
    pub fn kind(&self) -> &'static str {
        match self {
            TimelineEvent::SessionOpen { .. } => "open",
            TimelineEvent::Append { .. } => "append",
            TimelineEvent::Spill { .. } => "spill",
            TimelineEvent::Restore { .. } => "restore",
            TimelineEvent::SessionClose { .. } => "close",
            TimelineEvent::Release { .. } => "release",
            TimelineEvent::Recover { .. } => "recover",
            TimelineEvent::ConnOpen { .. } => "conn-open",
            TimelineEvent::ConnClose { .. } => "conn-close",
            TimelineEvent::ConnRefuse => "conn-refuse",
            TimelineEvent::Reject { .. } => "reject",
            TimelineEvent::Drain { .. } => "drain",
            TimelineEvent::Place { .. } => "place",
            TimelineEvent::MigrateBegin { .. } => "migrate-begin",
            TimelineEvent::MigrateVerify { .. } => "migrate-verify",
            TimelineEvent::MigrateCutover { .. } => "migrate-cutover",
            TimelineEvent::SpanBegin { .. } => "span-begin",
            TimelineEvent::SpanEnd { .. } => "span-end",
        }
    }

    /// Serialize as the flat record object (without the writer-assigned
    /// `seq`/`ts` fields — `obs::log` stamps those).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("ev".to_string(), Json::Str(self.kind().to_string()));
        let mut num = |obj: &mut BTreeMap<String, Json>, k: &str, v: u64| {
            obj.insert(k.to_string(), Json::Num(v as f64));
        };
        let hex = |obj: &mut BTreeMap<String, Json>, k: &str, v: u64| {
            obj.insert(k.to_string(), Json::Str(format!("{v:016x}")));
        };
        match self {
            TimelineEvent::SessionOpen { session, model, len }
            | TimelineEvent::Recover { session, model, len } => {
                num(&mut obj, "session", *session);
                obj.insert("model".to_string(), Json::Str(model.clone()));
                num(&mut obj, "len", *len as u64);
            }
            TimelineEvent::Append { session, appended, len } => {
                num(&mut obj, "session", *session);
                num(&mut obj, "n", *appended as u64);
                num(&mut obj, "len", *len as u64);
            }
            TimelineEvent::Spill { session, len }
            | TimelineEvent::Restore { session, len } => {
                num(&mut obj, "session", *session);
                num(&mut obj, "len", *len as u64);
            }
            TimelineEvent::SessionClose { session }
            | TimelineEvent::Release { session } => {
                num(&mut obj, "session", *session);
            }
            TimelineEvent::ConnOpen { conn }
            | TimelineEvent::ConnClose { conn } => {
                num(&mut obj, "conn", *conn);
            }
            TimelineEvent::ConnRefuse => {}
            TimelineEvent::Reject { msg } => {
                obj.insert("msg".to_string(), Json::Str(msg.clone()));
            }
            TimelineEvent::Drain { target } => {
                obj.insert("target".to_string(), Json::Str(target.clone()));
            }
            TimelineEvent::Place { session, worker } => {
                num(&mut obj, "session", *session);
                obj.insert("worker".to_string(), Json::Str(worker.clone()));
            }
            TimelineEvent::MigrateBegin { session, from, to } => {
                num(&mut obj, "session", *session);
                obj.insert("from".to_string(), Json::Str(from.clone()));
                obj.insert("to".to_string(), Json::Str(to.clone()));
            }
            TimelineEvent::MigrateVerify { session, to } => {
                num(&mut obj, "session", *session);
                obj.insert("to".to_string(), Json::Str(to.clone()));
            }
            TimelineEvent::MigrateCutover { session, from, to } => {
                num(&mut obj, "session", *session);
                obj.insert("from".to_string(), Json::Str(from.clone()));
                obj.insert("to".to_string(), Json::Str(to.clone()));
            }
            TimelineEvent::SpanBegin { trace, span, parent, stage } => {
                hex(&mut obj, "tr", *trace);
                hex(&mut obj, "sp", *span);
                hex(&mut obj, "ps", *parent);
                obj.insert("stage".to_string(), Json::Str(stage.clone()));
            }
            TimelineEvent::SpanEnd { trace, span, stage, us, slow, detail } => {
                hex(&mut obj, "tr", *trace);
                hex(&mut obj, "sp", *span);
                obj.insert("stage".to_string(), Json::Str(stage.clone()));
                num(&mut obj, "us", *us);
                if *slow {
                    obj.insert("slow".to_string(), Json::Bool(true));
                }
                if !detail.is_empty() {
                    obj.insert(
                        "detail".to_string(),
                        Json::Str(detail.clone()),
                    );
                }
            }
        }
        Json::Obj(obj)
    }

    /// Inverse of [`to_json`](Self::to_json); typed errors on missing
    /// or malformed fields, unknown kinds included (a reader must not
    /// silently mis-fold a record written by a future revision).
    pub fn from_json(v: &Json) -> Result<TimelineEvent> {
        let kind = v
            .get("ev")
            .as_str()
            .ok_or_else(|| Error::invalid_request("timeline record: 'ev'"))?;
        let num = |key: &str| -> Result<u64> {
            v.get(key).as_usize().map(|n| n as u64).ok_or_else(|| {
                Error::invalid_request(format!("timeline record: '{key}'"))
            })
        };
        let text = |key: &str| -> Result<String> {
            v.get(key)
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| {
                    Error::invalid_request(format!("timeline record: '{key}'"))
                })
        };
        let hex = |key: &str| -> Result<u64> {
            v.get(key)
                .as_str()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| {
                    Error::invalid_request(format!("timeline record: '{key}'"))
                })
        };
        Ok(match kind {
            "open" => TimelineEvent::SessionOpen {
                session: num("session")?,
                model: text("model")?,
                len: num("len")? as usize,
            },
            "append" => TimelineEvent::Append {
                session: num("session")?,
                appended: num("n")? as usize,
                len: num("len")? as usize,
            },
            "spill" => TimelineEvent::Spill {
                session: num("session")?,
                len: num("len")? as usize,
            },
            "restore" => TimelineEvent::Restore {
                session: num("session")?,
                len: num("len")? as usize,
            },
            "close" => TimelineEvent::SessionClose { session: num("session")? },
            "release" => TimelineEvent::Release { session: num("session")? },
            "recover" => TimelineEvent::Recover {
                session: num("session")?,
                model: text("model")?,
                len: num("len")? as usize,
            },
            "conn-open" => TimelineEvent::ConnOpen { conn: num("conn")? },
            "conn-close" => TimelineEvent::ConnClose { conn: num("conn")? },
            "conn-refuse" => TimelineEvent::ConnRefuse,
            "reject" => TimelineEvent::Reject { msg: text("msg")? },
            "drain" => TimelineEvent::Drain { target: text("target")? },
            "place" => TimelineEvent::Place {
                session: num("session")?,
                worker: text("worker")?,
            },
            "migrate-begin" => TimelineEvent::MigrateBegin {
                session: num("session")?,
                from: text("from")?,
                to: text("to")?,
            },
            "migrate-verify" => TimelineEvent::MigrateVerify {
                session: num("session")?,
                to: text("to")?,
            },
            "migrate-cutover" => TimelineEvent::MigrateCutover {
                session: num("session")?,
                from: text("from")?,
                to: text("to")?,
            },
            "span-begin" => TimelineEvent::SpanBegin {
                trace: hex("tr")?,
                span: hex("sp")?,
                parent: hex("ps")?,
                stage: text("stage")?,
            },
            "span-end" => TimelineEvent::SpanEnd {
                trace: hex("tr")?,
                span: hex("sp")?,
                stage: text("stage")?,
                us: num("us")?,
                // Optional fields (additive-field rules): absent means
                // false / empty, so old writers' records still parse.
                slow: v.get("slow").as_bool().unwrap_or(false),
                detail: v
                    .get("detail")
                    .as_str()
                    .unwrap_or("")
                    .to_string(),
            },
            other => {
                return Err(Error::invalid_request(format!(
                    "timeline record: unknown event kind '{other}'"
                )))
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn every_event() -> Vec<TimelineEvent> {
        vec![
            TimelineEvent::SessionOpen {
                session: 7,
                model: "ge".into(),
                len: 0,
            },
            TimelineEvent::Append { session: 7, appended: 32, len: 96 },
            TimelineEvent::Spill { session: 7, len: 96 },
            TimelineEvent::Restore { session: 7, len: 96 },
            TimelineEvent::SessionClose { session: 7 },
            TimelineEvent::Release { session: 9 },
            TimelineEvent::Recover {
                session: 3,
                model: "cv".into(),
                len: 40,
            },
            TimelineEvent::ConnOpen { conn: 1 },
            TimelineEvent::ConnClose { conn: 1 },
            TimelineEvent::ConnRefuse,
            TimelineEvent::Reject { msg: "draining".into() },
            TimelineEvent::Drain { target: "server".into() },
            TimelineEvent::Place { session: 7, worker: "127.0.0.1:9001".into() },
            TimelineEvent::MigrateBegin {
                session: 7,
                from: "a:1".into(),
                to: "b:2".into(),
            },
            TimelineEvent::MigrateVerify { session: 7, to: "b:2".into() },
            TimelineEvent::MigrateCutover {
                session: 7,
                from: "a:1".into(),
                to: "b:2".into(),
            },
            TimelineEvent::SpanBegin {
                trace: u64::MAX,
                span: 0xdead_beef_0042_0001,
                parent: 0,
                stage: "execute".into(),
            },
            TimelineEvent::SpanEnd {
                trace: u64::MAX,
                span: 0xdead_beef_0042_0001,
                stage: "execute".into(),
                us: 1234,
                slow: true,
                detail: "spec_d4=12".into(),
            },
            TimelineEvent::SpanEnd {
                trace: 1,
                span: 2,
                stage: "queue".into(),
                us: 0,
                slow: false,
                detail: String::new(),
            },
        ]
    }

    #[test]
    fn json_round_trip_every_kind() {
        for ev in every_event() {
            let json = ev.to_json();
            // The encoding survives a full text round-trip (what the
            // log writer/reader actually do).
            let text = json.to_string_compact();
            let back = Json::parse(&text).unwrap();
            assert_eq!(TimelineEvent::from_json(&back).unwrap(), ev);
            assert_eq!(back.get("ev").as_str().unwrap(), ev.kind());
        }
    }

    #[test]
    fn malformed_records_are_typed_errors() {
        assert!(TimelineEvent::from_json(&Json::Null).is_err());
        let unknown = Json::parse(r#"{"ev":"warp"}"#).unwrap();
        assert!(TimelineEvent::from_json(&unknown).is_err());
        let missing = Json::parse(r#"{"ev":"open","model":"ge"}"#).unwrap();
        assert!(TimelineEvent::from_json(&missing).is_err());
        let bad_type = Json::parse(r#"{"ev":"append","session":"x"}"#).unwrap();
        assert!(TimelineEvent::from_json(&bad_type).is_err());
        // Span ids must be 16-hex strings, not JSON numbers.
        let bad_id =
            Json::parse(r#"{"ev":"span-begin","tr":7,"sp":"1","ps":"0","stage":"queue"}"#)
                .unwrap();
        assert!(TimelineEvent::from_json(&bad_id).is_err());
    }

    #[test]
    fn span_ids_survive_full_u64_range_and_options_default() {
        // f64 holds only 53 integer bits; the hex-string encoding must
        // round-trip ids that a JSON number would silently corrupt.
        let ev = TimelineEvent::SpanBegin {
            trace: (1u64 << 53) + 1,
            span: u64::MAX - 1,
            parent: 3,
            stage: "admission".into(),
        };
        let back =
            TimelineEvent::from_json(&Json::parse(&ev.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back, ev);

        // A span-end without `slow`/`detail` (old writer) parses with
        // the defaults, and a fast/plain span never encodes them.
        let plain = TimelineEvent::SpanEnd {
            trace: 1,
            span: 2,
            stage: "queue".into(),
            us: 55,
            slow: false,
            detail: String::new(),
        };
        let text = plain.to_json().to_string_compact();
        assert!(!text.contains("slow") && !text.contains("detail"), "{text}");
        assert_eq!(
            TimelineEvent::from_json(&Json::parse(&text).unwrap()).unwrap(),
            plain
        );
    }
}

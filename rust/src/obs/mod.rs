//! Observability tier: an event-sourced, replayable coordinator
//! timeline.
//!
//! The serving stack (coordinator → session store → TCP front-end →
//! cluster router) exposes counters through
//! [`Metrics`](crate::coordinator::Metrics), but counters cannot answer
//! *why* — why a session spilled, why a p99 spiked, why a request was
//! shed. This module adds the event log that can: every session,
//! connection, and cluster state transition is appended to a segmented,
//! crash-safe timeline ([`log`]) through a bounded non-blocking channel
//! (the serve path never stalls on observability), and [`replay`] folds
//! that log back into the registry view — resident set, open
//! connections, per-worker placement — deterministically, at any
//! sequence number. `docs/OBSERVABILITY.md` specifies the record
//! schema, the scrape line format, and the replay semantics.
//!
//! Layout:
//!
//! * [`event`] — the flat [`TimelineEvent`] vocabulary and its JSON
//!   encoding.
//! * [`log`] — [`Timeline`] (bounded-channel writer, segmented framed
//!   log mirroring `docs/STORE_FORMAT.md`) and the prefix-valid
//!   [`read_events`] reader.
//! * [`replay`] — the pure [`replay`](replay::replay) fold producing
//!   [`ReplayState`], plus the cluster merge ([`merge_records`],
//!   [`trace_views`]) that joins N process timelines into causally
//!   ordered per-request span trees.
//! * [`span`] — request tracing: ambient `(trace, span)` context and
//!   the [`StageSpan`](span::StageSpan) guard emitting the
//!   `span-begin`/`span-end` record pair.

pub mod event;
pub mod log;
pub mod replay;
pub mod span;

pub use event::TimelineEvent;
pub use log::{read_events, Timeline, TimelineRecord};
pub use replay::{
    merge_records, replay as replay_records, trace_views, MergedRecord,
    ReplayState, SessionView, SpanView, TraceView,
};

//! Deterministic pseudo-random number generation (the `rand` crate is
//! unavailable offline — see DESIGN.md §1).
//!
//! [`SplitMix64`] seeds [`Xoshiro256StarStar`], the general-purpose
//! generator used throughout the workload generators and tests.
//! Distribution helpers cover exactly what the HMM experiments need:
//! uniforms, Bernoulli draws and categorical sampling. [`fnv1a_64`] is
//! the crate's one non-cryptographic byte hash (proptest seed
//! derivation, session-log framing checksums, model fingerprints).

/// FNV-1a 64 offset basis — the fresh-start seed for [`fnv1a_64`].
pub const FNV1A_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a 64 over `bytes`, continuing from `seed` (pass
/// [`FNV1A_OFFSET`] to start fresh; pass a previous result to chain
/// multi-part inputs). One definition shared by every caller so the
/// hash can never silently diverge between them.
pub fn fnv1a_64(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// SplitMix64 — tiny, fast seeder (Steele, Lea & Flood 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the generator.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniform bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the crate's workhorse generator (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Xoshiro256StarStar {
    s: [u64; 4],
}

impl Xoshiro256StarStar {
    /// Seed via SplitMix64 (as the reference implementation recommends).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 uniform bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hilo(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Categorical sample from (unnormalized, nonnegative) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: all-zero weights");
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1 // fp round-off: land on the last category
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul_hilo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference sequence for seed 1234567 (from the public domain
        // splitmix64.c by Vigna).
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut r = Xoshiro256StarStar::seed_from_u64(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Xoshiro256StarStar::seed_from_u64(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Xoshiro256StarStar::seed_from_u64(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Xoshiro256StarStar::seed_from_u64(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Xoshiro256StarStar::seed_from_u64(3);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.02, "frac {frac}");
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Xoshiro256StarStar::seed_from_u64(11);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            counts[r.categorical(&w)] += 1;
        }
        let fracs: Vec<f64> = counts.iter().map(|&c| c as f64 / n as f64).collect();
        assert!((fracs[0] - 0.1).abs() < 0.02);
        assert!((fracs[1] - 0.3).abs() < 0.02);
        assert!((fracs[2] - 0.6).abs() < 0.02);
    }

    #[test]
    fn categorical_degenerate_weight() {
        let mut r = Xoshiro256StarStar::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(r.categorical(&[0.0, 1.0, 0.0]), 1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256StarStar::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
        assert_ne!(xs, (0..50).collect::<Vec<u32>>()); // astronomically unlikely
    }
}

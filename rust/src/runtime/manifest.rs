//! The artifact manifest — the L2→L3 interchange contract.
//!
//! `python/compile/aot.py` emits `manifest.json` describing every
//! compiled HLO artifact: entry name, static shape grid position
//! (T, D, M), and the full input/output signature. This module parses
//! and indexes it; the [`Registry`](super::Registry) compiles artifacts
//! lazily and the [`Router`](crate::coordinator::Router) plans requests
//! against it.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::jsonx::Json;

/// Tensor element type used in artifact signatures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(Error::artifact(format!("unknown dtype '{other}'"))),
        }
    }
}

/// One input or output tensor in an artifact signature.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    /// Tensor name in the artifact signature.
    pub name: String,
    /// Static tensor shape.
    pub shape: Vec<usize>,
    /// Element dtype.
    pub dtype: DType,
}

impl IoSpec {
    /// Product of the shape dimensions.
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Whole-sequence vs block-wise (§V-B) artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Whole-sequence artifact (padded to a static T).
    Core,
    /// Block-wise fold/finalize artifact for sharded plans.
    Block,
}

/// One compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    /// Unique artifact name (manifest key).
    pub name: String,
    /// L2 entry point name (`sp_par`, `viterbi`, `sp_block_fold_mid`, …).
    pub entry: String,
    /// Core vs block artifact.
    pub kind: ArtifactKind,
    /// Static sequence length (core) or block length (block).
    pub t: usize,
    /// Number of hidden states.
    pub d: usize,
    /// Number of observation symbols.
    pub m: usize,
    /// Absolute path of the HLO text file.
    pub path: PathBuf,
    /// Input tensor signature, positional.
    pub inputs: Vec<IoSpec>,
    /// Output tensor signature, positional.
    pub outputs: Vec<IoSpec>,
}

/// Parsed, indexed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    dir: PathBuf,
    artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| Error::artifact(format!("manifest.json: {e}")))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text (directory used to resolve paths).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let root = Json::parse(text)?;
        if root.req_usize("version")? != 1 {
            return Err(Error::artifact("unsupported manifest version"));
        }
        if root.req_str("interchange")? != "hlo-text" {
            return Err(Error::artifact("unsupported interchange format"));
        }
        let mut artifacts = Vec::new();
        for rec in root.req_arr("artifacts")? {
            let kind = match rec.req_str("kind")? {
                "core" => ArtifactKind::Core,
                "block" => ArtifactKind::Block,
                other => {
                    return Err(Error::artifact(format!("unknown kind '{other}'")))
                }
            };
            artifacts.push(ArtifactSpec {
                name: rec.req_str("name")?.to_string(),
                entry: rec.req_str("entry")?.to_string(),
                kind,
                t: rec.req_usize("t")?,
                d: rec.req_usize("d")?,
                m: rec.req_usize("m")?,
                path: dir.join(rec.req_str("path")?),
                inputs: parse_ios(rec.req_arr("inputs")?)?,
                outputs: parse_ios(rec.req_arr("outputs")?)?,
            });
        }
        let m = Self { dir, artifacts };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        for a in &self.artifacts {
            if !seen.insert(&a.name) {
                return Err(Error::artifact(format!("duplicate artifact '{}'", a.name)));
            }
            if a.t == 0 || a.d == 0 || a.m == 0 {
                return Err(Error::artifact(format!("degenerate shape in '{}'", a.name)));
            }
        }
        Ok(())
    }

    /// The artifact directory the manifest was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Every artifact, manifest order.
    pub fn artifacts(&self) -> &[ArtifactSpec] {
        &self.artifacts
    }

    /// Look up one artifact by its unique name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Exact (entry, t, d, m) lookup.
    pub fn find(&self, entry: &str, t: usize, d: usize, m: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.entry == entry && a.t == t && a.d == d && a.m == m)
    }

    /// Smallest core artifact of `entry` whose capacity covers `min_t`
    /// (the router pads the remainder with masked steps).
    pub fn smallest_covering(
        &self,
        entry: &str,
        min_t: usize,
        d: usize,
        m: usize,
    ) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Core
                    && a.entry == entry
                    && a.d == d
                    && a.m == m
                    && a.t >= min_t
            })
            .min_by_key(|a| a.t)
    }

    /// Largest core artifact capacity for `entry` at (d, m).
    pub fn largest_core(&self, entry: &str, d: usize, m: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Core && a.entry == entry && a.d == d && a.m == m)
            .max_by_key(|a| a.t)
    }

    /// Block artifact for `entry` at (d, m) — any block length.
    pub fn block(&self, entry: &str, d: usize, m: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.kind == ArtifactKind::Block && a.entry == entry && a.d == d && a.m == m)
    }
}

fn parse_ios(items: &[Json]) -> Result<Vec<IoSpec>> {
    items
        .iter()
        .map(|io| {
            let shape = io
                .req_arr("shape")?
                .iter()
                .map(|v| {
                    v.as_usize()
                        .ok_or_else(|| Error::artifact("non-integer dim"))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(IoSpec {
                name: io.req_str("name")?.to_string(),
                shape,
                dtype: DType::parse(io.req_str("dtype")?)?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "interchange": "hlo-text", "generator": "x",
      "artifacts": [
        {"name": "sp_par_T128_D4_M2", "entry": "sp_par", "kind": "core",
         "t": 128, "d": 4, "m": 2, "path": "sp_par_T128_D4_M2.hlo.txt",
         "inputs": [{"name": "pi", "shape": [4,4], "dtype": "f32"},
                    {"name": "ys", "shape": [128], "dtype": "i32"}],
         "outputs": [{"name": "gamma", "shape": [128,4], "dtype": "f32"},
                     {"name": "loglik", "shape": [], "dtype": "f32"}]},
        {"name": "sp_par_T1024_D4_M2", "entry": "sp_par", "kind": "core",
         "t": 1024, "d": 4, "m": 2, "path": "p2.hlo.txt",
         "inputs": [], "outputs": []},
        {"name": "sp_block_fold_mid_L64_D4_M2", "entry": "sp_block_fold_mid",
         "kind": "block", "t": 64, "d": 4, "m": 2, "path": "b.hlo.txt",
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts().len(), 3);
        let a = m.find("sp_par", 128, 4, 2).unwrap();
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.outputs[1].shape, Vec::<usize>::new());
        assert_eq!(a.path, PathBuf::from("/tmp/a/sp_par_T128_D4_M2.hlo.txt"));
        assert_eq!(a.inputs[0].element_count(), 16);
    }

    #[test]
    fn smallest_covering_picks_tightest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert_eq!(m.smallest_covering("sp_par", 100, 4, 2).unwrap().t, 128);
        assert_eq!(m.smallest_covering("sp_par", 128, 4, 2).unwrap().t, 128);
        assert_eq!(m.smallest_covering("sp_par", 129, 4, 2).unwrap().t, 1024);
        assert!(m.smallest_covering("sp_par", 2000, 4, 2).is_none());
        assert!(m.smallest_covering("sp_par", 10, 8, 2).is_none());
        assert_eq!(m.largest_core("sp_par", 4, 2).unwrap().t, 1024);
    }

    #[test]
    fn block_lookup() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert!(m.block("sp_block_fold_mid", 4, 2).is_some());
        assert!(m.block("mp_block_fold_mid", 4, 2).is_none());
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse(
            r#"{"version": 2, "interchange": "hlo-text", "artifacts": []}"#,
            PathBuf::new()
        )
        .is_err());
        let dup = SAMPLE.replace("sp_par_T1024_D4_M2", "sp_par_T128_D4_M2");
        assert!(Manifest::parse(&dup, PathBuf::new()).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // Integration against the artifacts actually built by `make
        // artifacts` (skipped when the directory is absent, e.g. in a
        // bare checkout).
        let dir = crate::runtime::registry::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {dir:?}");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.find("sp_par", 1024, 4, 2).is_some());
        for a in m.artifacts() {
            assert!(a.path.exists(), "missing artifact file {:?}", a.path);
        }
    }
}

//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them from the request path.
//!
//! Python never runs at serve time — the interchange is
//! `artifacts/manifest.json` + `artifacts/*.hlo.txt`, loaded through the
//! `xla` crate's PJRT C API bindings:
//! `HloModuleProto::from_text_file → XlaComputation → client.compile →
//! execute`.

mod client;
mod executor;
mod manifest;
pub(crate) mod registry;

pub use client::{Executable, Value, XlaRuntime};
pub use executor::{marshal_block, ArtifactExec};
pub use manifest::{ArtifactKind, ArtifactSpec, DType, IoSpec, Manifest};
pub use registry::{artifacts_dir, Registry};

//! PJRT client wrapper: compile HLO text, execute with typed tensors.

use std::path::Path;

use crate::error::{Error, Result};
// The real PJRT bindings are unavailable offline; an API-compatible stub
// keeps this module building and fails typed at client construction.
use crate::xla_stub as xla;

use super::manifest::{ArtifactSpec, DType, IoSpec};

/// A host tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// f32 tensor: flat data + shape.
    F32(Vec<f32>, Vec<usize>),
    /// i32 tensor: flat data + shape.
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    /// A rank-0 f32 tensor.
    pub fn scalar_f32(v: f32) -> Self {
        Value::F32(vec![v], vec![])
    }

    /// Tensor shape (empty = scalar).
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(_, s) | Value::I32(_, s) => s,
        }
    }

    /// Element dtype.
    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(..) => DType::F32,
            Value::I32(..) => DType::I32,
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            Value::F32(d, _) => d.len(),
            Value::I32(d, _) => d.len(),
        }
    }

    /// Whether the tensor holds zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The flat f32 data; typed error for other dtypes.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(d, _) => Ok(d),
            _ => Err(Error::xla("expected f32 tensor")),
        }
    }

    /// The flat i32 data; typed error for other dtypes.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(d, _) => Ok(d),
            _ => Err(Error::xla("expected i32 tensor")),
        }
    }

    /// First element as f64 (for scalar outputs).
    pub fn scalar(&self) -> Result<f64> {
        match self {
            Value::F32(d, _) => d
                .first()
                .map(|&v| v as f64)
                .ok_or_else(|| Error::xla("empty tensor")),
            Value::I32(d, _) => d
                .first()
                .map(|&v| v as f64)
                .ok_or_else(|| Error::xla("empty tensor")),
        }
    }

    fn matches(&self, spec: &IoSpec) -> bool {
        self.dtype() == spec.dtype && self.shape() == spec.shape.as_slice()
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&v| v as i64).collect();
        let lit = match self {
            Value::F32(d, _) => xla::Literal::vec1(d),
            Value::I32(d, _) => xla::Literal::vec1(d),
        };
        lit.reshape(&dims).map_err(|e| Error::xla(e))
    }

    fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<Value> {
        let value = match spec.dtype {
            DType::F32 => {
                Value::F32(lit.to_vec::<f32>().map_err(Error::xla)?, spec.shape.clone())
            }
            DType::I32 => {
                Value::I32(lit.to_vec::<i32>().map_err(Error::xla)?, spec.shape.clone())
            }
        };
        if value.len() != spec.element_count() {
            return Err(Error::xla(format!(
                "output '{}': got {} elements, expected {}",
                spec.name,
                value.len(),
                spec.element_count()
            )));
        }
        Ok(value)
    }
}

/// The PJRT client (CPU plugin). One per process; executables share it.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// Construct over the CPU PJRT plugin (typed error when the real
    /// bindings are absent — the offline stub).
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu().map_err(Error::xla)? })
    }

    /// Platform name reported by the client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Devices the client sees.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Compile an artifact's HLO text into an executable.
    pub fn compile(&self, spec: &ArtifactSpec) -> Result<Executable> {
        self.compile_path(&spec.path, spec.clone())
    }

    fn compile_path(&self, path: &Path, spec: ArtifactSpec) -> Result<Executable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| Error::artifact("non-utf8 artifact path"))?;
        let proto =
            xla::HloModuleProto::from_text_file(path_str).map_err(Error::xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(Error::xla)?;
        Ok(Executable { exe, spec })
    }
}

/// A compiled artifact bound to its manifest signature. `run` validates
/// inputs against the signature before dispatch — shape bugs surface as
/// typed errors, not PJRT aborts.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

impl Executable {
    /// The manifest signature this executable was compiled against.
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Validate `inputs` against the signature and execute.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::invalid_request(format!(
                "{}: got {} inputs, expected {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        for (v, s) in inputs.iter().zip(&self.spec.inputs) {
            if !v.matches(s) {
                return Err(Error::invalid_request(format!(
                    "{}: input '{}' expects {:?} {:?}, got {:?} {:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    v.dtype(),
                    v.shape()
                )));
            }
        }

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(Error::xla)?;
        let out_lit = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::xla("no output buffer"))?
            .to_literal_sync()
            .map_err(Error::xla)?;

        // aot.py lowers with return_tuple=True → the output is a tuple.
        let parts = out_lit.to_tuple().map_err(Error::xla)?;
        if parts.len() != self.spec.outputs.len() {
            return Err(Error::xla(format!(
                "{}: got {} outputs, expected {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            )));
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| Value::from_literal(lit, spec))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{registry::artifacts_dir, Manifest};

    #[test]
    fn value_accessors() {
        let v = Value::F32(vec![1.0, 2.0], vec![2]);
        assert_eq!(v.shape(), &[2]);
        assert_eq!(v.dtype(), DType::F32);
        assert_eq!(v.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(v.as_i32().is_err());
        assert_eq!(v.scalar().unwrap(), 1.0);
        let s = Value::scalar_f32(3.5);
        assert!(s.shape().is_empty());
        assert_eq!(s.scalar().unwrap(), 3.5);
    }

    #[test]
    fn value_spec_matching() {
        let spec = IoSpec { name: "x".into(), shape: vec![2, 3], dtype: DType::F32 };
        assert!(Value::F32(vec![0.0; 6], vec![2, 3]).matches(&spec));
        assert!(!Value::F32(vec![0.0; 6], vec![3, 2]).matches(&spec));
        assert!(!Value::I32(vec![0; 6], vec![2, 3]).matches(&spec));
    }

    /// End-to-end artifact execution — the rust half of the interchange
    /// contract test (see python/tests/test_aot.py). Skipped when
    /// artifacts have not been built.
    #[test]
    fn executes_real_artifact_against_native_reference() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {dir:?}");
            return;
        }
        let manifest = Manifest::load(&dir).unwrap();
        let runtime = XlaRuntime::cpu().unwrap();

        let hmm = crate::hmm::gilbert_elliott(crate::hmm::GeParams::default());
        let mut rng = crate::rng::Xoshiro256StarStar::seed_from_u64(77);
        let t = 128usize;
        let tr = crate::hmm::sample(&hmm, t, &mut rng);
        let (pi, obs, prior) = hmm.to_f32_parts();
        let ys: Vec<i32> = tr.observations.iter().map(|&y| y as i32).collect();
        let valid = vec![1.0f32; t];

        let inputs = vec![
            Value::F32(pi, vec![4, 4]),
            Value::F32(obs, vec![4, 2]),
            Value::F32(prior, vec![4]),
            Value::I32(ys, vec![t]),
            Value::F32(valid, vec![t]),
        ];

        // Smoother artifact vs native sp_seq.
        let spec = manifest.find("sp_par", t, 4, 2).expect("sp_par artifact");
        let exe = runtime.compile(spec).unwrap();
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 2);
        let gamma = out[0].as_f32().unwrap();
        let loglik = out[1].scalar().unwrap();
        let native = crate::inference::sp_seq(&hmm, &tr.observations).unwrap();
        for k in 0..t {
            for s in 0..4 {
                let diff = (gamma[k * 4 + s] as f64 - native.gamma(k)[s]).abs();
                assert!(diff < 1e-4, "gamma[{k}][{s}] diff {diff}");
            }
        }
        assert!(
            (loglik - native.log_likelihood()).abs()
                < 1e-3 * native.log_likelihood().abs(),
            "loglik {loglik} vs {}",
            native.log_likelihood()
        );

        // Viterbi artifact vs native.
        let spec = manifest.find("viterbi", t, 4, 2).expect("viterbi artifact");
        let exe = runtime.compile(spec).unwrap();
        let out = exe.run(&inputs).unwrap();
        let path = out[0].as_i32().unwrap();
        let native = crate::inference::viterbi(&hmm, &tr.observations).unwrap();
        let same = path
            .iter()
            .zip(&native.path)
            .filter(|(&a, &b)| a as u32 == b)
            .count();
        assert!(same >= t - 2, "paths differ at {} positions", t - same);
        assert!((out[1].scalar().unwrap() - native.log_prob).abs() < 1e-3);

        // Input validation errors.
        assert!(exe.run(&inputs[..3]).is_err());
        let mut bad = inputs.clone();
        bad[0] = Value::F32(vec![0.0; 16], vec![16]);
        assert!(exe.run(&bad).is_err());
    }
}

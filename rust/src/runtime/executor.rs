//! Artifact-execution abstraction and input marshalling.
//!
//! `ArtifactExec` decouples "run this compiled artifact with these
//! inputs" from any particular worker-pool implementation: the
//! coordinator's `XlaPool` implements it over PJRT worker threads, the
//! engine's `XlaBackend` dispatches through it, and tests substitute
//! native mocks (`coordinator::sharder::NativeExec`).

use crate::error::Result;
use crate::hmm::Hmm;

use super::client::Value;

/// Abstraction over "run this artifact with these inputs" so callers
/// (sharder, engine backend) are independent of the worker-pool
/// implementation.
pub trait ArtifactExec {
    /// Run a single artifact call.
    fn run(&self, artifact: &str, inputs: Vec<Value>) -> Result<Vec<Value>>;

    /// Run many independent calls, preserving order of results.
    /// Implementations may execute them concurrently.
    fn run_many(&self, jobs: Vec<(String, Vec<Value>)>) -> Vec<Result<Vec<Value>>> {
        jobs.into_iter().map(|(a, i)| self.run(&a, i)).collect()
    }
}

/// Model + one block of observations → the artifact input list
/// (pi, obs, prior, ys padded to `capacity`, valid mask) — the exact
/// layout `python/compile/aot.py` compiles against.
pub fn marshal_block(hmm: &Hmm, ys: &[u32], capacity: usize) -> Vec<Value> {
    let (pi, obs, prior) = hmm.to_f32_parts();
    let d = hmm.num_states();
    let m = hmm.num_symbols();
    let mut ys_pad: Vec<i32> = ys.iter().map(|&y| y as i32).collect();
    ys_pad.resize(capacity, 0);
    let mut valid = vec![1.0f32; ys.len()];
    valid.resize(capacity, 0.0);
    vec![
        Value::F32(pi, vec![d, d]),
        Value::F32(obs, vec![d, m]),
        Value::F32(prior, vec![d]),
        Value::I32(ys_pad, vec![capacity]),
        Value::F32(valid, vec![capacity]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::{gilbert_elliott, GeParams};

    #[test]
    fn marshal_pads_to_capacity() {
        let hmm = gilbert_elliott(GeParams::default());
        let inputs = marshal_block(&hmm, &[0, 1, 1], 8);
        assert_eq!(inputs.len(), 5);
        assert_eq!(inputs[3].shape(), &[8]);
        assert_eq!(inputs[3].as_i32().unwrap(), &[0, 1, 1, 0, 0, 0, 0, 0]);
        assert_eq!(
            inputs[4].as_f32().unwrap(),
            &[1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]
        );
    }
}

//! Executable registry: lazy compilation + caching of artifacts.
//!
//! Compilation of an HLO module takes tens of milliseconds — far too
//! slow for the request path. The registry compiles each artifact at
//! most once (keyed by manifest name) and hands out shared handles;
//! workers run the same executable concurrently.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};

use super::client::{Executable, XlaRuntime};
use super::manifest::Manifest;

/// Default artifacts directory: `$HMM_SCAN_ARTIFACTS` or `artifacts/`
/// relative to the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("HMM_SCAN_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Walk up from the current dir so tests (running in target/…) and
    // the binary (running anywhere inside the repo) both resolve.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Thread-safe artifact registry.
pub struct Registry {
    runtime: XlaRuntime,
    manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl Registry {
    /// Open over an artifact directory: loads the manifest and builds
    /// the PJRT client.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let manifest = Manifest::load(dir.into())?;
        let runtime = XlaRuntime::cpu()?;
        Ok(Self { runtime, manifest, cache: Mutex::new(BTreeMap::new()) })
    }

    /// Open using [`artifacts_dir`] resolution.
    pub fn open_default() -> Result<Self> {
        Self::open(artifacts_dir())
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The PJRT runtime executables compile against.
    pub fn runtime(&self) -> &XlaRuntime {
        &self.runtime
    }

    /// Number of compiled (cached) executables.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Get (compiling on first use) the executable for `name`.
    pub fn get(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(exe));
        }
        // Compile outside the lock: compilation is slow and other
        // artifacts' lookups must not stall behind it. A racing double
        // compile of the same artifact is benign (last one wins).
        let spec = self
            .manifest
            .by_name(name)
            .ok_or_else(|| Error::artifact(format!("unknown artifact '{name}'")))?
            .clone();
        let exe = Arc::new(self.runtime.compile(&spec)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (used by `serve` startup).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_artifact_is_an_error() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {dir:?}");
            return;
        }
        let reg = Registry::open(dir).unwrap();
        assert!(reg.get("nope").is_err());
        assert_eq!(reg.compiled_count(), 0);
    }

    #[test]
    fn caches_compiled_executables() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {dir:?}");
            return;
        }
        let reg = Registry::open(dir).unwrap();
        let a = reg.get("sp_seq_T128_D4_M2").unwrap();
        let b = reg.get("sp_seq_T128_D4_M2").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.compiled_count(), 1);
        reg.warm(&["viterbi_T128_D4_M2"]).unwrap();
        assert_eq!(reg.compiled_count(), 2);
    }
}

//! Regeneration of every table and figure in the paper's §VI evaluation
//! (see DESIGN.md §4 for the experiment index and the GPU-substitution
//! note). Each function returns the data and writes CSV + ASCII plots
//! into the configured output directory; `cargo bench` targets and the
//! `hmm-scan figures` subcommand are thin wrappers.


use crate::benchx::{bench, BenchConfig, Measurement};
use crate::blockwise;
use crate::config::RunConfig;
use crate::engine::{Algorithm, Engine};
use crate::error::Result;
use crate::hmm::{gilbert_elliott, sample, Hmm};
use crate::inference::Posterior;
use crate::report::{ascii_plot, markdown_table, write_csv, PlotOptions, Series};
use crate::rng::Xoshiro256StarStar;
use crate::scan::ScanOptions;
use crate::simulator::{
    dag_parallel_smoother, dag_sequential, dag_viterbi, Device,
};

/// The seven benchmarked methods, in the paper's naming.
pub const METHODS: [&str; 7] =
    ["BS-Seq", "BS-Par", "SP-Seq", "SP-Par", "MP-Seq", "MP-Par", "Viterbi"];

/// Per-method relative cost factor for the simulator. The max-product
/// *combine* avoids the rescale division and the summation tree
/// (max-plus on the VPU), so MP-Par is cheaper per level than SP-Par —
/// which is why the paper's Fig. 6 shows the MP seq/par ratio (~6000 at
/// T=10⁵) well above SP/BS (~3000–4000): the discount applies to the
/// parallel pass, not the memory-bound sequential one. BS carries the
/// likelihood-vector bookkeeping on both sides.
fn method_cost_factor(method: &str) -> f64 {
    match method {
        "MP-Par" => 0.55,
        "MP-Seq" | "Viterbi" => 0.9,
        "BS-Seq" | "BS-Par" => 1.3,
        _ => 1.0,
    }
}

fn is_parallel(method: &str) -> bool {
    method.ends_with("Par")
}

/// Run one native method at length `t` through the unified engine;
/// returns the measured median. Dispatch is by the paper's method name
/// (`Algorithm::from_paper_name` — the taxonomy's single source of
/// truth), and repeated iterations reuse the engine's workspace exactly
/// as the serving hot path does.
fn run_method(
    method: &str,
    engine: &mut Engine,
    ys: &[u32],
    cfg: BenchConfig,
) -> Measurement {
    let alg = Algorithm::from_paper_name(method)
        .unwrap_or_else(|| panic!("unknown method {method}"));
    let name = format!("{method}/T={}", ys.len());
    bench(&name, cfg, || engine.run(alg, ys).unwrap())
}

fn workload(config: &RunConfig, t: usize) -> (Hmm, Vec<u32>) {
    let hmm = gilbert_elliott(config.ge);
    let mut rng = Xoshiro256StarStar::seed_from_u64(config.seed ^ t as u64);
    let tr = sample(&hmm, t, &mut rng);
    (hmm, tr.observations)
}

// ===========================================================================
// Fig. 2 — example GE states and measurements (T = 100)
// ===========================================================================

/// Regenerate Fig. 2: a sampled GE trajectory. Returns (plot, series).
pub fn fig2(config: &RunConfig) -> Result<String> {
    let hmm = gilbert_elliott(config.ge);
    let mut rng = Xoshiro256StarStar::seed_from_u64(config.seed);
    let tr = sample(&hmm, 100, &mut rng);
    let mut states = Series::new("state x_k");
    let mut meas = Series::new("measurement y_k");
    for (k, (&x, &y)) in tr.states.iter().zip(&tr.observations).enumerate() {
        states.push(k as f64, x as f64);
        meas.push(k as f64, y as f64 - 4.5); // offset like the paper's panel
    }
    let series = vec![states, meas];
    write_csv(config.out_dir.join("fig2.csv"), &series)?;
    let plot = ascii_plot(
        "Fig. 2 — Gilbert–Elliott states and measurements (T = 100)",
        &series,
        PlotOptions { log_x: false, log_y: false, width: 100, height: 14 },
    );
    std::fs::write(config.out_dir.join("fig2.txt"), &plot)?;
    Ok(plot)
}

// ===========================================================================
// Fig. 3 — measured CPU runtimes of all seven methods vs T
// ===========================================================================

/// Regenerate Fig. 3 on this machine's CPU. `quick` trims the grid for
/// CI-speed runs.
pub fn fig3(config: &RunConfig, quick: bool) -> Result<Vec<Series>> {
    let grid = effective_grid(config, quick);
    let scan = config.scan_options();
    let mut series: Vec<Series> = METHODS.iter().map(|m| Series::new(*m)).collect();
    for &t in &grid {
        let (hmm, ys) = workload(config, t);
        let mut engine = Engine::builder(hmm).scan_options(scan).build();
        let cfg = if t >= 30_000 { BenchConfig::heavy() } else { BenchConfig::default() };
        for (mi, method) in METHODS.iter().enumerate() {
            let m = run_method(method, &mut engine, &ys, cfg);
            series[mi].push(t as f64, m.median_secs());
        }
    }
    write_csv(config.out_dir.join("fig3.csv"), &series)?;
    let plot = ascii_plot(
        "Fig. 3 — average computation time on the CPU (measured)",
        &series,
        PlotOptions::default(),
    );
    std::fs::write(config.out_dir.join("fig3.txt"), &plot)?;

    // Companion: the paper's 24-core Threadripper simulated with the
    // work-span model (this testbed has a single core, so the measured
    // curves cannot show the multicore crossover — see EXPERIMENTS.md).
    let dev = Device::cpu_like(24, 2.0e-9);
    let mut sim: Vec<Series> =
        METHODS.iter().map(|m| Series::new(format!("{m}-sim24"))).collect();
    for &t in &config.t_grid {
        for (mi, method) in METHODS.iter().enumerate() {
            sim[mi].push(t as f64, simulate_method(method, t, 4, &dev));
        }
    }
    write_csv(config.out_dir.join("fig3_sim24.csv"), &sim)?;
    let plot = ascii_plot(
        "Fig. 3 (companion) — 24-core CPU, work-span simulated",
        &sim,
        PlotOptions::default(),
    );
    std::fs::write(config.out_dir.join("fig3_sim24.txt"), &plot)?;
    Ok(series)
}

// ===========================================================================
// Figs. 4/5/6 — simulated GPU (see DESIGN.md substitution note)
// ===========================================================================

/// Simulated runtime of one method at length `t` on `dev`.
pub fn simulate_method(method: &str, t: usize, d: usize, dev: &Device) -> f64 {
    let dag = match method {
        "Viterbi" => dag_viterbi(t),
        m if is_parallel(m) => dag_parallel_smoother(t),
        _ => dag_sequential(t),
    };
    dev.run(&dag, d) * method_cost_factor(method)
}

/// Regenerate Fig. 4: all seven methods on the simulated 3090-like GPU.
pub fn fig4(config: &RunConfig) -> Result<Vec<Series>> {
    let dev = Device::gpu_3090_default();
    let mut series: Vec<Series> = METHODS.iter().map(|m| Series::new(*m)).collect();
    for &t in &config.t_grid {
        for (mi, method) in METHODS.iter().enumerate() {
            series[mi].push(t as f64, simulate_method(method, t, 4, &dev));
        }
    }
    write_csv(config.out_dir.join("fig4.csv"), &series)?;
    let plot = ascii_plot(
        "Fig. 4 — computation time on the simulated GPU (work-span model)",
        &series,
        PlotOptions::default(),
    );
    std::fs::write(config.out_dir.join("fig4.txt"), &plot)?;
    Ok(series)
}

/// Regenerate Fig. 5: the parallel methods only, linear scale, with the
/// grid extended beyond 10⁵ to expose the core-saturation knee.
pub fn fig5(config: &RunConfig) -> Result<Vec<Series>> {
    let dev = Device::gpu_3090_default();
    let mut grid = config.t_grid.clone();
    if let Some(&max) = grid.last() {
        grid.push(max * 2);
        grid.push(max * 4);
    }
    let mut series: Vec<Series> = ["BS-Par", "SP-Par", "MP-Par"]
        .iter()
        .map(|m| Series::new(format!("{m}-GPU")))
        .collect();
    for &t in &grid {
        for (mi, method) in ["BS-Par", "SP-Par", "MP-Par"].iter().enumerate() {
            series[mi].push(t as f64, simulate_method(method, t, 4, &dev));
        }
    }
    write_csv(config.out_dir.join("fig5.csv"), &series)?;
    let plot = ascii_plot(
        "Fig. 5 — parallel methods on the simulated GPU (linear scale)",
        &series,
        PlotOptions { log_x: false, log_y: false, ..PlotOptions::default() },
    );
    std::fs::write(config.out_dir.join("fig5.txt"), &plot)?;
    Ok(series)
}

/// Regenerate Fig. 6: the seq/par speed-up ratio on the simulated GPU.
pub fn fig6(config: &RunConfig) -> Result<Vec<Series>> {
    let dev = Device::gpu_3090_default();
    let pairs =
        [("BS-Seq", "BS-Par", "BS"), ("SP-Seq", "SP-Par", "SP"), ("MP-Seq", "MP-Par", "MP")];
    let mut series: Vec<Series> =
        pairs.iter().map(|(_, _, n)| Series::new(format!("{n} ratio"))).collect();
    for &t in &config.t_grid {
        for (pi, (seq, par, _)) in pairs.iter().enumerate() {
            let r = simulate_method(seq, t, 4, &dev) / simulate_method(par, t, 4, &dev);
            series[pi].push(t as f64, r);
        }
    }
    write_csv(config.out_dir.join("fig6.csv"), &series)?;
    let plot = ascii_plot(
        "Fig. 6 — seq/par run-time ratio on the simulated GPU",
        &series,
        PlotOptions::default(),
    );
    std::fs::write(config.out_dir.join("fig6.txt"), &plot)?;
    Ok(series)
}

// ===========================================================================
// Table I analogue — our measured/simulated speedups
// ===========================================================================

/// The paper's Table I surveys prior GPU speedups; it is not re-runnable.
/// We emit the analogous table for *this* system: per method family, the
/// measured CPU speedup and the simulated-GPU speedup at the largest T.
pub fn table1(config: &RunConfig, quick: bool) -> Result<String> {
    let t = *effective_grid(config, quick).last().unwrap();
    let (hmm, ys) = workload(config, t);
    let d = hmm.num_states();
    let scan = config.scan_options();
    let mut engine = Engine::builder(hmm).scan_options(scan).build();
    let cfg = BenchConfig::heavy();
    let dev = Device::gpu_3090_default();

    let mut rows = Vec::new();
    for (seq, par, name) in
        [("BS-Seq", "BS-Par", "Bayesian smoother"),
         ("SP-Seq", "SP-Par", "Sum-product (fwd-bwd)"),
         ("MP-Seq", "MP-Par", "Max-product (Viterbi)")]
    {
        let ms = run_method(seq, &mut engine, &ys, cfg).median_secs();
        let mp = run_method(par, &mut engine, &ys, cfg).median_secs();
        let sim =
            simulate_method(seq, t, 4, &dev) / simulate_method(par, t, 4, &dev);
        rows.push(vec![
            name.to_string(),
            format!("{d}"),
            format!("{t}"),
            format!("{:.2}x", ms / mp),
            format!("{sim:.0}x"),
        ]);
    }
    let table = markdown_table(
        &["Algorithm", "States", "Observations", "CPU speedup (measured)",
          "GPU speedup (simulated)"],
        &rows,
    );
    std::fs::create_dir_all(&config.out_dir)?;
    std::fs::write(config.out_dir.join("table1.md"), &table)?;
    Ok(table)
}

// ===========================================================================
// §VI equivalence report (the paper's ≤ 1e-16 MAE claim)
// ===========================================================================

/// Numerical equivalence of parallel vs sequential methods on the GE
/// workload: max-abs marginal difference and MAP logprob differences.
pub fn equivalence_report(config: &RunConfig, quick: bool) -> Result<String> {
    let t = if quick { 1000 } else { 10_000 };
    let (hmm, ys) = workload(config, t);
    let scan = config.scan_options();
    let mut engine = Engine::builder(hmm).scan_options(scan).build();

    let sp_seq = engine.run(Algorithm::SpSeq, &ys)?.into_posterior()?;
    let sp_par = engine.run(Algorithm::SpPar, &ys)?.into_posterior()?;
    let bs_seq = engine.run(Algorithm::BsSeq, &ys)?.into_posterior()?;
    let bs_par = engine.run(Algorithm::BsPar, &ys)?.into_posterior()?;
    let bw =
        blockwise::sp_blockwise(engine.hmm(), &ys, config.block_len, config.threads)?;

    let mae = |a: &Posterior, b: &Posterior| {
        a.gamma_flat()
            .iter()
            .zip(b.gamma_flat())
            .fold(0.0f64, |m, (&x, &y)| m.max((x - y).abs()))
    };
    let vit = engine.run(Algorithm::Viterbi, &ys)?.into_map()?;
    let mp_seq = engine.run(Algorithm::MpSeq, &ys)?.into_map()?;
    let mp_par = engine.run(Algorithm::MpPar, &ys)?.into_map()?;

    let rows = vec![
        vec!["SP-Par vs SP-Seq (max abs dgamma)".into(), format!("{:.2e}", mae(&sp_par, &sp_seq))],
        vec!["BS-Par vs SP-Seq (max abs dgamma)".into(), format!("{:.2e}", mae(&bs_par, &sp_seq))],
        vec!["BS-Seq vs SP-Seq (max abs dgamma)".into(), format!("{:.2e}", mae(&bs_seq, &sp_seq))],
        vec!["SP-Blockwise vs SP-Seq (max abs dgamma)".into(), format!("{:.2e}", mae(&bw, &sp_seq))],
        vec!["MP-Par vs Viterbi (abs dlogp)".into(),
             format!("{:.2e}", (mp_par.log_prob - vit.log_prob).abs())],
        vec!["MP-Seq vs Viterbi (abs dlogp)".into(),
             format!("{:.2e}", (mp_seq.log_prob - vit.log_prob).abs())],
    ];
    let table = markdown_table(&[&format!("Comparison (GE, T={t})"), "value"], &rows);
    std::fs::create_dir_all(&config.out_dir)?;
    std::fs::write(config.out_dir.join("equivalence.md"), &table)?;
    Ok(table)
}

// ===========================================================================
// Ablations (DESIGN.md design-choice benches)
// ===========================================================================

/// Block-length ablation for the §V-B block-wise smoother.
pub fn ablation_block_len(config: &RunConfig, quick: bool) -> Result<Vec<Series>> {
    let t = if quick { 4096 } else { 65_536 };
    let (hmm, ys) = workload(config, t);
    let mut s = Series::new(format!("SP-Blockwise T={t}"));
    let blocks: &[usize] = if quick {
        &[64, 256, 1024, 4096]
    } else {
        &[64, 256, 1024, 4096, 16_384, 65_536]
    };
    for &b in blocks {
        let m = bench(
            &format!("block={b}"),
            BenchConfig::heavy(),
            || blockwise::sp_blockwise(&hmm, &ys, b, config.threads).unwrap(),
        );
        s.push(b as f64, m.median_secs());
    }
    let series = vec![s];
    write_csv(config.out_dir.join("ablation_block.csv"), &series)?;
    Ok(series)
}

/// Thread-count ablation for the native parallel scan.
pub fn ablation_threads(config: &RunConfig, quick: bool) -> Result<Vec<Series>> {
    let t = if quick { 8192 } else { 100_000 };
    let (hmm, ys) = workload(config, t);
    let mut s = Series::new(format!("SP-Par T={t}"));
    for threads in [1usize, 2, 4, 8, 16] {
        if threads > 2 * crate::exec::default_parallelism() {
            break;
        }
        let scan = ScanOptions { threads, ..ScanOptions::default() };
        let mut engine = Engine::builder(hmm.clone()).scan_options(scan).build();
        let m = bench(
            &format!("threads={threads}"),
            BenchConfig::heavy(),
            || engine.run(Algorithm::SpPar, &ys).unwrap(),
        );
        s.push(threads as f64, m.median_secs());
    }
    let series = vec![s];
    write_csv(config.out_dir.join("ablation_threads.csv"), &series)?;
    Ok(series)
}

fn effective_grid(config: &RunConfig, quick: bool) -> Vec<usize> {
    if quick {
        config.t_grid.iter().copied().filter(|&t| t <= 10_000).collect()
    } else {
        config.t_grid.clone()
    }
}

/// Pretty-print one Measurement row (used by the bench binaries).
pub fn print_measurement(m: &Measurement) {
    println!(
        "  {:<24} median {:>10}  mad {:>9}  ({} iters)",
        m.name,
        crate::benchx::fmt_duration(m.median),
        crate::benchx::fmt_duration(m.mad),
        m.iters
    );
}

/// Convenience for benches: run everything quick and return a summary.
pub fn run_all(config: &RunConfig, quick: bool) -> Result<String> {
    std::fs::create_dir_all(&config.out_dir)?;
    let mut out = String::new();
    out.push_str(&fig2(config)?);
    fig3(config, quick)?;
    fig4(config)?;
    fig5(config)?;
    fig6(config)?;
    out.push_str(&table1(config, quick)?);
    out.push_str(&equivalence_report(config, quick)?);
    ablation_block_len(config, quick)?;
    ablation_threads(config, quick)?;
    // provenance
    std::fs::write(
        config.out_dir.join("config.json"),
        config.to_json().to_string_pretty(),
    )?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_config() -> RunConfig {
        RunConfig {
            t_grid: vec![100, 300],
            out_dir: std::env::temp_dir().join("hmm_scan_experiments_test"),
            threads: 2,
            ..RunConfig::default()
        }
    }

    #[test]
    fn fig2_writes_outputs() {
        let c = quick_config();
        std::fs::create_dir_all(&c.out_dir).unwrap();
        let plot = fig2(&c).unwrap();
        assert!(plot.contains("Fig. 2"));
        assert!(c.out_dir.join("fig2.csv").exists());
    }

    #[test]
    fn fig3_measures_all_methods() {
        let c = quick_config();
        std::fs::create_dir_all(&c.out_dir).unwrap();
        let series = fig3(&c, true).unwrap();
        assert_eq!(series.len(), 7);
        for s in &series {
            assert_eq!(s.points.len(), 2);
            assert!(s.points.iter().all(|&(_, y)| y > 0.0));
        }
    }

    #[test]
    fn fig4_to_6_shapes() {
        let c = quick_config();
        std::fs::create_dir_all(&c.out_dir).unwrap();
        let f4 = fig4(&c).unwrap();
        assert_eq!(f4.len(), 7);
        let f5 = fig5(&c).unwrap();
        assert_eq!(f5.len(), 3);
        let f6 = fig6(&c).unwrap();
        assert_eq!(f6.len(), 3);
        // parallel beats sequential in the simulation at every T
        for (pi, (seq, par)) in
            [("BS-Seq", "BS-Par"), ("SP-Seq", "SP-Par")].iter().enumerate()
        {
            let si = METHODS.iter().position(|m| m == seq).unwrap();
            let qi = METHODS.iter().position(|m| m == par).unwrap();
            for (a, b) in f4[si].points.iter().zip(&f4[qi].points) {
                assert!(a.1 > b.1, "{seq} {a:?} !> {par} {b:?} ({pi})");
            }
        }
        // ratios exceed 1 and grow with T
        for s in &f6 {
            assert!(s.points.first().unwrap().1 > 1.0);
            assert!(s.points.last().unwrap().1 > s.points.first().unwrap().1);
        }
    }

    #[test]
    fn equivalence_is_tight() {
        let c = quick_config();
        std::fs::create_dir_all(&c.out_dir).unwrap();
        let report = equivalence_report(&c, true).unwrap();
        assert!(report.contains("SP-Par vs SP-Seq"));
        // all reported deltas parse and are small
        for line in report.lines().skip(2) {
            let v = line.split('|').nth(2).unwrap().trim();
            let x: f64 = v.parse().unwrap();
            assert!(x < 1e-8, "equivalence violated: {line}");
        }
    }
}

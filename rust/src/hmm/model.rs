//! The HMM parameter container (Eq. 4a/4b + prior).

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// A discrete HMM with `d` hidden states and `m` observation symbols:
///
/// * transition `pi[i, j] = p(x_k = j | x_{k-1} = i)` (row-stochastic),
/// * emission `obs[i, y] = p(y_k = y | x_k = i)` (row-stochastic),
/// * prior `p(x_1)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Hmm {
    pi: Mat,
    obs: Mat,
    prior: Vec<f64>,
}

impl Hmm {
    /// Validates stochasticity (rows sum to 1 within `1e-9`) and shapes.
    pub fn new(pi: Mat, obs: Mat, prior: Vec<f64>) -> Result<Self> {
        let d = pi.rows();
        if pi.cols() != d {
            return Err(Error::invalid_model("transition matrix must be square"));
        }
        if obs.rows() != d {
            return Err(Error::invalid_model(format!(
                "emission rows ({}) != number of states ({d})",
                obs.rows()
            )));
        }
        if prior.len() != d {
            return Err(Error::invalid_model(format!(
                "prior length ({}) != number of states ({d})",
                prior.len()
            )));
        }
        if d == 0 || obs.cols() == 0 {
            return Err(Error::invalid_model("empty state/observation space"));
        }
        check_stochastic("transition", d, |r| pi.row(r))?;
        check_stochastic("emission", d, |r| obs.row(r))?;
        check_row("prior", &prior)?;
        Ok(Self { pi, obs, prior })
    }

    /// Number of hidden states D.
    pub fn num_states(&self) -> usize {
        self.pi.rows()
    }

    /// Number of observation symbols M.
    pub fn num_symbols(&self) -> usize {
        self.obs.cols()
    }

    /// Transition matrix Π (D×D, rows sum to 1).
    pub fn transition(&self) -> &Mat {
        &self.pi
    }

    /// Emission matrix O (D×M, rows sum to 1).
    pub fn emission(&self) -> &Mat {
        &self.obs
    }

    /// Prior distribution over the initial state (length D).
    pub fn prior(&self) -> &[f64] {
        &self.prior
    }

    /// Emission column e_k[j] = p(y_k | x_k = j) for observation `y`.
    pub fn emission_col(&self, y: u32) -> Vec<f64> {
        self.obs.col(y as usize)
    }

    /// Validate an observation sequence against the symbol alphabet.
    pub fn check_observations(&self, ys: &[u32]) -> Result<()> {
        if ys.is_empty() {
            return Err(Error::invalid_request("empty observation sequence"));
        }
        let m = self.num_symbols() as u32;
        if let Some(&bad) = ys.iter().find(|&&y| y >= m) {
            return Err(Error::invalid_request(format!(
                "observation symbol {bad} out of range (M = {m})"
            )));
        }
        Ok(())
    }

    /// Flat f32 buffers in the exact layout the PJRT artifacts expect.
    pub fn to_f32_parts(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let pi = self.pi.data().iter().map(|&v| v as f32).collect();
        let obs = self.obs.data().iter().map(|&v| v as f32).collect();
        let prior = self.prior.iter().map(|&v| v as f32).collect();
        (pi, obs, prior)
    }
}

fn check_stochastic<'a>(
    what: &str,
    rows: usize,
    row: impl Fn(usize) -> &'a [f64],
) -> Result<()> {
    for r in 0..rows {
        check_row(&format!("{what} row {r}"), row(r))?;
    }
    Ok(())
}

fn check_row(what: &str, row: &[f64]) -> Result<()> {
    if row.iter().any(|&v| !(0.0..=1.0 + 1e-12).contains(&v)) {
        return Err(Error::invalid_model(format!(
            "{what} has entries outside [0, 1]"
        )));
    }
    let s: f64 = row.iter().sum();
    if (s - 1.0).abs() > 1e-9 {
        return Err(Error::invalid_model(format!(
            "{what} sums to {s}, expected 1"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple() -> Hmm {
        Hmm::new(
            Mat::from_vec(2, 2, vec![0.9, 0.1, 0.2, 0.8]),
            Mat::from_vec(2, 3, vec![0.5, 0.25, 0.25, 0.1, 0.2, 0.7]),
            vec![0.5, 0.5],
        )
        .unwrap()
    }

    #[test]
    fn valid_model_accepted() {
        let h = simple();
        assert_eq!(h.num_states(), 2);
        assert_eq!(h.num_symbols(), 3);
        assert_eq!(h.emission_col(2), vec![0.25, 0.7]);
    }

    #[test]
    fn rejects_non_square_transition() {
        let e = Hmm::new(
            Mat::from_vec(2, 3, vec![0.5; 6]),
            Mat::from_vec(2, 2, vec![0.5; 4]),
            vec![0.5, 0.5],
        );
        assert!(e.is_err());
    }

    #[test]
    fn rejects_non_stochastic_rows() {
        let e = Hmm::new(
            Mat::from_vec(2, 2, vec![0.9, 0.2, 0.2, 0.8]),
            Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]),
            vec![0.5, 0.5],
        );
        assert!(e.is_err());
        let e = Hmm::new(
            Mat::from_vec(2, 2, vec![0.9, 0.1, 0.2, 0.8]),
            Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]),
            vec![0.9, 0.2],
        );
        assert!(e.is_err());
    }

    #[test]
    fn rejects_negative_entries() {
        let e = Hmm::new(
            Mat::from_vec(2, 2, vec![1.1, -0.1, 0.2, 0.8]),
            Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]),
            vec![0.5, 0.5],
        );
        assert!(e.is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let e = Hmm::new(
            Mat::from_vec(2, 2, vec![0.9, 0.1, 0.2, 0.8]),
            Mat::from_vec(3, 2, vec![0.5; 6]),
            vec![0.5, 0.5],
        );
        assert!(e.is_err());
    }

    #[test]
    fn observation_validation() {
        let h = simple();
        assert!(h.check_observations(&[0, 1, 2]).is_ok());
        assert!(h.check_observations(&[]).is_err());
        assert!(h.check_observations(&[0, 3]).is_err());
    }

    #[test]
    fn f32_parts_layout() {
        let h = simple();
        let (pi, obs, prior) = h.to_f32_parts();
        assert_eq!(pi.len(), 4);
        assert_eq!(obs.len(), 6);
        assert_eq!(prior, vec![0.5f32, 0.5f32]);
        assert!((pi[1] - 0.1).abs() < 1e-7);
    }
}

//! Hidden Markov model definition, validation, sampling, and the paper's
//! Gilbert–Elliott channel workload (§VI, Eq. 43).

mod gilbert_elliott;
mod model;
mod sample;

pub use gilbert_elliott::{bit_of_state, gilbert_elliott, regime_of_state, GeParams};
pub use model::Hmm;
pub use sample::{sample, Trajectory};

//! The Gilbert–Elliott channel model (paper §VI, Eq. 43).
//!
//! Two binary hidden processes — the transmitted bit b_k (switch
//! probability p₂) and the channel regime s_k (good↔bad with p₀/p₁) —
//! observed through y_k = b_k ⊕ v_k where v_k is Bernoulli with error
//! rate q₀ (good regime) or q₁ (bad). The joint x_k = (s_k, b_k) is a
//! D = 4 Markov chain over states {(0,0), (0,1), (1,0), (1,1)} encoded
//! 0..3, with M = 2 observation symbols.

use crate::linalg::Mat;

use super::Hmm;

/// GE channel parameters; `Default` is the paper's experimental setting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeParams {
    /// p(bad → good) regime transition.
    pub p0: f64,
    /// p(good → bad) regime transition.
    pub p1: f64,
    /// Bit switch probability of b_k.
    pub p2: f64,
    /// Error rate in the good regime.
    pub q0: f64,
    /// Error rate in the bad regime.
    pub q1: f64,
}

impl Default for GeParams {
    fn default() -> Self {
        // §VI: p0 = 0.03, p1 = 0.1, p2 = 0.05, q0 = 0.01, q1 = 0.1.
        Self { p0: 0.03, p1: 0.1, p2: 0.05, q0: 0.01, q1: 0.1 }
    }
}

/// Build the 4-state GE joint HMM of Eq. (43) with a uniform prior.
pub fn gilbert_elliott(p: GeParams) -> Hmm {
    let GeParams { p0, p1, p2, q0, q1 } = p;
    #[rustfmt::skip]
    let pi = Mat::from_vec(4, 4, vec![
        (1.0 - p0) * (1.0 - p2), p0 * (1.0 - p2),         (1.0 - p0) * p2,         p0 * p2,
        p1 * (1.0 - p2),         (1.0 - p1) * (1.0 - p2), p1 * p2,                 (1.0 - p1) * p2,
        (1.0 - p0) * p2,         p0 * p2,                 (1.0 - p0) * (1.0 - p2), p0 * (1.0 - p2),
        p1 * p2,                 (1.0 - p1) * p2,         p1 * (1.0 - p2),         (1.0 - p1) * (1.0 - p2),
    ]);
    #[rustfmt::skip]
    let obs = Mat::from_vec(4, 2, vec![
        1.0 - q0, q0,
        1.0 - q1, q1,
        q0,       1.0 - q0,
        q1,       1.0 - q1,
    ]);
    Hmm::new(pi, obs, vec![0.25; 4]).expect("GE construction is always valid")
}

/// Transmitted bit encoded in joint state `x` (states 2, 3 carry b = 1).
pub fn bit_of_state(x: usize) -> u32 {
    (x >= 2) as u32
}

/// Channel regime encoded in joint state `x` (states 1, 3 are the bad
/// regime s = 1).
pub fn regime_of_state(x: usize) -> u32 {
    (x % 2 == 1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_values() {
        let h = gilbert_elliott(GeParams::default());
        assert_eq!(h.num_states(), 4);
        assert_eq!(h.num_symbols(), 2);
        let pi = h.transition();
        // Row 0: (1-p0)(1-p2) = 0.97*0.95
        assert!((pi[(0, 0)] - 0.97 * 0.95).abs() < 1e-12);
        assert!((pi[(0, 1)] - 0.03 * 0.95).abs() < 1e-12);
        assert!((pi[(0, 2)] - 0.97 * 0.05).abs() < 1e-12);
        assert!((pi[(0, 3)] - 0.03 * 0.05).abs() < 1e-12);
        let o = h.emission();
        assert!((o[(0, 0)] - 0.99).abs() < 1e-12);
        assert!((o[(1, 1)] - 0.1).abs() < 1e-12);
        assert!((o[(2, 0)] - 0.01).abs() < 1e-12);
        assert_eq!(h.prior(), &[0.25; 4]);
    }

    #[test]
    fn rows_stochastic_for_random_params() {
        let mut runner = crate::proptestx::Runner::new("ge-stochastic");
        runner.run(50, |r| {
            let p = GeParams {
                p0: r.uniform(0.0, 1.0),
                p1: r.uniform(0.0, 1.0),
                p2: r.uniform(0.0, 1.0),
                q0: r.uniform(0.0, 1.0),
                q1: r.uniform(0.0, 1.0),
            };
            let h = gilbert_elliott(p); // Hmm::new validates internally
            assert_eq!(h.num_states(), 4);
        });
    }

    #[test]
    fn state_encoding() {
        assert_eq!(bit_of_state(0), 0);
        assert_eq!(bit_of_state(1), 0);
        assert_eq!(bit_of_state(2), 1);
        assert_eq!(bit_of_state(3), 1);
        assert_eq!(regime_of_state(0), 0);
        assert_eq!(regime_of_state(1), 1);
        assert_eq!(regime_of_state(2), 0);
        assert_eq!(regime_of_state(3), 1);
    }
}

//! Ancestral sampling of (state, observation) trajectories.

use crate::rng::Xoshiro256StarStar;

use super::Hmm;

/// A sampled trajectory: hidden states and the observations they emitted.
#[derive(Debug, Clone, PartialEq)]
pub struct Trajectory {
    /// Hidden state sequence x_{1:T}.
    pub states: Vec<u32>,
    /// Emitted observation sequence y_{1:T}.
    pub observations: Vec<u32>,
}

/// Draw a length-`t` trajectory from the model.
pub fn sample(hmm: &Hmm, t: usize, rng: &mut Xoshiro256StarStar) -> Trajectory {
    let mut states = Vec::with_capacity(t);
    let mut observations = Vec::with_capacity(t);
    if t == 0 {
        return Trajectory { states, observations };
    }
    let mut x = rng.categorical(hmm.prior());
    for k in 0..t {
        if k > 0 {
            x = rng.categorical(hmm.transition().row(x));
        }
        let y = rng.categorical(hmm.emission().row(x));
        states.push(x as u32);
        observations.push(y as u32);
    }
    Trajectory { states, observations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::{gilbert_elliott, GeParams};
    use crate::linalg::Mat;

    #[test]
    fn lengths_and_ranges() {
        let h = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(1);
        let tr = sample(&h, 500, &mut rng);
        assert_eq!(tr.states.len(), 500);
        assert_eq!(tr.observations.len(), 500);
        assert!(tr.states.iter().all(|&x| x < 4));
        assert!(tr.observations.iter().all(|&y| y < 2));
    }

    #[test]
    fn empty_trajectory() {
        let h = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(1);
        let tr = sample(&h, 0, &mut rng);
        assert!(tr.states.is_empty() && tr.observations.is_empty());
    }

    #[test]
    fn deterministic_under_seed() {
        let h = gilbert_elliott(GeParams::default());
        let a = sample(&h, 100, &mut Xoshiro256StarStar::seed_from_u64(9));
        let b = sample(&h, 100, &mut Xoshiro256StarStar::seed_from_u64(9));
        assert_eq!(a, b);
    }

    #[test]
    fn stationary_frequencies_roughly_match() {
        // A chain that strongly prefers state 1 must show that in the
        // empirical state frequencies.
        let h = crate::hmm::Hmm::new(
            Mat::from_vec(2, 2, vec![0.1, 0.9, 0.1, 0.9]),
            Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]),
            vec![0.5, 0.5],
        )
        .unwrap();
        let mut rng = Xoshiro256StarStar::seed_from_u64(2);
        let tr = sample(&h, 20_000, &mut rng);
        let ones = tr.states.iter().filter(|&&x| x == 1).count() as f64;
        let frac = ones / tr.states.len() as f64;
        assert!((frac - 0.9).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn emissions_follow_state_rows() {
        // Deterministic emissions: y must equal the state.
        let h = crate::hmm::Hmm::new(
            Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]),
            Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]),
            vec![0.5, 0.5],
        )
        .unwrap();
        let mut rng = Xoshiro256StarStar::seed_from_u64(3);
        let tr = sample(&h, 1000, &mut rng);
        assert!(tr.states.iter().zip(&tr.observations).all(|(&x, &y)| x == y));
    }
}

//! Block-wise (two-level) inference — the paper's §V-B extension.
//!
//! A block of `l` consecutive elements is folded into a single summary
//! element by one "computational element" (sequentially); the small
//! sequence of B = ⌈T/l⌉ summaries is prefix/suffix-combined; each block
//! is then finalized with its incoming forward prefix and backward
//! suffix. This is the schedule to use when cores ≪ T — and it is the
//! exact protocol the coordinator's temporal sharder executes over PJRT
//! workers (each fold/finalize becomes one artifact call).
//!
//! The native implementation here serves three purposes: the CPU
//! block-wise baseline for the ablation benches, the reference the
//! sharded PJRT path is tested against, and documentation-by-code of the
//! §V-B algebra.

use crate::elements::{
    mp_element_chain, mp_terminal, sp_element_chain, sp_terminal, MpElement,
    MpOp, SpElement, SpOp,
};
use crate::error::Result;
use crate::exec::parallel_for_chunks;
use crate::hmm::Hmm;
use crate::inference::{MapEstimate, Posterior};
use crate::linalg::{argmax, normalize_sum};
use crate::scan::{seq_scan, seq_scan_rev, AssocOp};

/// Partition of `0..t` into blocks of length `block_len` (last may be
/// short).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockPlan {
    /// Total sequence length.
    pub t: usize,
    /// Observations per block.
    pub block_len: usize,
}

impl BlockPlan {
    /// A plan over `0..t` with blocks of `block_len` (≥ 1).
    pub fn new(t: usize, block_len: usize) -> Self {
        Self { t, block_len: block_len.max(1) }
    }

    /// Number of blocks (the last may be short).
    pub fn num_blocks(&self) -> usize {
        self.t.div_ceil(self.block_len)
    }

    /// Half-open range of block `b`.
    pub fn range(&self, b: usize) -> (usize, usize) {
        let start = b * self.block_len;
        (start, (start + self.block_len).min(self.t))
    }

    /// Block ranges partition `0..t` exactly (invariant; property-tested).
    pub fn is_partition(&self) -> bool {
        let mut expect = 0;
        for b in 0..self.num_blocks() {
            let (s, e) = self.range(b);
            if s != expect || e <= s || e > self.t {
                return false;
            }
            expect = e;
        }
        expect == self.t
    }
}

/// Generic §V-B two-level summary computation: per-block folds, then the
/// exclusive prefix and suffix combinations of the summaries at the
/// leader. Returns (incoming-prefix, incoming-suffix) per block, where
/// suffix for block b already includes `terminal`.
pub fn block_summaries<E, Op>(
    op: &Op,
    elems: &[E],
    plan: &BlockPlan,
    terminal: E,
    threads: usize,
) -> (Vec<E>, Vec<E>)
where
    E: Clone + Send + Sync,
    Op: AssocOp<E>,
{
    let nb = plan.num_blocks();
    let mut folds: Vec<E> = vec![op.identity(); nb];
    {
        let out = crate::exec::SharedSliceMut::new(&mut folds);
        parallel_for_chunks(nb, threads, |_, lo, hi| {
            for b in lo..hi {
                let (s, e) = plan.range(b);
                let mut acc = elems[s].clone();
                for x in &elems[s + 1..e] {
                    acc = op.combine(&acc, x);
                }
                // SAFETY: block b written by exactly one chunk.
                unsafe { out.write(b, acc) };
            }
        });
    }

    // Leader-side exclusive prefix (a_{0:s_b}) and suffix (a_{e_b:T+1}).
    let mut prefixes = Vec::with_capacity(nb);
    let mut acc = op.identity();
    for f in &folds {
        prefixes.push(acc.clone());
        acc = op.combine(&acc, f);
    }
    let mut suffixes = vec![op.identity(); nb];
    let mut acc = terminal;
    for b in (0..nb).rev() {
        suffixes[b] = acc.clone();
        acc = op.combine(&folds[b], &acc);
    }
    (prefixes, suffixes)
}

/// SP-Blockwise — two-level parallel sum-product smoother (§V-B).
pub fn sp_blockwise(
    hmm: &Hmm,
    ys: &[u32],
    block_len: usize,
    threads: usize,
) -> Result<Posterior> {
    hmm.check_observations(ys)?;
    let d = hmm.num_states();
    let t = ys.len();
    let op = SpOp { d };
    let plan = BlockPlan::new(t, block_len);
    let elems = sp_element_chain(hmm, ys);

    // Backward chain elements: ψ_{k,k+1} for k=1..T-1 (shifted) — the
    // suffix summaries must be built over the *shifted* chain, so fold
    // those separately.
    let mut bwd_elems: Vec<SpElement> = elems[1..].to_vec();
    bwd_elems.push(sp_terminal(d));

    let (fwd_in, _) = block_summaries(&op, &elems, &plan, sp_terminal(d), threads);
    let (_, bwd_in) = block_summaries(&op, &bwd_elems, &plan, op.identity(), threads);
    // Note: bwd chain's own terminal ψ_{T,T+1} is already the last
    // element of `bwd_elems`, so the leader suffix uses the identity as
    // its terminal.

    let nb = plan.num_blocks();
    let mut gamma = vec![0.0f64; t * d];
    let mut loglik_parts = vec![0.0f64; 1];
    {
        let out = crate::exec::SharedSliceMut::new(&mut gamma);
        let ll = crate::exec::SharedSliceMut::new(&mut loglik_parts);
        parallel_for_chunks(nb, threads, |_, lo, hi| {
            for b in lo..hi {
                let (s, e) = plan.range(b);
                // Within-block forward prefixes and (shifted) suffixes.
                let pref = seq_scan(&op, &elems[s..e]);
                let suf = seq_scan_rev(&op, &bwd_elems[s..e]);
                for k in s..e {
                    // global fwd = fwd_in[b] ⊗ pref[k-s]
                    let gf = op.combine(&fwd_in[b], &pref[k - s]);
                    // global bwd = suf[k-s] ⊗ bwd_in[b]
                    let gb = op.combine(&suf[k - s], &bwd_in[b]);
                    // SAFETY: step k belongs to exactly one block.
                    let g = unsafe { out.range_mut(k * d, (k + 1) * d) };
                    for st in 0..d {
                        g[st] = gf.mat[(0, st)] * gb.mat[(st, 0)];
                    }
                    normalize_sum(g);
                    if k == plan.t - 1 {
                        let total =
                            gf.mat.row(0).iter().sum::<f64>().max(f64::MIN_POSITIVE);
                        // SAFETY: only the owner of the last block writes.
                        unsafe { ll.write(0, gf.log_scale + total.ln()) };
                    }
                }
            }
        });
    }

    Ok(Posterior::new(d, gamma, loglik_parts[0]))
}

/// MP-Blockwise — two-level parallel max-product MAP (§V-B).
pub fn mp_blockwise(
    hmm: &Hmm,
    ys: &[u32],
    block_len: usize,
    threads: usize,
) -> Result<MapEstimate> {
    hmm.check_observations(ys)?;
    let d = hmm.num_states();
    let t = ys.len();
    let op = MpOp { d };
    let plan = BlockPlan::new(t, block_len);
    let elems = mp_element_chain(hmm, ys);

    let mut bwd_elems: Vec<MpElement> = elems[1..].to_vec();
    bwd_elems.push(mp_terminal(d));

    let (fwd_in, _) = block_summaries(&op, &elems, &plan, mp_terminal(d), threads);
    let (_, bwd_in) = block_summaries(&op, &bwd_elems, &plan, op.identity(), threads);

    let nb = plan.num_blocks();
    let mut path = vec![0u32; t];
    let mut logp_parts = vec![f64::NEG_INFINITY; 1];
    {
        let out = crate::exec::SharedSliceMut::new(&mut path);
        let lp = crate::exec::SharedSliceMut::new(&mut logp_parts);
        parallel_for_chunks(nb, threads, |_, lo, hi| {
            for b in lo..hi {
                let (s, e) = plan.range(b);
                let pref = seq_scan(&op, &elems[s..e]);
                let suf = seq_scan_rev(&op, &bwd_elems[s..e]);
                for k in s..e {
                    let gf = op.combine(&fwd_in[b], &pref[k - s]);
                    let gb = op.combine(&suf[k - s], &bwd_in[b]);
                    let delta: Vec<f64> =
                        (0..d).map(|st| gf.mat[(0, st)] + gb.mat[(st, 0)]).collect();
                    // SAFETY: step k belongs to exactly one block.
                    unsafe { out.write(k, argmax(&delta) as u32) };
                    if k == plan.t - 1 {
                        let best = gf
                            .mat
                            .row(0)
                            .iter()
                            .fold(f64::NEG_INFINITY, |m, &v| m.max(v));
                        unsafe { lp.write(0, best) };
                    }
                }
            }
        });
    }

    Ok(MapEstimate { path, log_prob: logp_parts[0] })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::{gilbert_elliott, sample, GeParams};
    use crate::inference::{sp_seq, viterbi};
    use crate::proptestx::Runner;
    use crate::rng::Xoshiro256StarStar;

    #[test]
    fn block_plan_partitions() {
        let mut runner = Runner::new("blockplan-partition");
        runner.run(100, |r| {
            let t = 1 + r.below(5000) as usize;
            let l = 1 + r.below(300) as usize;
            let plan = BlockPlan::new(t, l);
            assert!(plan.is_partition(), "t={t} l={l}");
            assert_eq!(plan.num_blocks(), t.div_ceil(l));
        });
    }

    #[test]
    fn sp_blockwise_equals_flat() {
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(31);
        let tr = sample(&hmm, 500, &mut rng);
        let flat = sp_seq(&hmm, &tr.observations).unwrap();
        for block in [1usize, 7, 64, 100, 500, 1000] {
            let two = sp_blockwise(&hmm, &tr.observations, block, 4).unwrap();
            assert!(
                (two.log_likelihood() - flat.log_likelihood()).abs() < 1e-9,
                "loglik block={block}"
            );
            for k in 0..500 {
                for s in 0..4 {
                    assert!(
                        (two.gamma(k)[s] - flat.gamma(k)[s]).abs() < 1e-9,
                        "gamma[{k}][{s}] block={block}"
                    );
                }
            }
        }
    }

    #[test]
    fn mp_blockwise_equals_viterbi_logprob() {
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(32);
        let tr = sample(&hmm, 400, &mut rng);
        let vit = viterbi(&hmm, &tr.observations).unwrap();
        for block in [3usize, 50, 128, 400] {
            let two = mp_blockwise(&hmm, &tr.observations, block, 4).unwrap();
            assert!(
                (two.log_prob - vit.log_prob).abs() < 1e-9,
                "logp block={block}"
            );
            // Path may differ from backtrace only at exact ties; verify
            // every state attains the optimum by re-scoring through the
            // δ oracle in the inference tests — here check length/range.
            assert_eq!(two.path.len(), 400);
            assert!(two.path.iter().all(|&s| s < 4));
        }
    }

    #[test]
    fn blockwise_random_models_property() {
        let mut runner = Runner::new("blockwise-random");
        runner.run(6, |r| {
            use crate::proptestx::gen;
            let d = 2 + r.below(4) as usize;
            let m = 2 + r.below(3) as usize;
            let t = 5 + r.below(150) as usize;
            let block = 1 + r.below(40) as usize;
            let pi = crate::linalg::Mat::from_vec(d, d, gen::stochastic_matrix(r, d));
            let mut obs = crate::linalg::Mat::zeros(d, m);
            for row in 0..d {
                let mut vals: Vec<f64> =
                    (0..m).map(|_| r.uniform(0.05, 1.0)).collect();
                let s: f64 = vals.iter().sum();
                vals.iter_mut().for_each(|v| *v /= s);
                for (c, v) in vals.into_iter().enumerate() {
                    obs[(row, c)] = v;
                }
            }
            let hmm =
                crate::hmm::Hmm::new(pi, obs, gen::prob_vector(r, d)).unwrap();
            let ys = gen::obs_seq(r, m, t);
            let flat = sp_seq(&hmm, &ys).unwrap();
            let two = sp_blockwise(&hmm, &ys, block, 3).unwrap();
            for k in 0..t {
                for s in 0..d {
                    assert!((two.gamma(k)[s] - flat.gamma(k)[s]).abs() < 1e-8);
                }
            }
        });
    }
}

//! Minimal JSON parser/serializer (the `serde`/`serde_json` crates are
//! unavailable offline — see DESIGN.md §1).
//!
//! Covers the full JSON grammar (RFC 8259) minus any extension: objects,
//! arrays, strings with escapes (incl. `\uXXXX` and surrogate pairs),
//! numbers, booleans and null. Used for the artifact manifest, run
//! configuration and results output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value. Object keys are kept in sorted order (BTreeMap)
/// so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (f64 — integers above 2^53 do not round-trip).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object, keys sorted.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing input is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    /// The string payload, when this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, when this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload as a non-negative integer (rejects
    /// fractional and negative values).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// The boolean payload, when this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, when this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key→value map, when this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field accessors for manifest/config loading.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| Error::artifact(format!("missing string field '{key}'")))
    }

    /// Required non-negative-integer field, typed error when absent.
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| Error::artifact(format!("missing integer field '{key}'")))
    }

    /// Required array field, typed error when absent.
    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| Error::artifact(format!("missing array field '{key}'")))
    }

    // -- serialization -----------------------------------------------------

    /// Serialize with no whitespace (the store/wire form).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with two-space indentation (the human form).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder: `obj([("k", v.into()), …])`.
pub fn obj<const N: usize>(pairs: [(&str, Json); N]) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no inf/nan; emit null like most encoders.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone surrogate"));
                                }
                                self.pos += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(self.err("lone surrogate"));
                                }
                                self.pos += 1;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad low surrogate"));
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: re-decode from the source slice
                    let start = self.pos - 1;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo ∀x\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∀x"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"\\q\"", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"obj":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn integer_formatting_exact() {
        assert_eq!(Json::Num(8192.0).to_string_compact(), "8192");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn accessors_and_defaults() {
        let v = Json::parse(r#"{"n": 7, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 7);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_str("missing").is_err());
        assert_eq!(v.get("missing"), &Json::Null);
        assert_eq!(v.get("missing").get("deeper"), &Json::Null);
    }

    #[test]
    fn builder_and_from_impls() {
        let v = obj([
            ("name", "x".into()),
            ("count", 3usize.into()),
            ("vals", vec![1.0, 2.0].into()),
        ]);
        let s = v.to_string_compact();
        assert_eq!(s, r#"{"count":3,"name":"x","vals":[1,2]}"#);
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        let v = Json::parse(&s).unwrap();
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }
}

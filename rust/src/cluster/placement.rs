//! Consistent placement: 256 slots × rendezvous (HRW) hashing.
//!
//! A session id maps to one of [`SLOTS`] slots (`id % 256`, mirroring
//! the store's 256-way directory sharding), and each slot maps to a
//! worker by **highest-random-weight** hashing: every worker is ranked
//! by `fnv1a_64(slot ‖ address)` and the maximum wins. The properties
//! the router leans on:
//!
//! * **Deterministic** — placement is a pure function of (slot, member
//!   set); any process that knows the membership computes the same
//!   owner, no coordination required.
//! * **Minimal movement** — removing a worker only re-homes the slots
//!   that worker owned; every other slot's ranking is untouched (the
//!   removed candidate never beat them). Adding a worker re-homes only
//!   the slots the newcomer now wins. This is what keeps a failover or
//!   scale-out from reshuffling the whole session population.

use crate::rng::{fnv1a_64, FNV1A_OFFSET};

/// Number of placement slots. Matches the session store's directory
/// fan-out so a slot's sessions land in one store shard per worker.
pub const SLOTS: usize = 256;

/// The slot a session id belongs to.
pub fn slot_of(session: u64) -> usize {
    (session % SLOTS as u64) as usize
}

/// Rendezvous weight of `worker` for `slot`: the FNV-1a chain over the
/// slot index and the worker address.
pub fn weight(slot: usize, worker: &str) -> u64 {
    let h = fnv1a_64(FNV1A_OFFSET, &(slot as u64).to_le_bytes());
    fnv1a_64(h, worker.as_bytes())
}

/// Index (into `workers`) of the slot's owner: the candidate with the
/// highest rendezvous weight, ties broken by index for determinism.
/// `None` when `workers` is empty.
pub fn place(slot: usize, workers: &[&str]) -> Option<usize> {
    workers
        .iter()
        .enumerate()
        .max_by_key(|(i, w)| (weight(slot, w), usize::MAX - i))
        .map(|(i, _)| i)
}

/// Candidate order for the slot: worker indices by descending
/// rendezvous weight. The router tries them in this order when the
/// preferred owner refuses (busy) or fails, so spill-over placement is
/// deterministic too.
pub fn ranked(slot: usize, workers: &[&str]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..workers.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(weight(slot, workers[i])), i));
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    const W3: [&str; 3] = ["10.0.0.1:9000", "10.0.0.2:9000", "10.0.0.3:9000"];

    #[test]
    fn placement_is_deterministic_and_covers_all_workers() {
        let mut owned = [0usize; 3];
        for slot in 0..SLOTS {
            let a = place(slot, &W3).unwrap();
            let b = place(slot, &W3).unwrap();
            assert_eq!(a, b, "placement must be a pure function");
            owned[a] += 1;
        }
        // HRW balances slots across members (no worker starved).
        for (i, n) in owned.iter().enumerate() {
            assert!(*n > SLOTS / 8, "worker {i} owns only {n}/{SLOTS} slots");
        }
        assert_eq!(owned.iter().sum::<usize>(), SLOTS);
    }

    #[test]
    fn removal_moves_only_the_lost_workers_slots() {
        let survivors = [W3[0], W3[2]];
        for slot in 0..SLOTS {
            let before = place(slot, &W3).unwrap();
            let after = place(slot, &survivors).unwrap();
            if before != 1 {
                // Slots the removed worker did not own keep their owner.
                let kept = [W3[0], W3[2]][after];
                assert_eq!(
                    W3[before], kept,
                    "slot {slot} moved although its owner survived"
                );
            }
        }
    }

    #[test]
    fn ranked_is_a_permutation_led_by_the_owner() {
        for slot in [0usize, 17, 255] {
            let order = ranked(slot, &W3);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2]);
            assert_eq!(order[0], place(slot, &W3).unwrap());
        }
    }

    #[test]
    fn slots_mirror_store_sharding() {
        assert_eq!(slot_of(0), 0);
        assert_eq!(slot_of(256), 0);
        assert_eq!(slot_of(257), 1);
        assert_eq!(slot_of(u64::MAX), 255);
    }
}

//! The distributed serving tier (L5): a consistent-hash session router
//! over a pool of independent workers, with health-driven failover and
//! live session migration.
//!
//! One `hmm-scan serve` process scales decode throughput to its core
//! count and session capacity to its RAM + store; this module scales
//! both across *processes*. The shape deliberately mirrors the layers
//! below it — the router is "just" another [`WireService`]
//! implementation, so the entire existing serving stack (the
//! `NetServer` front-end, the versioned wire protocol, admission
//! control, graceful drain, `NetClient` with its append-retry ledger)
//! is reused unchanged on both sides of the router:
//!
//! ```text
//!   clients ── wire ──▶ NetServer ▷ ClusterRouter ── wire ──▶ NetServer ▷ Coordinator   (worker 1)
//!                                         │
//!                                         └────────── wire ──▶ NetServer ▷ Coordinator   (worker N)
//! ```
//!
//! * [`placement`] — 256 placement slots (mirroring the store's
//!   directory sharding) mapped to workers by rendezvous hashing:
//!   deterministic, coordination-free, minimal movement on membership
//!   change.
//! * [`router`] — the [`ClusterRouter`]: session placement and routing,
//!   round-robin decode fan-out with failover past dead/busy workers,
//!   probe-driven membership ([`WorkerState`]), administrative drain,
//!   and verified live migration (compact-on-A → restore-on-B →
//!   bit-identical `Stat` check → cutover).
//!
//! CLI: `hmm-scan route --listen ADDR --workers A,B,C` fronts a router
//! with a `NetServer`; `hmm-scan cluster-demo` runs a three-worker
//! loopback cluster end to end. `bench-cluster` measures decode
//! throughput scaling across worker counts. Design notes:
//! `DESIGN.md` §7.
//!
//! [`WireService`]: crate::net::WireService

pub mod placement;
pub mod router;

pub use placement::{place, ranked, slot_of, weight, SLOTS};
pub use router::{ClusterConfig, ClusterRouter, WorkerState};

//! The session router: one [`WireService`] fanning out to N workers.
//!
//! A [`ClusterRouter`] owns a pool of worker endpoints (each an
//! unmodified `NetServer` + `Coordinator` + store), places streaming
//! sessions on them by consistent hash of the session id
//! ([`super::placement`]), and fans decode requests out round-robin
//! with failover. It implements [`WireService`], so the *same*
//! `NetServer` front-end, wire protocol, drain state machine and
//! `NetClient` serve it — a client cannot tell a router from a single
//! worker.
//!
//! ## Per-worker links
//!
//! * **Stream link** — one persistent [`NetClient`] per worker,
//!   serialized by a mutex, carries every session verb. Stream verbs
//!   for one session must apply in order, and `NetClient`'s
//!   append-retry ledger lives in the client — keeping one long-lived
//!   client per worker is what makes a router-side reconnect after a
//!   worker restart *safe*: the ledger's re-`Stat` resolution proves
//!   whether an in-flight append landed before ever re-sending, so no
//!   append double-applies across a failover.
//! * **Decode pool** — up to `decode_pool` additional connections per
//!   worker, checked out per request so decodes overlap. A saturated
//!   pool rejects with a typed [`Error::Busy`] after
//!   `checkout_timeout` (the router's per-worker in-flight limit), and
//!   the front-end turns that into a reject-with-retry-after frame.
//!
//! ## Membership & health
//!
//! A prober thread re-scores every worker each `probe_interval`:
//! connect + `Stat` probe → [`WorkerState::Up`]; connection refused
//! with a reject (the worker's own drain/admission control) →
//! [`WorkerState::Draining`]; connection failure →
//! [`WorkerState::Down`]. Any verb that hits an I/O error marks the
//! worker down immediately — the prober brings it back when it
//! recovers. [`drain_worker`](ClusterRouter::drain_worker) places an
//! administrative hold (reported as draining, excluded from placement)
//! and live-migrates every resident session away.
//!
//! ## Live migration
//!
//! [`migrate_session`](ClusterRouter::migrate_session) moves one
//! session A→B with traffic paused only for the route flip (the
//! session's route lock): **export** on A (compact into one
//! self-contained snapshot), **import** on B (resume bit-identically),
//! **verify** B's `Stat` reports exactly the exported length and model
//! before any traffic cuts over, then **release** A's copy. A failed
//! verification releases B and leaves the route on A — the session
//! never has two serving homes.
//!
//! ## Request tracing
//!
//! The router participates in the wire-propagated trace context
//! (protocol v4): a routed decode's pool-checkout wait is attributed as
//! a `checkout` span under the fronting server's ambient `execute`
//! span, and the worker-bound `NetClient`s stamp that ambient context
//! onto every outgoing frame — so a worker's own `admission` / `queue`
//! / `execute` spans land in *its* timeline as children of the router's
//! execute span, and `hmm-scan trace --merge` joins the two logs into
//! one cross-process span tree. A live migration originates its own
//! trace (`migrate` root span) so the export → import → verify →
//! cutover hops on both workers fold into one causal view.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{
    DecodeRequest, DecodeResponse, Metrics, StreamReply, StreamRequest,
    StreamResponse, StreamVerb,
};
use crate::error::{Error, Result};
use crate::net::{NetClient, WireService};
use crate::obs::span::StageSpan;
use crate::obs::{Timeline, TimelineEvent};

use super::placement::{ranked, slot_of};

/// Health/administrative state of one worker as the router sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerState {
    /// Serving: eligible for placement, decodes, and migration targets.
    Up,
    /// Refusing new work (its own drain/admission control, or an
    /// administrative hold from [`ClusterRouter::drain_worker`]);
    /// existing sessions may still be served or migrated away.
    Draining,
    /// Unreachable; excluded from everything until a probe succeeds.
    Down,
}

impl std::fmt::Display for WorkerState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            WorkerState::Up => "up",
            WorkerState::Draining => "draining",
            WorkerState::Down => "down",
        })
    }
}

const HEALTH_UP: u8 = 0;
const HEALTH_DRAINING: u8 = 1;
const HEALTH_DOWN: u8 = 2;

/// Bound on fresh-id attempts when a worker reports an id collision
/// (possible when a worker recovered pre-router sessions from its
/// store); far above any realistic collision run.
const MAX_ID_ATTEMPTS: usize = 64;

/// Decode-connection pool of one worker: idle clients plus the count of
/// every client currently existing (idle or checked out).
#[derive(Default)]
struct PoolInner {
    idle: Vec<NetClient>,
    created: usize,
}

/// One worker endpoint and the router's links to it.
struct Worker {
    addr: String,
    /// Probe-scored health ([`HEALTH_UP`] / [`HEALTH_DRAINING`] /
    /// [`HEALTH_DOWN`]); verbs store [`HEALTH_DOWN`] on I/O errors.
    health: AtomicU8,
    /// Administrative drain hold ([`ClusterRouter::drain_worker`]).
    admin_hold: AtomicBool,
    /// The persistent stream-verb client (lazily connected, never
    /// discarded — its append-retry ledger must survive reconnects).
    stream: Mutex<Option<NetClient>>,
    pool: Mutex<PoolInner>,
    pool_freed: Condvar,
}

impl Worker {
    fn new(addr: String) -> Worker {
        Worker {
            addr,
            health: AtomicU8::new(HEALTH_UP),
            admin_hold: AtomicBool::new(false),
            stream: Mutex::new(None),
            pool: Mutex::new(PoolInner::default()),
            pool_freed: Condvar::new(),
        }
    }

    fn state(&self) -> WorkerState {
        if self.admin_hold.load(Ordering::Acquire) {
            return WorkerState::Draining;
        }
        match self.health.load(Ordering::Acquire) {
            HEALTH_UP => WorkerState::Up,
            HEALTH_DRAINING => WorkerState::Draining,
            _ => WorkerState::Down,
        }
    }

    fn mark_down(&self) {
        self.health.store(HEALTH_DOWN, Ordering::Release);
    }
}

/// Tuning knobs for [`ClusterRouter::new`].
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Worker addresses (`host:port`), each an independent
    /// `hmm-scan serve` process. Order is irrelevant to placement
    /// (rendezvous hashing ranks by address), but duplicates are
    /// rejected.
    pub workers: Vec<String>,
    /// Decode connections kept per worker — the router's per-worker
    /// in-flight decode limit.
    pub decode_pool: usize,
    /// How long a decode waits for a free pooled connection before the
    /// router rejects it with a typed busy error.
    pub checkout_timeout: Duration,
    /// Health-probe period.
    pub probe_interval: Duration,
    /// Retry-after hint (ms) carried by router-issued busy rejections.
    pub retry_after_ms: u64,
    /// Optional event timeline: placements, migrations (begin, verify,
    /// cutover), drains and routed-session closes are appended to it.
    /// Share one timeline with the fronting server's
    /// [`crate::net::NetServerConfig::timeline`] for a single
    /// interleaved log of connection and routing events.
    pub timeline: Option<Arc<Timeline>>,
}

impl ClusterConfig {
    /// A config for `workers` with default tuning.
    pub fn new<I, S>(workers: I) -> ClusterConfig
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        ClusterConfig {
            workers: workers.into_iter().map(Into::into).collect(),
            decode_pool: 4,
            checkout_timeout: Duration::from_secs(2),
            probe_interval: Duration::from_secs(1),
            retry_after_ms: 100,
            timeline: None,
        }
    }
}

/// Routing state of one placed session: the index of its current home
/// worker, behind a mutex that serializes session verbs against
/// migration (a verb holds it for the duration of the worker call; a
/// migration holds it across the whole export → verify → flip).
struct SessionRoute {
    home: Mutex<usize>,
}

/// The distributed serving tier's router (see the module docs).
///
/// Construct with [`new`](Self::new), then either call the
/// [`WireService`] methods in-process or front it with a
/// [`NetServer`](crate::net::NetServer) (`hmm-scan route`).
pub struct ClusterRouter {
    workers: Vec<Arc<Worker>>,
    sessions: Mutex<BTreeMap<u64, Arc<SessionRoute>>>,
    /// Router-owned session id allocator. Workers advance their local
    /// allocators past every routed id (`OpenAt`/`Import` contract), so
    /// the two spaces never collide.
    next_session: AtomicU64,
    /// Round-robin cursor for sessionless decode fan-out.
    rr: AtomicUsize,
    metrics: Arc<Metrics>,
    config: ClusterConfig,
    stop: Arc<(Mutex<bool>, Condvar)>,
    prober: Option<thread::JoinHandle<()>>,
}

impl ClusterRouter {
    /// Build a router over `config.workers` and start its health
    /// prober. Workers need not be reachable yet — each is probed
    /// once synchronously (so initial states are honest) and then every
    /// `probe_interval`.
    pub fn new(config: ClusterConfig) -> Result<ClusterRouter> {
        if config.workers.is_empty() {
            return Err(Error::invalid_request(
                "cluster: at least one worker address is required",
            ));
        }
        let mut seen = std::collections::BTreeSet::new();
        for w in &config.workers {
            if !seen.insert(w.as_str()) {
                return Err(Error::invalid_request(format!(
                    "cluster: duplicate worker address {w}"
                )));
            }
        }
        let workers: Vec<Arc<Worker>> = config
            .workers
            .iter()
            .map(|a| Arc::new(Worker::new(a.clone())))
            .collect();
        for w in &workers {
            w.health.store(probe(&w.addr), Ordering::Release);
        }
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let prober = {
            let stop = Arc::clone(&stop);
            let workers = workers.clone();
            let interval = config.probe_interval;
            thread::Builder::new()
                .name("hmm-scan-cluster-probe".into())
                .spawn(move || loop {
                    {
                        let (lock, cv) = &*stop;
                        let guard = lock.lock().unwrap();
                        if *guard {
                            break;
                        }
                        let (guard, _) =
                            cv.wait_timeout(guard, interval).unwrap();
                        if *guard {
                            break;
                        }
                    }
                    for w in &workers {
                        w.health.store(probe(&w.addr), Ordering::Release);
                    }
                })
                .expect("spawn cluster prober")
        };
        let metrics = Arc::new(Metrics::new());
        if let Some(tl) = &config.timeline {
            // The router's scrape reports its own timeline's health.
            metrics.attach_timeline(Arc::clone(tl));
        }
        Ok(ClusterRouter {
            workers,
            sessions: Mutex::new(BTreeMap::new()),
            next_session: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            metrics,
            config,
            stop,
            prober: Some(prober),
        })
    }

    /// The router's metrics registry (placement/migration/failover
    /// gauges, per-worker link latency, plus everything the fronting
    /// `NetServer` records).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Every worker with its current state, in configuration order.
    pub fn worker_states(&self) -> Vec<(String, WorkerState)> {
        self.workers.iter().map(|w| (w.addr.clone(), w.state())).collect()
    }

    /// Sessions currently routed (placed and not yet closed).
    pub fn open_sessions(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    /// The address currently serving `session`, if the router placed it.
    pub fn session_home(&self, session: u64) -> Option<String> {
        let route = self.sessions.lock().unwrap().get(&session).cloned()?;
        let home = route.home.lock().unwrap();
        Some(self.workers[*home].addr.clone())
    }

    fn worker_index(&self, addr: &str) -> Result<usize> {
        self.workers.iter().position(|w| w.addr == addr).ok_or_else(|| {
            Error::invalid_request(format!("cluster: unknown worker {addr}"))
        })
    }

    /// Append an event to the timeline (no-op without one; never
    /// blocks — a full channel drops the event and bumps a counter).
    fn record(&self, event: TimelineEvent) {
        if let Some(timeline) = &self.config.timeline {
            timeline.record(event);
        }
    }

    /// Administratively drain `addr`: exclude it from placement and
    /// decode fan-out, then live-migrate every session it serves to its
    /// rendezvous-preferred surviving worker. Returns how many sessions
    /// moved. The worker process itself is untouched (stop it with its
    /// own drain once this returns).
    pub fn drain_worker(&self, addr: &str) -> Result<usize> {
        let wi = self.worker_index(addr)?;
        self.workers[wi].admin_hold.store(true, Ordering::Release);
        self.record(TimelineEvent::Drain { target: addr.to_string() });
        let resident: Vec<u64> = {
            let sessions = self.sessions.lock().unwrap();
            sessions
                .iter()
                .filter(|(_, r)| *r.home.lock().unwrap() == wi)
                .map(|(id, _)| *id)
                .collect()
        };
        let addrs: Vec<&str> =
            self.workers.iter().map(|w| w.addr.as_str()).collect();
        let mut moved = 0;
        for id in resident {
            let target = ranked(slot_of(id), &addrs)
                .into_iter()
                .find(|&i| {
                    i != wi && self.workers[i].state() == WorkerState::Up
                })
                .ok_or_else(|| {
                    Error::coordinator(format!(
                        "drain of {addr}: no eligible target worker \
                         ({moved} sessions migrated before giving up)"
                    ))
                })?;
            let target_addr = self.workers[target].addr.clone();
            self.migrate_session(id, &target_addr)?;
            moved += 1;
        }
        Ok(moved)
    }

    /// Lift the administrative hold placed by
    /// [`drain_worker`](Self::drain_worker); the worker re-enters
    /// rotation at its probed health.
    pub fn resume_worker(&self, addr: &str) -> Result<()> {
        let wi = self.worker_index(addr)?;
        self.workers[wi].admin_hold.store(false, Ordering::Release);
        Ok(())
    }

    /// Live-migrate one session to `target` (see the module docs for
    /// the state machine). No-op if the session already lives there.
    /// On any verification failure the target copy is released and the
    /// route is left unchanged.
    pub fn migrate_session(&self, session: u64, target: &str) -> Result<()> {
        let ti = self.worker_index(target)?;
        let route = self
            .sessions
            .lock()
            .unwrap()
            .get(&session)
            .cloned()
            .ok_or_else(|| {
                Error::invalid_request(format!(
                    "cluster: unknown session {session}"
                ))
            })?;
        // Holding the route lock pauses this session's verbs for the
        // whole handoff, so the exported image is provably final.
        let mut home = route.home.lock().unwrap();
        if *home == ti {
            return Ok(());
        }
        let src = Arc::clone(&self.workers[*home]);
        let dst = Arc::clone(&self.workers[ti]);
        // The whole handoff is one traced root span: the stream clients
        // stamp its context onto every export/import/verify/release hop,
        // so both workers' spans fold under it in a merged timeline.
        let span =
            StageSpan::begin_root(self.config.timeline.as_ref(), "migrate");
        let out = span.enter(|| {
            self.record(TimelineEvent::MigrateBegin {
                session,
                from: src.addr.clone(),
                to: dst.addr.clone(),
            });
            // Compact-on-A: one self-contained checkpoint + meta.
            let (meta, snapshot, len_a) =
                self.on_worker_stream(&src, |c| c.export(session))?;
            let model = meta.model.clone();
            // Restore-on-B.
            let len_b = self
                .on_worker_stream(&dst, |c| c.import(session, meta, snapshot))?;
            // Verify before cutover: B's own Stat must report exactly the
            // state A exported — length and model — or traffic stays on A.
            let verified = len_b == len_a && {
                let reply = self.on_worker_stream(&dst, |c| c.stat(session))?;
                matches!(
                    &reply,
                    StreamReply::Stats { len, model: m, .. }
                        if *len == len_a && *m == model
                )
            };
            if !verified {
                let _ = self.on_worker_stream(&dst, |c| c.release(session));
                return Err(Error::coordinator(format!(
                    "migration of session {session} to {target} failed \
                     verification; route unchanged"
                )));
            }
            self.record(TimelineEvent::MigrateVerify {
                session,
                to: dst.addr.clone(),
            });
            // Cut over, then release A's copy (best effort — if A is dying
            // anyway its copy is unreachable and harmless: the router's id
            // space never re-issues the id).
            let from = src.addr.clone();
            *home = ti;
            self.metrics.on_session_migrated();
            self.record(TimelineEvent::MigrateCutover {
                session,
                from,
                to: dst.addr.clone(),
            });
            let _ = self.on_worker_stream(&src, |c| c.release(session));
            Ok(())
        });
        span.finish_with(false, format!("session={session}"));
        out
    }

    /// Place a new session: allocate a router id, rank the Up workers
    /// for its slot, and `open_at` on the first that accepts. Busy and
    /// unreachable workers are skipped (failed-over); an id collision
    /// (a worker with recovered pre-router sessions) retries with a
    /// fresh id.
    fn open_session(
        &self,
        rid: u64,
        model: &str,
        options: crate::engine::SessionOptions,
        lag: usize,
    ) -> Result<StreamResponse> {
        let t0 = Instant::now();
        for _ in 0..MAX_ID_ATTEMPTS {
            let id = self.next_session.fetch_add(1, Ordering::Relaxed) + 1;
            let addrs: Vec<&str> =
                self.workers.iter().map(|w| w.addr.as_str()).collect();
            let mut collided = false;
            let mut attempted = false;
            for wi in ranked(slot_of(id), &addrs) {
                let w = Arc::clone(&self.workers[wi]);
                if w.state() != WorkerState::Up {
                    continue;
                }
                if attempted {
                    self.metrics.on_failover();
                }
                attempted = true;
                let placed = self.on_worker_stream(&w, |c| {
                    c.open_at(id, model, options, lag)
                });
                match placed {
                    Ok(_) => {
                        self.sessions.lock().unwrap().insert(
                            id,
                            Arc::new(SessionRoute { home: Mutex::new(wi) }),
                        );
                        self.metrics.on_session_placed();
                        self.record(TimelineEvent::Place {
                            session: id,
                            worker: w.addr.clone(),
                        });
                        return Ok(StreamResponse {
                            id: rid,
                            reply: StreamReply::Opened { session: id },
                            elapsed: t0.elapsed(),
                        });
                    }
                    // Try the next-ranked worker on transient failures.
                    Err(Error::Io(_)) | Err(Error::Busy { .. }) => continue,
                    Err(Error::InvalidRequest(msg))
                        if msg.contains("already exists") =>
                    {
                        collided = true;
                        break; // fresh id, same ranking logic
                    }
                    Err(e) => return Err(e),
                }
            }
            if !collided {
                // Every Up worker refused or none exists.
                return Err(Error::busy(
                    self.config.retry_after_ms,
                    "cluster: no worker available to place the session",
                ));
            }
        }
        Err(Error::coordinator(
            "cluster: session id space exhausted by collisions",
        ))
    }

    /// Run a session verb on the session's home worker, holding the
    /// route lock so migration cannot flip the home mid-verb.
    fn on_route<T>(
        &self,
        session: u64,
        f: impl FnOnce(&mut NetClient) -> Result<T>,
    ) -> Result<T> {
        let route = self
            .sessions
            .lock()
            .unwrap()
            .get(&session)
            .cloned()
            .ok_or_else(|| {
                Error::invalid_request(format!(
                    "cluster: unknown session {session} (not placed by this \
                     router)"
                ))
            })?;
        let home = route.home.lock().unwrap();
        let w = Arc::clone(&self.workers[*home]);
        self.on_worker_stream(&w, f)
    }

    /// Run `f` on the worker's persistent stream client (lazily
    /// connected), recording link latency and marking the worker down
    /// on connection-level failures. The client is never discarded:
    /// its append-retry ledger is what makes retrying safe.
    fn on_worker_stream<T>(
        &self,
        w: &Worker,
        f: impl FnOnce(&mut NetClient) -> Result<T>,
    ) -> Result<T> {
        let mut guard = w.stream.lock().unwrap();
        if guard.is_none() {
            match NetClient::connect(&w.addr) {
                Ok(c) => *guard = Some(c),
                Err(e) => {
                    if matches!(e, Error::Io(_)) {
                        w.mark_down();
                    }
                    return Err(e);
                }
            }
        }
        let client = guard.as_mut().expect("stream client just ensured");
        let t0 = Instant::now();
        let out = f(client);
        self.metrics.on_worker_call(&w.addr, t0.elapsed());
        if matches!(out, Err(Error::Io(_))) {
            w.mark_down();
        }
        out
    }

    /// Check one decode client out of the worker's pool: an idle one,
    /// a fresh connection below the cap, or — after `checkout_timeout`
    /// of waiting at the cap — a typed busy rejection.
    fn checkout(&self, w: &Worker) -> Result<NetClient> {
        let deadline = Instant::now() + self.config.checkout_timeout;
        let mut inner = w.pool.lock().unwrap();
        loop {
            if let Some(c) = inner.idle.pop() {
                return Ok(c);
            }
            if inner.created < self.config.decode_pool.max(1) {
                inner.created += 1;
                drop(inner);
                return match NetClient::connect(&w.addr) {
                    Ok(c) => Ok(c),
                    Err(e) => {
                        w.pool.lock().unwrap().created -= 1;
                        w.pool_freed.notify_one();
                        Err(e)
                    }
                };
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::busy(
                    self.config.retry_after_ms,
                    format!(
                        "cluster: decode pool for worker {} saturated",
                        w.addr
                    ),
                ));
            }
            let (guard, _) = w.pool_freed.wait_timeout(inner, left).unwrap();
            inner = guard;
        }
    }

    /// Return a healthy decode client to the pool.
    fn checkin(&self, w: &Worker, client: NetClient) {
        w.pool.lock().unwrap().idle.push(client);
        w.pool_freed.notify_one();
    }

    /// Drop a broken decode client (its connection died).
    fn discard(&self, w: &Worker) {
        w.pool.lock().unwrap().created -= 1;
        w.pool_freed.notify_one();
    }

    /// One decode attempt against one worker through its pool.
    fn decode_on(
        &self,
        w: &Worker,
        req: DecodeRequest,
    ) -> Result<DecodeResponse> {
        // The pool-checkout wait is its own stage under the fronting
        // server's ambient execute span (inert when untraced).
        let co =
            StageSpan::begin(self.config.timeline.as_ref(), "checkout");
        let checked = self.checkout(w);
        co.finish_with(false, w.addr.clone());
        let mut client = checked?;
        let t0 = Instant::now();
        let out = client.decode(&req);
        self.metrics.on_worker_call(&w.addr, t0.elapsed());
        if matches!(out, Err(Error::Io(_))) {
            self.discard(w);
        } else {
            self.checkin(w, client);
        }
        out
    }
}

impl Drop for ClusterRouter {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.stop;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        if let Some(prober) = self.prober.take() {
            let _ = prober.join();
        }
    }
}

impl WireService for ClusterRouter {
    /// Fan one decode out round-robin over the Up workers, failing over
    /// past unreachable (marked down) and busy ones. Deterministic
    /// request errors (unknown model, bad observation…) return
    /// immediately — they would fail identically everywhere.
    fn decode(&self, req: DecodeRequest) -> Result<DecodeResponse> {
        let n = self.workers.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        let mut prior_io_failure = false;
        for k in 0..n {
            let w = Arc::clone(&self.workers[(start + k) % n]);
            if w.state() != WorkerState::Up {
                continue;
            }
            if prior_io_failure {
                self.metrics.on_failover();
            }
            match self.decode_on(&w, req.clone()) {
                Ok(resp) => return Ok(resp),
                Err(Error::Io(_)) => {
                    w.mark_down();
                    prior_io_failure = true;
                }
                Err(Error::Busy { .. }) => continue,
                Err(e) => return Err(e),
            }
        }
        Err(Error::busy(
            self.config.retry_after_ms,
            "cluster: no worker available for decode",
        ))
    }

    /// Serve one streaming verb: `open` places a session; `append` /
    /// `stat` / `close` follow its route. The migration verbs
    /// (`open_at` / `export` / `import` / `release`) are router→worker
    /// internals and are rejected at this tier.
    fn stream(&self, req: StreamRequest) -> Result<StreamResponse> {
        let rid = req.id;
        let t0 = Instant::now();
        match req.verb {
            StreamVerb::Open { model, options, lag } => {
                self.open_session(rid, &model, options, lag)
            }
            StreamVerb::Append { session, ys } => {
                let reply = self.on_route(session, |c| c.append(session, &ys))?;
                Ok(StreamResponse { id: rid, reply, elapsed: t0.elapsed() })
            }
            StreamVerb::Stat { session } => {
                let reply = self.on_route(session, |c| c.stat(session))?;
                Ok(StreamResponse { id: rid, reply, elapsed: t0.elapsed() })
            }
            StreamVerb::Close { session } => {
                let posterior =
                    self.on_route(session, |c| c.close(session))?;
                self.sessions.lock().unwrap().remove(&session);
                self.record(TimelineEvent::SessionClose { session });
                Ok(StreamResponse {
                    id: rid,
                    reply: StreamReply::Closed { session, posterior },
                    elapsed: t0.elapsed(),
                })
            }
            StreamVerb::OpenAt { .. }
            | StreamVerb::Export { .. }
            | StreamVerb::Import { .. }
            | StreamVerb::Release { .. } => Err(Error::invalid_request(
                "cluster: migration verbs are router→worker internal and \
                 not accepted from clients",
            )),
        }
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

/// Score one worker: connect + `Stat` probe. A refusal reject means
/// the worker is alive but draining; a connection failure means down;
/// anything else (the expected typed unknown-session error included)
/// means up.
fn probe(addr: &str) -> u8 {
    match NetClient::connect(addr) {
        Ok(mut c) => match c.stat(u64::MAX) {
            Err(Error::Io(_)) => HEALTH_DOWN,
            Err(Error::Busy { .. }) => HEALTH_DRAINING,
            _ => HEALTH_UP,
        },
        Err(Error::Busy { .. }) => HEALTH_DRAINING,
        Err(_) => HEALTH_DOWN,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Algo, Coordinator, CoordinatorConfig};
    use crate::engine::SessionOptions;
    use crate::hmm::{gilbert_elliott, GeParams};
    use crate::net::{NetServer, NetServerConfig};
    use crate::proptestx::{gen, Runner};
    use crate::rng::Xoshiro256StarStar;

    fn spawn_worker() -> (Arc<Coordinator>, NetServer, String) {
        let c = Coordinator::new(CoordinatorConfig::native_only()).unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        c.register_lgssm(
            "cv",
            crate::kalman::Lgssm::constant_velocity(0.1, 0.8, 0.5),
        );
        let coord = Arc::new(c);
        let server = NetServer::start(
            Arc::clone(&coord),
            "127.0.0.1:0",
            NetServerConfig {
                exec_threads: 2,
                read_timeout: Duration::from_millis(50),
                ..NetServerConfig::default()
            },
        )
        .unwrap();
        let addr = server.local_addr().to_string();
        (coord, server, addr)
    }

    fn test_router(addrs: Vec<String>) -> ClusterRouter {
        let mut cfg = ClusterConfig::new(addrs);
        cfg.probe_interval = Duration::from_millis(100);
        ClusterRouter::new(cfg).unwrap()
    }

    /// The acceptance bar end to end: a client talking to a fronted
    /// router gets decode and streaming responses bit-identical to a
    /// single local coordinator, across three workers.
    #[test]
    fn routed_serving_is_bit_identical_end_to_end() {
        let workers: Vec<_> = (0..3).map(|_| spawn_worker()).collect();
        let addrs: Vec<String> =
            workers.iter().map(|(_, _, a)| a.clone()).collect();
        let router = Arc::new(test_router(addrs));
        let front = NetServer::start(
            Arc::clone(&router),
            "127.0.0.1:0",
            NetServerConfig::default(),
        )
        .unwrap();
        let mut client =
            NetClient::connect(front.local_addr().to_string()).unwrap();
        client.ping().unwrap();

        let control =
            Coordinator::new(CoordinatorConfig::native_only()).unwrap();
        control.register_model("ge", gilbert_elliott(GeParams::default()));
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(0xC1A5);
        let ys = crate::hmm::sample(&hmm, 240, &mut rng).observations;

        for algo in Algo::ALL {
            let remote = client
                .decode(&DecodeRequest::new(7, "ge", ys.clone(), algo))
                .unwrap();
            let local = control
                .decode(DecodeRequest::new(7, "ge", ys.clone(), algo))
                .unwrap();
            match (&remote.result, &local.result) {
                (
                    crate::coordinator::DecodeResult::Posterior(a),
                    crate::coordinator::DecodeResult::Posterior(b),
                ) => assert_eq!(a, b, "{algo:?} diverged through the router"),
                (
                    crate::coordinator::DecodeResult::Map(a),
                    crate::coordinator::DecodeResult::Map(b),
                ) => assert_eq!(a, b, "MAP diverged through the router"),
                (a, b) => panic!("shape diverged: {a:?} vs {b:?}"),
            }
        }
        // A bad request is a typed error, not a failover storm.
        assert!(client
            .decode(&DecodeRequest::new(7, "nope", vec![0], Algo::Smooth))
            .is_err());

        // Streaming through the router vs the local control.
        let sid = client.open("ge", SessionOptions::default(), 8).unwrap();
        let opened =
            control.stream(StreamRequest::open(0, "ge", 8)).unwrap();
        let StreamReply::Opened { session: ctl } = opened.reply else {
            panic!("expected Opened")
        };
        for chunk in ys.chunks(50) {
            let remote = client.append(sid, chunk).unwrap();
            let local = control
                .stream(StreamRequest::append(0, ctl, chunk.to_vec()))
                .unwrap();
            let StreamReply::Appended { filtered: rf, window: rw, .. } =
                remote
            else {
                panic!("expected Appended")
            };
            let StreamReply::Appended { filtered: lf, window: lw, .. } =
                local.reply
            else {
                panic!("expected Appended")
            };
            assert_eq!(rf, lf, "filtered diverged through the router");
            assert_eq!(
                rw.unwrap().posterior,
                lw.unwrap().posterior,
                "lag window diverged through the router"
            );
        }
        let StreamReply::Stats { len, model, .. } =
            client.stat(sid).unwrap()
        else {
            panic!("expected Stats")
        };
        assert_eq!((len, model.as_str()), (240, "ge"));
        assert!(router.session_home(sid).is_some());
        assert_eq!(router.open_sessions(), 1);

        let remote_posterior = client.close(sid).unwrap();
        let closed = control.stream(StreamRequest::close(0, ctl)).unwrap();
        let StreamReply::Closed { posterior: local_posterior, .. } =
            closed.reply
        else {
            panic!("expected Closed")
        };
        assert_eq!(
            remote_posterior, local_posterior,
            "posterior diverged through the router"
        );
        assert_eq!(router.open_sessions(), 0);

        let snap = router.metrics().snapshot();
        assert!(snap.sessions_placed >= 1);
        assert!(!snap.worker_links.is_empty(), "link latency not recorded");
        drop(client);
        assert!(front.shutdown(Duration::from_secs(5)));
        for (_, server, _) in workers {
            server.shutdown(Duration::from_secs(5));
        }
    }

    /// Kill one worker mid-run: decodes keep succeeding (failover), the
    /// dead worker is marked down, and the failover gauge moves.
    #[test]
    fn decode_fails_over_when_a_worker_dies() {
        let (coord_a, server_a, addr_a) = spawn_worker();
        let (_coord_b, server_b, addr_b) = spawn_worker();
        // A long probe interval keeps the prober from marking the dead
        // worker down first: the decode path itself must discover the
        // death (and count the failover) for this test to be exact.
        let mut cfg = ClusterConfig::new(vec![addr_a.clone(), addr_b.clone()]);
        cfg.probe_interval = Duration::from_secs(300);
        let router = ClusterRouter::new(cfg).unwrap();
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(0xDEAD);
        let ys = crate::hmm::sample(&hmm, 60, &mut rng).observations;

        let local = coord_a
            .decode(DecodeRequest::new(1, "ge", ys.clone(), Algo::Smooth))
            .unwrap();
        for i in 0..4 {
            let resp = router
                .decode(DecodeRequest::new(i, "ge", ys.clone(), Algo::Smooth))
                .unwrap();
            assert_eq!(resp.result.as_posterior(), local.result.as_posterior());
        }
        // Worker A dies. Every subsequent decode must still succeed.
        server_a.shutdown(Duration::from_secs(5));
        for i in 0..6 {
            let resp = router
                .decode(DecodeRequest::new(i, "ge", ys.clone(), Algo::Smooth))
                .unwrap();
            assert_eq!(
                resp.result.as_posterior(),
                local.result.as_posterior(),
                "failover decode diverged"
            );
        }
        let snap = router.metrics().snapshot();
        assert!(snap.decode_failovers >= 1, "failover was never recorded");
        let states = router.worker_states();
        assert!(
            states.iter().any(|(a, s)| *a == addr_a
                && *s == WorkerState::Down),
            "dead worker not marked down: {states:?}"
        );
        assert!(states
            .iter()
            .any(|(a, s)| *a == addr_b && *s == WorkerState::Up));
        server_b.shutdown(Duration::from_secs(5));
    }

    /// Administrative drain re-homes every session off the drained
    /// worker and the sessions keep serving bit-identically.
    #[test]
    fn drain_worker_rehomes_sessions() {
        let workers: Vec<_> = (0..3).map(|_| spawn_worker()).collect();
        let addrs: Vec<String> =
            workers.iter().map(|(_, _, a)| a.clone()).collect();
        let router = test_router(addrs.clone());

        let mut sids = Vec::new();
        for _ in 0..6 {
            let resp = router
                .stream(StreamRequest::open(0, "ge", 0))
                .unwrap();
            let StreamReply::Opened { session } = resp.reply else {
                panic!("expected Opened")
            };
            router
                .stream(StreamRequest::append(0, session, vec![0, 1, 1, 0]))
                .unwrap();
            sids.push(session);
        }
        // Drain whichever worker serves the first session.
        let victim = router.session_home(sids[0]).unwrap();
        let moved = router.drain_worker(&victim).unwrap();
        assert!(moved >= 1, "the victim served at least session {}", sids[0]);
        for &sid in &sids {
            assert_ne!(
                router.session_home(sid).unwrap(),
                victim,
                "session {sid} still routed to the drained worker"
            );
        }
        assert!(router
            .worker_states()
            .iter()
            .any(|(a, s)| *a == victim && *s == WorkerState::Draining));
        // Migrated sessions keep serving.
        for &sid in &sids {
            let resp = router
                .stream(StreamRequest::append(0, sid, vec![1, 0]))
                .unwrap();
            let StreamReply::Appended { len, .. } = resp.reply else {
                panic!("expected Appended")
            };
            assert_eq!(len, 6);
        }
        assert!(
            router.metrics().snapshot().sessions_migrated >= moved as u64
        );
        router.resume_worker(&victim).unwrap();
        assert!(router
            .worker_states()
            .iter()
            .any(|(a, s)| *a == victim && *s == WorkerState::Up));
        for (_, server, _) in workers {
            server.shutdown(Duration::from_secs(5));
        }
    }

    /// The migration acceptance property: across random observation
    /// sequences, random push splits, and random mid-stream migrations,
    /// a migrated session's final posterior is bit-identical to a
    /// never-migrated control session fed the same chunks.
    #[test]
    fn migrated_sessions_finish_bit_identical_to_control() {
        let (_ca, server_a, addr_a) = spawn_worker();
        let (_cb, server_b, addr_b) = spawn_worker();
        let router = test_router(vec![addr_a.clone(), addr_b.clone()]);
        let control =
            Coordinator::new(CoordinatorConfig::native_only()).unwrap();
        control.register_model("ge", gilbert_elliott(GeParams::default()));

        let mut migrations = 0u64;
        Runner::new("cluster-migration-bit-identity").run(4, |rng| {
            let t = 40 + rng.below(160) as usize;
            let ys = gen::obs_seq(rng, 2, t);
            let lag = if rng.below(2) == 0 { 0 } else { 4 };

            let resp =
                router.stream(StreamRequest::open(0, "ge", lag)).unwrap();
            let StreamReply::Opened { session } = resp.reply else {
                panic!("expected Opened")
            };
            let opened =
                control.stream(StreamRequest::open(0, "ge", lag)).unwrap();
            let StreamReply::Opened { session: ctl } = opened.reply else {
                panic!("expected Opened")
            };

            // Random split points; migrate between random chunks (at
            // least once per case, alternating homes A↔B).
            let mut rest = ys.as_slice();
            while !rest.is_empty() {
                let take = (1 + rng.below(48) as usize).min(rest.len());
                let (chunk, tail) = rest.split_at(take);
                rest = tail;
                router
                    .stream(StreamRequest::append(
                        0,
                        session,
                        chunk.to_vec(),
                    ))
                    .unwrap();
                control
                    .stream(StreamRequest::append(0, ctl, chunk.to_vec()))
                    .unwrap();
                if rng.below(2) == 0 || rest.is_empty() {
                    let here = router.session_home(session).unwrap();
                    let there = if here == addr_a {
                        addr_b.clone()
                    } else {
                        addr_a.clone()
                    };
                    router.migrate_session(session, &there).unwrap();
                    assert_eq!(
                        router.session_home(session).unwrap(),
                        there
                    );
                    migrations += 1;
                }
            }

            let resp = router
                .stream(StreamRequest::close(0, session))
                .unwrap();
            let StreamReply::Closed { posterior: routed, .. } = resp.reply
            else {
                panic!("expected Closed")
            };
            let closed =
                control.stream(StreamRequest::close(0, ctl)).unwrap();
            let StreamReply::Closed { posterior: ctrl, .. } = closed.reply
            else {
                panic!("expected Closed")
            };
            assert_eq!(
                routed, ctrl,
                "migrated session diverged from never-migrated control \
                 (T={t}, lag={lag})"
            );
        });
        assert!(migrations >= 4, "every case migrates at least once");
        assert_eq!(
            router.metrics().snapshot().sessions_migrated,
            migrations
        );
        server_a.shutdown(Duration::from_secs(5));
        server_b.shutdown(Duration::from_secs(5));
    }

    /// Kalman sessions ride the same wire, placement and migration
    /// machinery as the discrete families: a Gaussian session migrated
    /// mid-stream (with torn observation rows crossing the wire inside
    /// snapshots) closes bit-identically to a never-migrated local
    /// control.
    #[test]
    fn kalman_sessions_migrate_bit_identical_to_control() {
        use crate::kalman::{obs_to_words, tests_support::tracking_obs, Lgssm};

        let (_ca, server_a, addr_a) = spawn_worker();
        let (_cb, server_b, addr_b) = spawn_worker();
        let router = test_router(vec![addr_a.clone(), addr_b.clone()]);
        let control =
            Coordinator::new(CoordinatorConfig::native_only()).unwrap();
        control.register_lgssm("cv", Lgssm::constant_velocity(0.1, 0.8, 0.5));

        let open = || StreamRequest {
            id: 0,
            verb: StreamVerb::Open {
                model: "cv".into(),
                options: SessionOptions {
                    kind: crate::engine::SessionKind::Kalman,
                    ..Default::default()
                },
                lag: 0,
            },
        };
        let m = Lgssm::constant_velocity(0.1, 0.8, 0.5);
        let words = obs_to_words(&tracking_obs(&m, 90, 11));

        let StreamReply::Opened { session } =
            router.stream(open()).unwrap().reply
        else {
            panic!("expected Opened")
        };
        let StreamReply::Opened { session: ctl } =
            control.stream(open()).unwrap().reply
        else {
            panic!("expected Opened")
        };

        let (mut lo, mut step, mut k) = (0usize, 5usize, 0usize);
        let mut migrations = 0u64;
        while lo < words.len() {
            let hi = (lo + step).min(words.len());
            let chunk = words[lo..hi].to_vec();
            lo = hi;
            step = step % 9 + 3; // odd sizes tear f64 halves mid-chunk
            let r = router
                .stream(StreamRequest::append(0, session, chunk.clone()))
                .unwrap();
            let c = control
                .stream(StreamRequest::append(0, ctl, chunk))
                .unwrap();
            let StreamReply::Appended { filtered: rf, .. } = r.reply else {
                panic!("expected Appended")
            };
            let StreamReply::Appended { filtered: cf, .. } = c.reply else {
                panic!("expected Appended")
            };
            assert_eq!(rf, cf, "kalman filtered diverged through the router");
            k += 1;
            if k % 3 == 0 {
                let here = router.session_home(session).unwrap();
                let there = if here == addr_a {
                    addr_b.clone()
                } else {
                    addr_a.clone()
                };
                router.migrate_session(session, &there).unwrap();
                assert_eq!(router.session_home(session).unwrap(), there);
                migrations += 1;
            }
        }
        assert!(migrations >= 2, "the session never moved");

        let StreamReply::Closed { posterior: routed, .. } =
            router.stream(StreamRequest::close(0, session)).unwrap().reply
        else {
            panic!("expected Closed")
        };
        let StreamReply::Closed { posterior: ctrl, .. } =
            control.stream(StreamRequest::close(0, ctl)).unwrap().reply
        else {
            panic!("expected Closed")
        };
        assert_eq!(
            routed, ctrl,
            "migrated kalman session diverged from local control"
        );
        server_a.shutdown(Duration::from_secs(5));
        server_b.shutdown(Duration::from_secs(5));
    }

    /// The cluster observability acceptance bar: with per-worker and
    /// router timelines, replaying each log reconstructs the live view
    /// exactly — the worker's session registry bit-identical to its
    /// `Stat` across spills and restores, and the router's placements
    /// identical to the live routes across live migrations — and the
    /// scrape verb round-trips through a fronted router.
    #[test]
    fn cluster_timelines_replay_to_live_state() {
        use crate::obs::{read_events, replay_records, Timeline};

        let dir = crate::store::testutil::tempdir("cluster-timeline");
        // Worker A: disk store, watermark 1, its own timeline.
        let wa_tl = Timeline::open(dir.join("wa-tl")).unwrap();
        let ca = Coordinator::new(CoordinatorConfig {
            resident_watermark: 1,
            session_store: Some(dir.join("wa-store")),
            timeline: Some(Arc::clone(&wa_tl)),
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        ca.register_model("ge", gilbert_elliott(GeParams::default()));
        let ca = Arc::new(ca);
        let server_a = NetServer::start(
            Arc::clone(&ca),
            "127.0.0.1:0",
            NetServerConfig::default(),
        )
        .unwrap();
        let addr_a = server_a.local_addr().to_string();
        let (_cb, server_b, addr_b) = spawn_worker();

        let rt_tl = Timeline::open(dir.join("rt-tl")).unwrap();
        let mut cfg = ClusterConfig::new(vec![addr_a.clone(), addr_b.clone()]);
        cfg.probe_interval = Duration::from_millis(100);
        cfg.timeline = Some(Arc::clone(&rt_tl));
        let router = Arc::new(ClusterRouter::new(cfg).unwrap());

        let mut sids = Vec::new();
        for _ in 0..4 {
            let StreamReply::Opened { session } = router
                .stream(StreamRequest::open(0, "ge", 0))
                .unwrap()
                .reply
            else {
                panic!("expected Opened")
            };
            router
                .stream(StreamRequest::append(0, session, vec![0, 1]))
                .unwrap();
            sids.push(session);
        }
        // Herd every session onto worker A so its watermark-1 registry
        // spills, then append to each so evicted ones restore.
        let mut migrated = 0u64;
        for &sid in &sids {
            if router.session_home(sid).unwrap() != addr_a {
                router.migrate_session(sid, &addr_a).unwrap();
                migrated += 1;
            }
        }
        ca.quiesce_housekeeping();
        for &sid in &sids {
            router.stream(StreamRequest::append(0, sid, vec![1])).unwrap();
        }
        // One more live migration after the spill/restore churn.
        router.migrate_session(sids[0], &addr_b).unwrap();
        migrated += 1;
        ca.quiesce_housekeeping();
        let snap = ca.metrics().snapshot();
        assert!(snap.spills > 0, "worker A never spilled");
        assert!(snap.restores > 0, "worker A never restored");

        // Worker A's timeline replays to its live registry.
        wa_tl.flush();
        let state = replay_records(&read_events(wa_tl.dir()).unwrap(), None);
        assert_eq!(state.open_sessions(), ca.open_sessions());
        assert_eq!(state.resident_sessions(), ca.resident_sessions());
        for (&sid, view) in &state.sessions {
            let StreamReply::Stats { len, resident, model, .. } =
                ca.stream(StreamRequest::stat(0, sid)).unwrap().reply
            else {
                panic!("expected Stats")
            };
            assert_eq!(
                (view.len, view.resident, view.model.as_str()),
                (len, resident, model.as_str()),
                "worker A session {sid} diverged from replay"
            );
        }
        assert_eq!(wa_tl.dropped(), 0);

        // The router's timeline replays to the live routes.
        rt_tl.flush();
        let rt = replay_records(&read_events(rt_tl.dir()).unwrap(), None);
        assert_eq!(rt.migrations, migrated);
        assert_eq!(rt.placements.len(), sids.len());
        for &sid in &sids {
            assert_eq!(
                rt.placements.get(&sid),
                router.session_home(sid).as_ref(),
                "router placement for session {sid} diverged from replay"
            );
        }

        // Scrape round-trips through a fronted router, and a close
        // replays the placement away.
        let front = NetServer::start(
            Arc::clone(&router),
            "127.0.0.1:0",
            NetServerConfig::default(),
        )
        .unwrap();
        let mut client =
            NetClient::connect(front.local_addr().to_string()).unwrap();
        let text = client.scrape().unwrap();
        for line in text.lines() {
            let (key, value) = line.split_once(' ').unwrap();
            assert!(!key.is_empty());
            assert!(value.parse::<f64>().is_ok(), "unparseable line: {line}");
        }
        let placed = format!("sessions_placed {}", sids.len());
        assert!(text.contains(&placed), "scrape missing: {placed}");
        assert!(text.contains(&format!("sessions_migrated {migrated}")));
        assert!(text.contains("worker_"), "no per-worker link lines");

        client.close(sids[1]).unwrap();
        rt_tl.flush();
        let rt = replay_records(&read_events(rt_tl.dir()).unwrap(), None);
        assert!(
            !rt.placements.contains_key(&sids[1]),
            "closed session must replay out of the placements"
        );
        assert_eq!(rt_tl.dropped(), 0);

        drop(client);
        assert!(front.shutdown(Duration::from_secs(5)));
        server_a.shutdown(Duration::from_secs(5));
        server_b.shutdown(Duration::from_secs(5));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The tracing acceptance bar end to end: a routed decode and a
    /// live migration each produce — across the router's and both
    /// workers' timelines — one merged span tree whose parent/child
    /// links cross process boundaries (the router's execute span
    /// parents the worker's spans), with stage latencies summing
    /// within the wall-clock envelope.
    #[test]
    fn merged_timelines_link_spans_across_processes() {
        use crate::obs::{merge_records, read_events, trace_views, Timeline};

        fn traced_worker(
            dir: std::path::PathBuf,
        ) -> (Arc<Timeline>, NetServer, String) {
            let tl = Timeline::open(dir).unwrap();
            let c = Coordinator::new(CoordinatorConfig::native_only()).unwrap();
            c.register_model("ge", gilbert_elliott(GeParams::default()));
            let server = NetServer::start(
                Arc::new(c),
                "127.0.0.1:0",
                NetServerConfig {
                    exec_threads: 2,
                    read_timeout: Duration::from_millis(50),
                    timeline: Some(Arc::clone(&tl)),
                    ..NetServerConfig::default()
                },
            )
            .unwrap();
            let addr = server.local_addr().to_string();
            (tl, server, addr)
        }

        let dir = crate::store::testutil::tempdir("cluster-trace");
        let (wa_tl, server_a, addr_a) = traced_worker(dir.join("wa"));
        let (wb_tl, server_b, addr_b) = traced_worker(dir.join("wb"));
        let rt_tl = Timeline::open(dir.join("rt")).unwrap();
        let mut cfg = ClusterConfig::new(vec![addr_a.clone(), addr_b.clone()]);
        cfg.probe_interval = Duration::from_secs(300);
        cfg.timeline = Some(Arc::clone(&rt_tl));
        let router = Arc::new(ClusterRouter::new(cfg).unwrap());
        let front = NetServer::start(
            Arc::clone(&router),
            "127.0.0.1:0",
            NetServerConfig {
                timeline: Some(Arc::clone(&rt_tl)),
                ..NetServerConfig::default()
            },
        )
        .unwrap();
        let mut client =
            NetClient::connect(front.local_addr().to_string()).unwrap();

        let t0 = Instant::now();
        client
            .decode(&DecodeRequest::new(1, "ge", vec![0, 1, 1, 0], Algo::Smooth))
            .unwrap();
        let envelope_us = t0.elapsed().as_micros() as u64;

        // A routed session whose live migration crosses both workers.
        let sid = client.open("ge", SessionOptions::default(), 0).unwrap();
        client.append(sid, &[0, 1, 1]).unwrap();
        let here = router.session_home(sid).unwrap();
        let there =
            if here == addr_a { addr_b.clone() } else { addr_a.clone() };
        router.migrate_session(sid, &there).unwrap();
        client.append(sid, &[1, 0]).unwrap();
        client.close(sid).unwrap();

        drop(client);
        assert!(front.shutdown(Duration::from_secs(5)));
        server_a.shutdown(Duration::from_secs(5));
        server_b.shutdown(Duration::from_secs(5));
        rt_tl.flush();
        wa_tl.flush();
        wb_tl.flush();

        let sources = vec![
            ("router".to_string(), read_events(rt_tl.dir()).unwrap()),
            ("worker_a".to_string(), read_events(wa_tl.dir()).unwrap()),
            ("worker_b".to_string(), read_events(wb_tl.dir()).unwrap()),
        ];
        let merged = merge_records(&sources);
        let views = trace_views(&merged);

        // The routed decode: exactly one trace carries a checkout span.
        let decode = views
            .iter()
            .filter(|v| v.spans.iter().any(|s| s.stage == "checkout"))
            .collect::<Vec<_>>();
        assert_eq!(decode.len(), 1, "exactly one decode went through");
        let decode = decode[0];
        assert!(!decode.torn, "every decode span must have closed");
        let rt_exec = decode
            .spans
            .iter()
            .find(|s| s.source == "router" && s.stage == "execute")
            .expect("router execute span");
        let worker_spans: Vec<_> = decode
            .spans
            .iter()
            .filter(|s| s.source.starts_with("worker"))
            .collect();
        assert!(
            !worker_spans.is_empty(),
            "the decode tree must cross into a worker process"
        );
        for s in &worker_spans {
            assert_eq!(
                s.parent, rt_exec.span,
                "worker {} span must be a child of the router execute span",
                s.stage
            );
        }
        let worker_stages: std::collections::BTreeSet<&str> =
            worker_spans.iter().map(|s| s.stage.as_str()).collect();
        assert!(worker_stages.contains("execute"));
        // Stage attribution stays inside the causal envelope: the
        // router-side stages sum within the client's wall clock, and
        // the worker-side stages nest inside the router execute span.
        let rt_sum: u64 = decode
            .spans
            .iter()
            .filter(|s| s.source == "router" && s.parent == 0)
            .map(|s| s.us.unwrap())
            .sum();
        assert!(
            rt_sum <= envelope_us,
            "router stages ({rt_sum}us) exceed the wall clock \
             ({envelope_us}us)"
        );
        let worker_sum: u64 =
            worker_spans.iter().map(|s| s.us.unwrap()).sum();
        assert!(
            worker_sum <= rt_exec.us.unwrap(),
            "worker stages ({worker_sum}us) exceed the router execute \
             span ({}us)",
            rt_exec.us.unwrap()
        );

        // The migration: a router-originated root span whose children
        // (the export/import/verify/release hops) span both workers.
        let migrate = views
            .iter()
            .find(|v| v.spans.iter().any(|s| s.stage == "migrate"))
            .expect("the migration trace");
        assert!(!migrate.torn);
        let root = migrate
            .spans
            .iter()
            .find(|s| s.stage == "migrate")
            .unwrap();
        assert_eq!(root.source, "router");
        assert!(root.detail.contains(&format!("session={sid}")));
        let hops: Vec<_> = migrate
            .spans
            .iter()
            .filter(|s| s.parent == root.span && s.stage == "execute")
            .collect();
        let hop_verbs: std::collections::BTreeSet<&str> =
            hops.iter().map(|s| s.detail.as_str()).collect();
        assert!(hop_verbs.contains("export"), "hops: {hop_verbs:?}");
        assert!(hop_verbs.contains("import"), "hops: {hop_verbs:?}");
        let hop_sources: std::collections::BTreeSet<&str> =
            hops.iter().map(|s| s.source.as_str()).collect();
        assert_eq!(
            hop_sources.len(),
            2,
            "the migration must touch both workers: {hop_sources:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

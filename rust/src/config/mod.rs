//! Typed run configuration, loadable from JSON and overridable from the
//! CLI. One `RunConfig` drives the launcher (`hmm-scan` subcommands),
//! the figure benches, and the examples, so experiment parameters live
//! in exactly one place.

use std::path::PathBuf;

use crate::coordinator::BatcherConfig;
use crate::error::Result;
use crate::hmm::GeParams;
use crate::jsonx::Json;
use crate::scan::ScanOptions;

/// Global run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Gilbert–Elliott channel parameters (the paper's workload).
    pub ge: GeParams,
    /// RNG seed for workload generation.
    pub seed: u64,
    /// T sweep for the figure benches (paper: 10²…10⁵ log grid).
    pub t_grid: Vec<usize>,
    /// Threads for the native parallel algorithms.
    pub threads: usize,
    /// §V-B block length used by native block-wise runs.
    pub block_len: usize,
    /// Output directory for figures/CSVs.
    pub out_dir: PathBuf,
    /// XLA worker count for the coordinator.
    pub xla_workers: usize,
    /// Batching policy.
    pub batcher: BatcherConfig,
    /// Durable session-store directory for streaming serving
    /// (`None` = in-memory spill only, nothing survives the process).
    pub session_store: Option<PathBuf>,
    /// Resident-session watermark for the streaming coordinator.
    pub resident_watermark: usize,
    /// Group-commit fsync deadline window, microseconds (0 = one fsync
    /// per logged append).
    pub group_commit_us: u64,
    /// Run spills/compactions on the background housekeeping worker
    /// (`false` = in-band on the serve path).
    pub housekeeping: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            ge: GeParams::default(),
            seed: 0xC0FFEE,
            // Paper §VI: T from 1e2 to 1e5; half-decade log grid.
            t_grid: vec![100, 316, 1000, 3162, 10_000, 31_623, 100_000],
            threads: crate::exec::default_parallelism(),
            block_len: 1024,
            out_dir: PathBuf::from("results"),
            xla_workers: 4,
            batcher: BatcherConfig::default(),
            session_store: None,
            resident_watermark: 1024,
            group_commit_us: 200,
            housekeeping: true,
        }
    }
}

impl RunConfig {
    /// Load overrides from a JSON file (missing keys keep defaults).
    pub fn from_json_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&text)
    }

    /// Parse overrides from a JSON string (missing keys keep defaults).
    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let mut c = Self::default();
        if let Some(g) = v.get("ge").as_obj() {
            let f = |k: &str, d: f64| g.get(k).and_then(|x| x.as_f64()).unwrap_or(d);
            c.ge = GeParams {
                p0: f("p0", c.ge.p0),
                p1: f("p1", c.ge.p1),
                p2: f("p2", c.ge.p2),
                q0: f("q0", c.ge.q0),
                q1: f("q1", c.ge.q1),
            };
        }
        if let Some(s) = v.get("seed").as_f64() {
            c.seed = s as u64;
        }
        if let Some(grid) = v.get("t_grid").as_arr() {
            c.t_grid = grid.iter().filter_map(|x| x.as_usize()).collect();
        }
        if let Some(t) = v.get("threads").as_usize() {
            c.threads = t.max(1);
        }
        if let Some(b) = v.get("block_len").as_usize() {
            c.block_len = b.max(1);
        }
        if let Some(o) = v.get("out_dir").as_str() {
            c.out_dir = PathBuf::from(o);
        }
        if let Some(w) = v.get("xla_workers").as_usize() {
            c.xla_workers = w.max(1);
        }
        if let Some(ms) = v.get("batch_window_ms").as_f64() {
            c.batcher.max_delay = std::time::Duration::from_micros((ms * 1e3) as u64);
        }
        if let Some(mb) = v.get("max_batch").as_usize() {
            c.batcher.max_batch = mb.max(1);
        }
        if let Some(dir) = v.get("session_store").as_str() {
            c.session_store =
                (!dir.is_empty()).then(|| PathBuf::from(dir));
        }
        if let Some(w) = v.get("resident_watermark").as_usize() {
            c.resident_watermark = w;
        }
        if let Some(us) = v.get("group_commit_us").as_usize() {
            c.group_commit_us = us as u64;
        }
        if let Some(hk) = v.get("housekeeping").as_bool() {
            c.housekeeping = hk;
        }
        Ok(c)
    }

    /// Coordinator configuration derived from the serving knobs here
    /// (callers overlay artifacts/worker settings as needed).
    pub fn coordinator_config(&self) -> crate::coordinator::CoordinatorConfig {
        crate::coordinator::CoordinatorConfig {
            xla_workers: self.xla_workers,
            batcher: self.batcher,
            scan: self.scan_options(),
            session_store: self.session_store.clone(),
            resident_watermark: self.resident_watermark,
            group_commit_window: std::time::Duration::from_micros(
                self.group_commit_us,
            ),
            housekeeping: self.housekeeping,
            ..crate::coordinator::CoordinatorConfig::default()
        }
    }

    /// Scan options derived from the thread setting.
    pub fn scan_options(&self) -> ScanOptions {
        ScanOptions { threads: self.threads, ..ScanOptions::default() }
    }

    /// Serialize the effective configuration (for results provenance).
    pub fn to_json(&self) -> Json {
        crate::jsonx::obj([
            (
                "ge",
                crate::jsonx::obj([
                    ("p0", self.ge.p0.into()),
                    ("p1", self.ge.p1.into()),
                    ("p2", self.ge.p2.into()),
                    ("q0", self.ge.q0.into()),
                    ("q1", self.ge.q1.into()),
                ]),
            ),
            ("seed", (self.seed as usize).into()),
            ("t_grid", self.t_grid.clone().into()),
            ("threads", self.threads.into()),
            ("block_len", self.block_len.into()),
            ("out_dir", self.out_dir.display().to_string().into()),
            ("xla_workers", self.xla_workers.into()),
            (
                "session_store",
                match &self.session_store {
                    Some(dir) => Json::Str(dir.display().to_string()),
                    None => Json::Str(String::new()),
                },
            ),
            ("resident_watermark", self.resident_watermark.into()),
            ("group_commit_us", (self.group_commit_us as usize).into()),
            ("housekeeping", Json::Bool(self.housekeeping)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_setup() {
        let c = RunConfig::default();
        assert_eq!(c.ge, GeParams::default());
        assert_eq!(c.t_grid.first(), Some(&100));
        assert_eq!(c.t_grid.last(), Some(&100_000));
    }

    #[test]
    fn json_overrides() {
        let c = RunConfig::from_json(
            r#"{"ge": {"p0": 0.5}, "seed": 7, "t_grid": [10, 20],
                "threads": 2, "out_dir": "/tmp/x", "max_batch": 3}"#,
        )
        .unwrap();
        assert_eq!(c.ge.p0, 0.5);
        assert_eq!(c.ge.p1, GeParams::default().p1); // untouched
        assert_eq!(c.seed, 7);
        assert_eq!(c.t_grid, vec![10, 20]);
        assert_eq!(c.threads, 2);
        assert_eq!(c.out_dir, PathBuf::from("/tmp/x"));
        assert_eq!(c.batcher.max_batch, 3);
    }

    #[test]
    fn round_trip_through_json() {
        let c = RunConfig::default();
        let text = c.to_json().to_string_pretty();
        let back = RunConfig::from_json(&text).unwrap();
        assert_eq!(back.ge, c.ge);
        assert_eq!(back.t_grid, c.t_grid);
        assert_eq!(back.seed, c.seed);
        assert_eq!(back.session_store, c.session_store);
        assert_eq!(back.resident_watermark, c.resident_watermark);
        assert_eq!(back.group_commit_us, c.group_commit_us);
        assert_eq!(back.housekeeping, c.housekeeping);
    }

    #[test]
    fn store_knobs_override_and_flow_into_coordinator_config() {
        let c = RunConfig::from_json(
            r#"{"session_store": "/tmp/store", "resident_watermark": 7,
                "group_commit_us": 500, "housekeeping": false}"#,
        )
        .unwrap();
        assert_eq!(c.session_store, Some(PathBuf::from("/tmp/store")));
        assert_eq!(c.resident_watermark, 7);
        assert_eq!(c.group_commit_us, 500);
        assert!(!c.housekeeping);
        let cc = c.coordinator_config();
        assert_eq!(cc.session_store, Some(PathBuf::from("/tmp/store")));
        assert_eq!(cc.resident_watermark, 7);
        assert_eq!(
            cc.group_commit_window,
            std::time::Duration::from_micros(500)
        );
        assert!(!cc.housekeeping);
        // An empty string means "no store" (the CLI's disable value).
        let c = RunConfig::from_json(r#"{"session_store": ""}"#).unwrap();
        assert_eq!(c.session_store, None);
    }

    #[test]
    fn rejects_invalid_json() {
        assert!(RunConfig::from_json("{nope").is_err());
    }
}

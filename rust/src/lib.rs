//! # hmm-scan
//!
//! Temporal parallelization of inference in hidden Markov models —
//! a Rust + JAX + Pallas reproduction of Hassan, Särkkä &
//! García-Fernández, *IEEE TSP* 2021 (DOI 10.1109/TSP.2021.3103338).
//!
//! The crate is organized in three groups (see DESIGN.md):
//!
//! * **Algorithm library** — [`semiring`], [`linalg`], [`scan`],
//!   [`hmm`], [`elements`], [`inference`], [`blockwise`]: native-Rust
//!   implementations of every algorithm the paper benchmarks, used for
//!   verification, CPU baselines and the figure benches.
//! * **Serving runtime** — [`engine`] (the unified inference API: one
//!   entry point for all nine algorithms, pluggable backends, reusable
//!   workspaces, and streaming [`engine::Session`]s over checkpointed
//!   scans), [`store`] (the durable session store: disk spill, LRU
//!   eviction and crash recovery under the streaming coordinator),
//!   [`runtime`] (PJRT artifact loading and execution),
//!   [`coordinator`] (router, batcher, temporal sharder): the L3 layer
//!   that serves inference requests over the AOT-compiled XLA artifacts
//!   produced by `python/compile/aot.py`, and [`net`] (the L4 network
//!   layer: TCP front-end, versioned wire protocol, and client — what
//!   turns the coordinator into a deployable server), and [`cluster`]
//!   (the L5 distributed tier: consistent-hash session router, worker
//!   pool with health-driven failover, and live session migration),
//!   plus [`obs`] (the observability tier: replayable event-sourced
//!   timeline, wire-scrapable metrics, deadline/quota load shedding).
//! * **Substrates** — [`rng`], [`jsonx`], [`exec`], [`cli`], [`benchx`],
//!   [`proptestx`], [`report`], [`config`], [`simulator`], [`xla_stub`]:
//!   in-tree replacements for crates unavailable in the offline build
//!   environment plus the work-span GPU simulator used for Figs. 4–6.

// Public API documentation is enforced: `cargo doc --no-deps` runs in
// CI with `RUSTDOCFLAGS="-D warnings"`, so an undocumented public item
// or a broken intra-doc link fails the build there.
#![warn(missing_docs)]

pub mod benchx;
pub mod blockwise;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod elements;
pub mod engine;
pub mod error;
pub mod experiments;
pub mod exec;
pub mod hmm;
pub mod inference;
pub mod jsonx;
pub mod kalman;
pub mod linalg;
pub mod net;
pub mod obs;
pub mod proptestx;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod scan;
pub mod semiring;
pub mod simulator;
pub mod store;
pub mod xla_stub;

pub use error::{Error, Result};

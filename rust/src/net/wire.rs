//! The versioned wire protocol — framing and payload serde for the TCP
//! serving layer.
//!
//! The byte-level contract is **specified** in `docs/WIRE_FORMAT.md`;
//! this module is one reader/writer of it. Summary:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "HMWP"
//! 4       1     protocol version (4; readers accept 1..=4)
//! 5       1     frame kind (see [`FrameKind`])
//! 6       2     reserved (zero)
//! 8       8     request id, u64 little-endian (echoed in the response)
//! 16      4     payload length, u32 little-endian
//! 20      8     FNV-1a 64 checksum of the payload, little-endian
//! 28      len   payload — compact JSON, UTF-8
//! ```
//!
//! Decoding is defensive end to end: bad magic, a newer version, an
//! unknown kind, an oversized length, a short read, a checksum mismatch
//! or unparsable JSON are all *typed errors*, never panics — the server
//! treats them as connection-fatal (framing cannot be resynchronized),
//! while a well-framed request with a malformed payload only fails that
//! request. Numeric payloads reuse the packed hex encodings of
//! [`elements::serde`](crate::elements::serde) (bit-exact f64 round
//! trips), so a decode served over the wire is **bit-identical** to the
//! same request served in-process — the loopback tests assert exactly
//! that.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::time::Duration;

use crate::coordinator::{
    Algo, DecodeRequest, DecodeResponse, DecodeResult, ExecMode, StreamReply,
    StreamRequest, StreamResponse, StreamVerb,
};
use crate::elements::serde::{f64s_from_hex, f64s_to_hex, obs_from_json, obs_to_json};
use crate::engine::{Filtered, LagSmoothed, SessionKind, SessionOptions};
use crate::error::{Error, Result};
use crate::inference::{MapEstimate, Posterior};
use crate::jsonx::Json;
use crate::store::SessionMeta;

/// Current wire-protocol revision; readers reject frames stamped with a
/// newer version (and accept every older one — v2 added the
/// [`FrameKind::Reject`] frame and the cluster-router stream verbs; v3
/// added the metrics scrape pair [`FrameKind::ScrapeRequest`] /
/// [`FrameKind::ScrapeResponse`] and the optional per-request
/// `deadline_ms` payload field; v4 adds the optional per-request
/// `trace` payload field ([`TraceContext`]) — all additive, no older
/// encoding changed).
pub const WIRE_VERSION: u8 = 4;

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"HMWP";

/// Fixed binary header length (see the module docs for the layout).
pub const HEADER_LEN: usize = 28;

/// Default ceiling on a frame's payload length (64 MiB) — a garbage or
/// hostile length field is rejected before any allocation happens.
pub const DEFAULT_MAX_PAYLOAD: usize = 1 << 26;

/// The framing checksum: fresh-start FNV-1a 64 (same function the
/// session store frames with).
fn fnv64(bytes: &[u8]) -> u64 {
    crate::rng::fnv1a_64(crate::rng::FNV1A_OFFSET, bytes)
}

/// What a frame carries. Requests flow client → server; responses (and
/// [`FrameKind::Error`]) flow back, carrying the request's id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// A [`DecodeRequest`] payload.
    DecodeRequest,
    /// A [`StreamRequest`] payload (open / append / stat / close).
    StreamRequest,
    /// Liveness / handshake probe (null payload).
    Ping,
    /// Metrics scrape request (v3, null payload): ask the server for
    /// its full metrics snapshot rendered as stable `key value` text.
    ScrapeRequest,
    /// A [`DecodeResponse`] payload.
    DecodeResponse,
    /// A [`StreamResponse`] payload.
    StreamResponse,
    /// Reply to [`FrameKind::Ping`] (null payload).
    Pong,
    /// Reply to [`FrameKind::ScrapeRequest`] (v3): `{"text": ..}`, the
    /// scrape body in the line format of
    /// [`MetricsSnapshot::render_text`](crate::coordinator::MetricsSnapshot::render_text).
    ScrapeResponse,
    /// Typed admission rejection (v2): the request was refused because
    /// of transient overload (connection limit, drain, saturated worker
    /// pool), with a retry hint — `{"retry_after_ms": .., "msg": ..}`.
    /// Unlike [`FrameKind::Error`], this is an explicit *back off and
    /// retry* signal, never a request failure.
    Reject,
    /// A serialized [`Error`] payload (`{"code": .., "msg": ..}`).
    Error,
}

impl FrameKind {
    /// Every kind, for exhaustive round-trip tests.
    pub const ALL: [FrameKind; 10] = [
        FrameKind::DecodeRequest,
        FrameKind::StreamRequest,
        FrameKind::Ping,
        FrameKind::ScrapeRequest,
        FrameKind::DecodeResponse,
        FrameKind::StreamResponse,
        FrameKind::Pong,
        FrameKind::ScrapeResponse,
        FrameKind::Reject,
        FrameKind::Error,
    ];

    /// The header byte for this kind.
    pub fn code(self) -> u8 {
        match self {
            FrameKind::DecodeRequest => 0x01,
            FrameKind::StreamRequest => 0x02,
            FrameKind::Ping => 0x03,
            FrameKind::ScrapeRequest => 0x04,
            FrameKind::DecodeResponse => 0x81,
            FrameKind::StreamResponse => 0x82,
            FrameKind::Pong => 0x83,
            FrameKind::Reject => 0x84,
            FrameKind::ScrapeResponse => 0x85,
            FrameKind::Error => 0xee,
        }
    }

    /// Inverse of [`code`](Self::code).
    pub fn from_code(code: u8) -> Option<FrameKind> {
        FrameKind::ALL.into_iter().find(|k| k.code() == code)
    }

    /// Whether this kind flows server → client.
    pub fn is_response(self) -> bool {
        matches!(
            self,
            FrameKind::DecodeResponse
                | FrameKind::StreamResponse
                | FrameKind::Pong
                | FrameKind::ScrapeResponse
                | FrameKind::Reject
                | FrameKind::Error
        )
    }
}

/// One decoded frame: the echoed request id, the kind, and the parsed
/// JSON payload.
#[derive(Debug, Clone)]
pub struct Frame {
    /// Request id (client-chosen; echoed verbatim in responses).
    pub id: u64,
    /// What the payload is.
    pub kind: FrameKind,
    /// The payload ([`Json::Null`] for ping/pong).
    pub payload: Json,
}

/// Encode one frame to bytes (header + compact-JSON payload).
pub fn encode_frame(id: u64, kind: FrameKind, payload: &Json) -> Vec<u8> {
    let body = payload.to_string_compact().into_bytes();
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(WIRE_VERSION);
    out.push(kind.code());
    out.extend_from_slice(&[0u8; 2]);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv64(&body).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Write one frame (no flush — callers batch and flush).
pub fn write_frame(
    w: &mut impl Write,
    id: u64,
    kind: FrameKind,
    payload: &Json,
) -> Result<()> {
    w.write_all(&encode_frame(id, kind, payload))?;
    Ok(())
}

/// Parsed fixed header fields.
struct Header {
    id: u64,
    kind: FrameKind,
    len: usize,
    sum: u64,
}

fn parse_header(h: &[u8; HEADER_LEN], max_payload: usize) -> Result<Header> {
    if h[0..4] != MAGIC {
        return Err(Error::invalid_request("wire: bad frame magic"));
    }
    if h[4] == 0 || h[4] > WIRE_VERSION {
        return Err(Error::invalid_request(format!(
            "wire: protocol version {} is not supported (max {WIRE_VERSION})",
            h[4]
        )));
    }
    let kind = FrameKind::from_code(h[5]).ok_or_else(|| {
        Error::invalid_request(format!("wire: unknown frame kind 0x{:02x}", h[5]))
    })?;
    if h[6] != 0 || h[7] != 0 {
        return Err(Error::invalid_request("wire: nonzero reserved bytes"));
    }
    let id = u64::from_le_bytes(h[8..16].try_into().expect("8 bytes"));
    let len = u32::from_le_bytes(h[16..20].try_into().expect("4 bytes")) as usize;
    if len > max_payload {
        return Err(Error::invalid_request(format!(
            "wire: frame payload of {len} bytes exceeds the {max_payload} cap"
        )));
    }
    let sum = u64::from_le_bytes(h[20..28].try_into().expect("8 bytes"));
    Ok(Header { id, kind, len, sum })
}

/// Read one complete frame. Every structural violation — short read,
/// bad magic, future version, unknown kind, oversized or checksum-failed
/// payload, non-JSON body — is a typed error (the caller treats it as
/// connection-fatal; framing cannot resynchronize after garbage).
pub fn read_frame(r: &mut impl Read, max_payload: usize) -> Result<Frame> {
    let mut h = [0u8; HEADER_LEN];
    r.read_exact(&mut h)?;
    let header = parse_header(&h, max_payload)?;
    let mut body = vec![0u8; header.len];
    r.read_exact(&mut body)?;
    if fnv64(&body) != header.sum {
        return Err(Error::invalid_request("wire: frame checksum mismatch"));
    }
    let text = std::str::from_utf8(&body)
        .map_err(|_| Error::invalid_request("wire: non-UTF-8 frame payload"))?;
    let payload =
        if text.is_empty() { Json::Null } else { Json::parse(text)? };
    Ok(Frame { id: header.id, kind: header.kind, payload })
}

// ===========================================================================
// Payload serde — requests
// ===========================================================================

fn exec_mode_name(mode: ExecMode) -> &'static str {
    match mode {
        ExecMode::Auto => "auto",
        ExecMode::Native => "native",
        ExecMode::Pjrt => "pjrt",
        ExecMode::Sharded => "sharded",
    }
}

fn exec_mode_parse(s: &str) -> Option<ExecMode> {
    match s {
        "auto" => Some(ExecMode::Auto),
        "native" => Some(ExecMode::Native),
        "pjrt" => Some(ExecMode::Pjrt),
        "sharded" => Some(ExecMode::Sharded),
        _ => None,
    }
}

fn req_u64(v: &Json, key: &str, what: &str) -> Result<u64> {
    v.get(key)
        .as_usize()
        .map(|u| u as u64)
        .ok_or_else(|| Error::invalid_request(format!("{what}: missing '{key}'")))
}

/// [`DecodeRequest`] → wire payload. The request id travels in the
/// frame header, not the payload.
pub fn decode_request_to_json(req: &DecodeRequest) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("model".to_string(), Json::Str(req.model.clone()));
    obj.insert("ys".to_string(), obs_to_json(&req.ys));
    obj.insert("algo".to_string(), req.algo.to_json());
    obj.insert(
        "mode".to_string(),
        Json::Str(exec_mode_name(req.mode).to_string()),
    );
    Json::Obj(obj)
}

/// Inverse of [`decode_request_to_json`]; `id` is the frame header's
/// request id.
pub fn decode_request_from_json(id: u64, v: &Json) -> Result<DecodeRequest> {
    let model = v
        .get("model")
        .as_str()
        .ok_or_else(|| Error::invalid_request("decode request: missing 'model'"))?
        .to_string();
    let ys = match v.get("ys") {
        Json::Null => {
            return Err(Error::invalid_request("decode request: missing 'ys'"))
        }
        obs => obs_from_json(obs)?,
    };
    let algo = Algo::from_json(v.get("algo")).ok_or_else(|| {
        Error::invalid_request("decode request: missing or unknown 'algo'")
    })?;
    let mode = match v.get("mode") {
        Json::Null => ExecMode::Auto,
        m => m.as_str().and_then(exec_mode_parse).ok_or_else(|| {
            Error::invalid_request("decode request: unknown 'mode'")
        })?,
    };
    Ok(DecodeRequest { id, model, ys, algo, mode })
}

/// [`StreamRequest`] → wire payload (the verb object).
pub fn stream_request_to_json(req: &StreamRequest) -> Json {
    let mut obj = BTreeMap::new();
    match &req.verb {
        StreamVerb::Open { model, options, lag } => {
            obj.insert("verb".to_string(), Json::Str("open".to_string()));
            obj.insert("model".to_string(), Json::Str(model.clone()));
            obj.insert(
                "block".to_string(),
                options.block.map_or(Json::Null, |b| Json::Num(b as f64)),
            );
            obj.insert("track_map".to_string(), Json::Bool(options.track_map));
            obj.insert(
                "kind".to_string(),
                Json::Str(options.kind.name().to_string()),
            );
            obj.insert("lag".to_string(), Json::Num(*lag as f64));
        }
        StreamVerb::Append { session, ys } => {
            obj.insert("verb".to_string(), Json::Str("append".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
            obj.insert("ys".to_string(), obs_to_json(ys));
        }
        StreamVerb::Stat { session } => {
            obj.insert("verb".to_string(), Json::Str("stat".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
        }
        StreamVerb::Close { session } => {
            obj.insert("verb".to_string(), Json::Str("close".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
        }
        StreamVerb::OpenAt { session, model, options, lag } => {
            obj.insert("verb".to_string(), Json::Str("open_at".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
            obj.insert("model".to_string(), Json::Str(model.clone()));
            obj.insert(
                "block".to_string(),
                options.block.map_or(Json::Null, |b| Json::Num(b as f64)),
            );
            obj.insert("track_map".to_string(), Json::Bool(options.track_map));
            obj.insert(
                "kind".to_string(),
                Json::Str(options.kind.name().to_string()),
            );
            obj.insert("lag".to_string(), Json::Num(*lag as f64));
        }
        StreamVerb::Export { session } => {
            obj.insert("verb".to_string(), Json::Str("export".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
        }
        StreamVerb::Import { session, meta, snapshot } => {
            obj.insert("verb".to_string(), Json::Str("import".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
            obj.insert("meta".to_string(), meta.to_json());
            obj.insert("snapshot".to_string(), snapshot.clone());
        }
        StreamVerb::Release { session } => {
            obj.insert("verb".to_string(), Json::Str("release".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
        }
    }
    Json::Obj(obj)
}

/// Inverse of [`stream_request_to_json`]; `id` is the frame header's
/// request id.
pub fn stream_request_from_json(id: u64, v: &Json) -> Result<StreamRequest> {
    let verb = match v.get("verb").as_str() {
        Some("open") => {
            let model = v
                .get("model")
                .as_str()
                .ok_or_else(|| {
                    Error::invalid_request("stream open: missing 'model'")
                })?
                .to_string();
            let block = match v.get("block") {
                Json::Null => None,
                b => Some(b.as_usize().ok_or_else(|| {
                    Error::invalid_request("stream open: invalid 'block'")
                })?),
            };
            let track_map = v.get("track_map").as_bool().unwrap_or(false);
            let kind = match v.get("kind") {
                Json::Null => SessionKind::SumProduct,
                k => k.as_str().and_then(SessionKind::parse).ok_or_else(|| {
                    Error::invalid_request("stream open: unknown 'kind'")
                })?,
            };
            let lag = v.get("lag").as_usize().unwrap_or(0);
            StreamVerb::Open {
                model,
                options: SessionOptions { block, track_map, kind },
                lag,
            }
        }
        Some("append") => {
            let session = req_u64(v, "session", "stream append")?;
            let ys = match v.get("ys") {
                Json::Null => Vec::new(),
                obs => obs_from_json(obs)?,
            };
            StreamVerb::Append { session, ys }
        }
        Some("stat") => {
            StreamVerb::Stat { session: req_u64(v, "session", "stream stat")? }
        }
        Some("close") => {
            StreamVerb::Close { session: req_u64(v, "session", "stream close")? }
        }
        Some("open_at") => {
            let session = req_u64(v, "session", "stream open_at")?;
            let model = v
                .get("model")
                .as_str()
                .ok_or_else(|| {
                    Error::invalid_request("stream open_at: missing 'model'")
                })?
                .to_string();
            let block = match v.get("block") {
                Json::Null => None,
                b => Some(b.as_usize().ok_or_else(|| {
                    Error::invalid_request("stream open_at: invalid 'block'")
                })?),
            };
            let track_map = v.get("track_map").as_bool().unwrap_or(false);
            let kind = match v.get("kind") {
                Json::Null => SessionKind::SumProduct,
                k => k.as_str().and_then(SessionKind::parse).ok_or_else(|| {
                    Error::invalid_request("stream open_at: unknown 'kind'")
                })?,
            };
            let lag = v.get("lag").as_usize().unwrap_or(0);
            StreamVerb::OpenAt {
                session,
                model,
                options: SessionOptions { block, track_map, kind },
                lag,
            }
        }
        Some("export") => StreamVerb::Export {
            session: req_u64(v, "session", "stream export")?,
        },
        Some("import") => {
            let session = req_u64(v, "session", "stream import")?;
            let meta = SessionMeta::from_json(v.get("meta"))?;
            let snapshot = match v.get("snapshot") {
                Json::Null => {
                    return Err(Error::invalid_request(
                        "stream import: missing 'snapshot'",
                    ))
                }
                s => s.clone(),
            };
            StreamVerb::Import { session, meta, snapshot }
        }
        Some("release") => StreamVerb::Release {
            session: req_u64(v, "session", "stream release")?,
        },
        _ => {
            return Err(Error::invalid_request(
                "stream request: missing or unknown 'verb'",
            ))
        }
    };
    Ok(StreamRequest { id, verb })
}

// ===========================================================================
// Payload serde — results and responses
// ===========================================================================

/// [`Posterior`] → `{"d": D, "loglik": .., "gamma": "<hex-f64>"}` —
/// hex-f64 marginals keep the wire round trip bit-exact.
pub fn posterior_to_json(p: &Posterior) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("d".to_string(), Json::Num(p.num_states() as f64));
    obj.insert("loglik".to_string(), Json::Num(p.log_likelihood()));
    obj.insert("gamma".to_string(), Json::Str(f64s_to_hex(p.gamma_flat())));
    Json::Obj(obj)
}

/// Inverse of [`posterior_to_json`]; shape-validated so a malformed
/// payload is a typed error, not a downstream panic.
pub fn posterior_from_json(v: &Json) -> Result<Posterior> {
    let d = v
        .get("d")
        .as_usize()
        .filter(|&d| d > 0)
        .ok_or_else(|| Error::invalid_request("posterior: missing 'd'"))?;
    let loglik = v
        .get("loglik")
        .as_f64()
        .ok_or_else(|| Error::invalid_request("posterior: missing 'loglik'"))?;
    let gamma = match v.get("gamma") {
        Json::Str(s) => f64s_from_hex(s)?,
        _ => return Err(Error::invalid_request("posterior: missing 'gamma'")),
    };
    if gamma.len() % d != 0 {
        return Err(Error::invalid_request(format!(
            "posterior: {} marginals for {d} states",
            gamma.len()
        )));
    }
    Ok(Posterior::new(d, gamma, loglik))
}

fn map_to_json(m: &MapEstimate) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("path".to_string(), obs_to_json(&m.path));
    obj.insert("log_prob".to_string(), Json::Num(m.log_prob));
    Json::Obj(obj)
}

fn map_from_json(v: &Json) -> Result<MapEstimate> {
    let path = match v.get("path") {
        Json::Null => {
            return Err(Error::invalid_request("map estimate: missing 'path'"))
        }
        p => obs_from_json(p)?,
    };
    let log_prob = v.get("log_prob").as_f64().ok_or_else(|| {
        Error::invalid_request("map estimate: missing 'log_prob'")
    })?;
    Ok(MapEstimate { path, log_prob })
}

fn decode_result_to_json(r: &DecodeResult) -> Json {
    let mut obj = BTreeMap::new();
    match r {
        DecodeResult::Posterior(p) => {
            obj.insert("type".to_string(), Json::Str("posterior".to_string()));
            obj.insert("posterior".to_string(), posterior_to_json(p));
        }
        DecodeResult::Map(m) => {
            obj.insert("type".to_string(), Json::Str("map".to_string()));
            obj.insert("map".to_string(), map_to_json(m));
        }
    }
    Json::Obj(obj)
}

fn decode_result_from_json(v: &Json) -> Result<DecodeResult> {
    match v.get("type").as_str() {
        Some("posterior") => {
            Ok(DecodeResult::Posterior(posterior_from_json(v.get("posterior"))?))
        }
        Some("map") => Ok(DecodeResult::Map(map_from_json(v.get("map"))?)),
        _ => Err(Error::invalid_request("decode result: unknown 'type'")),
    }
}

/// [`DecodeResponse`] → wire payload (the id travels in the frame).
pub fn decode_response_to_json(resp: &DecodeResponse) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("plan".to_string(), Json::Str(resp.plan.clone()));
    obj.insert(
        "elapsed_us".to_string(),
        Json::Num(resp.elapsed.as_micros().min(u128::from(u64::MAX)) as f64),
    );
    obj.insert("result".to_string(), decode_result_to_json(&resp.result));
    Json::Obj(obj)
}

/// Inverse of [`decode_response_to_json`].
pub fn decode_response_from_json(id: u64, v: &Json) -> Result<DecodeResponse> {
    let plan = v
        .get("plan")
        .as_str()
        .ok_or_else(|| Error::invalid_request("decode response: missing 'plan'"))?
        .to_string();
    let elapsed =
        Duration::from_micros(v.get("elapsed_us").as_f64().unwrap_or(0.0) as u64);
    let result = decode_result_from_json(v.get("result"))?;
    Ok(DecodeResponse { id, result, plan, elapsed })
}

fn filtered_to_json(f: &Filtered) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("probs".to_string(), Json::Str(f64s_to_hex(&f.probs)));
    obj.insert("loglik".to_string(), Json::Num(f.log_likelihood));
    obj.insert("step".to_string(), Json::Num(f.step as f64));
    Json::Obj(obj)
}

fn filtered_from_json(v: &Json) -> Result<Filtered> {
    let probs = match v.get("probs") {
        Json::Str(s) => f64s_from_hex(s)?,
        _ => return Err(Error::invalid_request("filtered: missing 'probs'")),
    };
    let log_likelihood = v
        .get("loglik")
        .as_f64()
        .ok_or_else(|| Error::invalid_request("filtered: missing 'loglik'"))?;
    let step = v
        .get("step")
        .as_usize()
        .ok_or_else(|| Error::invalid_request("filtered: missing 'step'"))?;
    Ok(Filtered { probs, log_likelihood, step })
}

fn lag_smoothed_to_json(w: &LagSmoothed) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("start".to_string(), Json::Num(w.start as f64));
    obj.insert("posterior".to_string(), posterior_to_json(&w.posterior));
    obj.insert("rescan_width".to_string(), Json::Num(w.rescan_width as f64));
    Json::Obj(obj)
}

fn lag_smoothed_from_json(v: &Json) -> Result<LagSmoothed> {
    let start = v
        .get("start")
        .as_usize()
        .ok_or_else(|| Error::invalid_request("lag window: missing 'start'"))?;
    let posterior = posterior_from_json(v.get("posterior"))?;
    let rescan_width = v.get("rescan_width").as_usize().unwrap_or(0);
    Ok(LagSmoothed { start, posterior, rescan_width })
}

fn stream_reply_to_json(reply: &StreamReply) -> Json {
    let mut obj = BTreeMap::new();
    match reply {
        StreamReply::Opened { session } => {
            obj.insert("reply".to_string(), Json::Str("opened".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
        }
        StreamReply::Appended { session, len, filtered, window, plan_hint } => {
            obj.insert("reply".to_string(), Json::Str("appended".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
            obj.insert("len".to_string(), Json::Num(*len as f64));
            obj.insert("filtered".to_string(), filtered_to_json(filtered));
            obj.insert(
                "window".to_string(),
                window.as_ref().map_or(Json::Null, lag_smoothed_to_json),
            );
            obj.insert(
                "plan_hint".to_string(),
                plan_hint
                    .as_ref()
                    .map_or(Json::Null, |h| Json::Str(h.clone())),
            );
        }
        StreamReply::Stats {
            session,
            len,
            resident,
            model,
            open_sessions,
            resident_sessions,
        } => {
            obj.insert("reply".to_string(), Json::Str("stats".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
            obj.insert("len".to_string(), Json::Num(*len as f64));
            obj.insert("resident".to_string(), Json::Bool(*resident));
            obj.insert("model".to_string(), Json::Str(model.clone()));
            obj.insert(
                "open_sessions".to_string(),
                Json::Num(*open_sessions as f64),
            );
            obj.insert(
                "resident_sessions".to_string(),
                Json::Num(*resident_sessions as f64),
            );
        }
        StreamReply::Closed { session, posterior } => {
            obj.insert("reply".to_string(), Json::Str("closed".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
            obj.insert("posterior".to_string(), posterior_to_json(posterior));
        }
        StreamReply::Exported { session, len, meta, snapshot } => {
            obj.insert("reply".to_string(), Json::Str("exported".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
            obj.insert("len".to_string(), Json::Num(*len as f64));
            obj.insert("meta".to_string(), meta.to_json());
            obj.insert("snapshot".to_string(), snapshot.clone());
        }
        StreamReply::Imported { session, len } => {
            obj.insert("reply".to_string(), Json::Str("imported".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
            obj.insert("len".to_string(), Json::Num(*len as f64));
        }
        StreamReply::Released { session } => {
            obj.insert("reply".to_string(), Json::Str("released".to_string()));
            obj.insert("session".to_string(), Json::Num(*session as f64));
        }
    }
    Json::Obj(obj)
}

fn stream_reply_from_json(v: &Json) -> Result<StreamReply> {
    match v.get("reply").as_str() {
        Some("opened") => Ok(StreamReply::Opened {
            session: req_u64(v, "session", "stream reply")?,
        }),
        Some("appended") => Ok(StreamReply::Appended {
            session: req_u64(v, "session", "stream reply")?,
            len: v.get("len").as_usize().ok_or_else(|| {
                Error::invalid_request("stream reply: missing 'len'")
            })?,
            filtered: filtered_from_json(v.get("filtered"))?,
            window: match v.get("window") {
                Json::Null => None,
                w => Some(lag_smoothed_from_json(w)?),
            },
            plan_hint: v.get("plan_hint").as_str().map(str::to_string),
        }),
        Some("stats") => Ok(StreamReply::Stats {
            session: req_u64(v, "session", "stream reply")?,
            len: v.get("len").as_usize().ok_or_else(|| {
                Error::invalid_request("stream reply: missing 'len'")
            })?,
            resident: v.get("resident").as_bool().unwrap_or(false),
            model: v.get("model").as_str().unwrap_or_default().to_string(),
            open_sessions: v.get("open_sessions").as_usize().unwrap_or(0),
            resident_sessions: v.get("resident_sessions").as_usize().unwrap_or(0),
        }),
        Some("closed") => Ok(StreamReply::Closed {
            session: req_u64(v, "session", "stream reply")?,
            posterior: posterior_from_json(v.get("posterior"))?,
        }),
        Some("exported") => Ok(StreamReply::Exported {
            session: req_u64(v, "session", "stream reply")?,
            len: v.get("len").as_usize().ok_or_else(|| {
                Error::invalid_request("stream reply: missing 'len'")
            })?,
            meta: SessionMeta::from_json(v.get("meta"))?,
            snapshot: match v.get("snapshot") {
                Json::Null => {
                    return Err(Error::invalid_request(
                        "stream reply: missing 'snapshot'",
                    ))
                }
                s => s.clone(),
            },
        }),
        Some("imported") => Ok(StreamReply::Imported {
            session: req_u64(v, "session", "stream reply")?,
            len: v.get("len").as_usize().ok_or_else(|| {
                Error::invalid_request("stream reply: missing 'len'")
            })?,
        }),
        Some("released") => Ok(StreamReply::Released {
            session: req_u64(v, "session", "stream reply")?,
        }),
        _ => Err(Error::invalid_request("stream reply: unknown 'reply'")),
    }
}

/// [`StreamResponse`] → wire payload (the id travels in the frame).
pub fn stream_response_to_json(resp: &StreamResponse) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert(
        "elapsed_us".to_string(),
        Json::Num(resp.elapsed.as_micros().min(u128::from(u64::MAX)) as f64),
    );
    obj.insert("reply".to_string(), stream_reply_to_json(&resp.reply));
    Json::Obj(obj)
}

/// Inverse of [`stream_response_to_json`].
pub fn stream_response_from_json(id: u64, v: &Json) -> Result<StreamResponse> {
    let elapsed =
        Duration::from_micros(v.get("elapsed_us").as_f64().unwrap_or(0.0) as u64);
    let reply = stream_reply_from_json(v.get("reply"))?;
    Ok(StreamResponse { id, reply, elapsed })
}

// ===========================================================================
// Payload serde — errors
// ===========================================================================

fn error_code(e: &Error) -> &'static str {
    match e {
        Error::InvalidModel(_) => "invalid_model",
        Error::InvalidRequest(_) => "invalid_request",
        Error::Json { .. } => "json",
        Error::Artifact(_) => "artifact",
        Error::Xla(_) => "xla",
        Error::Coordinator(_) => "coordinator",
        Error::Usage(_) => "usage",
        Error::Busy { .. } => "busy",
        Error::Io(_) => "io",
    }
}

/// [`Error`] → `{"code": .., "msg": ..}` for an error frame. A
/// [`Error::Busy`] additionally carries its `retry_after_ms` hint (the
/// same payload shape a [`FrameKind::Reject`] frame uses).
pub fn error_to_json(e: &Error) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("code".to_string(), Json::Str(error_code(e).to_string()));
    obj.insert("msg".to_string(), Json::Str(e.to_string()));
    if let Error::Busy { retry_after_ms, .. } = e {
        obj.insert(
            "retry_after_ms".to_string(),
            Json::Num(*retry_after_ms as f64),
        );
    }
    Json::Obj(obj)
}

/// Inverse of [`error_to_json`]: reconstruct a typed error from an
/// error frame (best effort — remote IO/JSON details collapse into the
/// message text).
pub fn error_from_json(v: &Json) -> Error {
    let msg = v.get("msg").as_str().unwrap_or("unknown remote error");
    match v.get("code").as_str() {
        Some("invalid_model") => Error::invalid_model(msg),
        Some("invalid_request") => Error::invalid_request(msg),
        Some("artifact") => Error::artifact(msg),
        Some("xla") => Error::xla(msg),
        Some("usage") => Error::usage(msg),
        Some("busy") => Error::busy(
            v.get("retry_after_ms").as_usize().unwrap_or(0) as u64,
            msg,
        ),
        _ => Error::coordinator(format!("remote: {msg}")),
    }
}

/// A [`FrameKind::Reject`] payload: `{"retry_after_ms": .., "msg": ..}`
/// — the typed admission rejection of v2 (connection limit hit, server
/// draining, every cluster worker saturated).
pub fn reject_to_json(retry_after_ms: u64, msg: &str) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert(
        "retry_after_ms".to_string(),
        Json::Num(retry_after_ms as f64),
    );
    obj.insert("msg".to_string(), Json::Str(msg.to_string()));
    Json::Obj(obj)
}

/// Surface a received [`FrameKind::Reject`] payload as the typed
/// [`Error::Busy`] clients retry on.
pub fn busy_from_reject(v: &Json) -> Error {
    Error::busy(
        v.get("retry_after_ms").as_usize().unwrap_or(0) as u64,
        v.get("msg").as_str().unwrap_or("request rejected"),
    )
}

// ===========================================================================
// Payload serde — metrics scrape and overload control (v3)
// ===========================================================================

/// A [`FrameKind::ScrapeResponse`] payload: `{"text": ..}`, the scrape
/// body rendered server-side so every service (coordinator or cluster
/// router) serves the identical stable line format.
pub fn scrape_to_json(text: &str) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("text".to_string(), Json::Str(text.to_string()));
    Json::Obj(obj)
}

/// Inverse of [`scrape_to_json`].
pub fn scrape_text_from_json(v: &Json) -> Result<String> {
    v.get("text")
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| Error::invalid_request("scrape response: missing 'text'"))
}

/// Read the optional per-request `deadline_ms` payload field (v3
/// overload control). Absent or non-numeric means no deadline; `0`
/// means already expired (useful for tests and explicit sheds). The
/// field rides *next to* the request object's own keys — additive, so
/// v2 readers simply ignore it.
pub fn deadline_ms_from_json(v: &Json) -> Option<u64> {
    match v.get("deadline_ms") {
        Json::Null => None,
        d => d.as_usize().map(|ms| ms as u64),
    }
}

/// Stamp `deadline_ms` onto a request payload (client side). Non-object
/// payloads (ping) are returned unchanged.
pub fn with_deadline_ms(payload: Json, deadline_ms: u64) -> Json {
    match payload {
        Json::Obj(mut obj) => {
            obj.insert("deadline_ms".to_string(), Json::Num(deadline_ms as f64));
            Json::Obj(obj)
        }
        other => other,
    }
}

// ===========================================================================
// Payload serde — request tracing (v4)
// ===========================================================================

/// The wire-propagated trace context (v4): which end-to-end request a
/// frame belongs to and which remote span caused it. `NetClient`
/// originates ids; the cluster router forwards its own execute span as
/// `parent_span` when it fans a request out to a worker, which is what
/// stitches the three processes' timelines into one span tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceContext {
    /// Trace id shared by every span of the request (fnv64, non-zero).
    pub trace_id: u64,
    /// Span id of the caller's active span (0 = this request is the
    /// trace root).
    pub parent_span: u64,
}

/// Read the optional `trace` payload field (v4 tracing). Ids are
/// 16-hex-digit strings (a JSON number is an f64 — 53 integer bits —
/// so numeric ids would silently corrupt). Absent or malformed means
/// untraced; like `deadline_ms`, the field rides next to the request
/// object's own keys, so v1..v3 readers simply ignore it.
pub fn trace_from_json(v: &Json) -> Option<TraceContext> {
    let t = v.get("trace");
    let hex = |key: &str| {
        t.get(key)
            .as_str()
            .and_then(|s| u64::from_str_radix(s, 16).ok())
    };
    let trace_id = hex("trace_id")?;
    if trace_id == 0 {
        return None;
    }
    Some(TraceContext { trace_id, parent_span: hex("parent_span")? })
}

/// Stamp a [`TraceContext`] onto a request payload (client side).
/// Non-object payloads (ping) are returned unchanged.
pub fn with_trace(payload: Json, ctx: TraceContext) -> Json {
    match payload {
        Json::Obj(mut obj) => {
            let mut t = BTreeMap::new();
            t.insert(
                "trace_id".to_string(),
                Json::Str(format!("{:016x}", ctx.trace_id)),
            );
            t.insert(
                "parent_span".to_string(),
                Json::Str(format!("{:016x}", ctx.parent_span)),
            );
            obj.insert("trace".to_string(), Json::Obj(t));
            Json::Obj(obj)
        }
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptestx::Runner;

    fn round_frame(id: u64, kind: FrameKind, payload: Json) -> Frame {
        let bytes = encode_frame(id, kind, &payload);
        read_frame(&mut &bytes[..], DEFAULT_MAX_PAYLOAD).unwrap()
    }

    #[test]
    fn frame_round_trip_all_kinds() {
        for kind in FrameKind::ALL {
            let payload = if matches!(
                kind,
                FrameKind::Ping | FrameKind::Pong | FrameKind::ScrapeRequest
            ) {
                Json::Null
            } else {
                Json::parse(r#"{"k": [1, 2.5, "s"]}"#).unwrap()
            };
            let f = round_frame(0xDEAD_BEEF_0000_0001, kind, payload.clone());
            assert_eq!(f.id, 0xDEAD_BEEF_0000_0001);
            assert_eq!(f.kind, kind);
            assert_eq!(f.payload, payload);
            assert_eq!(FrameKind::from_code(kind.code()), Some(kind));
        }
        assert_eq!(FrameKind::from_code(0x55), None);
    }

    #[test]
    fn decoder_rejects_garbage_without_panicking() {
        let good = encode_frame(7, FrameKind::DecodeRequest, &Json::Num(1.0));

        // Truncations at every length short of the full frame.
        for cut in 0..good.len() {
            assert!(
                read_frame(&mut &good[..cut], DEFAULT_MAX_PAYLOAD).is_err(),
                "cut={cut}"
            );
        }
        // A bit flip anywhere breaks magic, version, reserved bytes,
        // length, checksum, or the payload sum. Two fields are
        // structurally opaque: the id (any value is a valid id) and a
        // kind flip that happens to land on another registered code.
        for byte in 0..good.len() {
            for bit in 0..8u8 {
                let mut bad = good.clone();
                bad[byte] ^= 1 << bit;
                let structurally_ok = match byte {
                    8..=15 => true,
                    5 => FrameKind::from_code(bad[5]).is_some(),
                    _ => false,
                };
                let out = read_frame(&mut &bad[..], DEFAULT_MAX_PAYLOAD);
                if structurally_ok {
                    assert!(out.is_ok(), "byte={byte} bit={bit} rejected");
                } else {
                    assert!(out.is_err(), "byte={byte} bit={bit} parsed");
                }
            }
        }
        // An oversized declared length is rejected before allocation.
        let huge = {
            let mut h = good.clone();
            h[16..20].copy_from_slice(&(u32::MAX).to_le_bytes());
            h
        };
        assert!(read_frame(&mut &huge[..], DEFAULT_MAX_PAYLOAD).is_err());
        // …and a frame over a caller-chosen cap too.
        assert!(read_frame(&mut &good[..], 0).is_err());
        // A future protocol version is refused.
        let future = {
            let mut h = good.clone();
            h[4] = WIRE_VERSION + 1;
            h
        };
        assert!(read_frame(&mut &future[..], DEFAULT_MAX_PAYLOAD).is_err());
    }

    fn rand_ys(r: &mut crate::rng::Xoshiro256StarStar, max: u32) -> Vec<u32> {
        let n = (r.next_u64() % 40) as usize;
        (0..n).map(|_| (r.next_u64() as u32) % (max + 1)).collect()
    }

    fn rand_f64s(r: &mut crate::rng::Xoshiro256StarStar, n: usize) -> Vec<f64> {
        // Strictly positive ratio: the `| 1` keeps ln() finite (the Num
        // encoding for scalars handles finite values only).
        (0..n)
            .map(|_| ((r.next_u64() | 1) as f64 / u64::MAX as f64).ln())
            .collect()
    }

    /// Property: every request and response variant round-trips the
    /// codec bit-exactly — the wire contract behind the loopback
    /// bit-identity acceptance test.
    #[test]
    fn payload_round_trip_every_variant() {
        let mut runner = Runner::new("wire-payload-roundtrip");
        runner.run(50, |r| {
            let id = r.next_u64();

            // Decode request, all algos × modes.
            let algo = Algo::ALL[(r.next_u64() % 3) as usize];
            let mode = [
                ExecMode::Auto,
                ExecMode::Native,
                ExecMode::Pjrt,
                ExecMode::Sharded,
            ][(r.next_u64() % 4) as usize];
            let req = DecodeRequest {
                id,
                model: "ge".to_string(),
                ys: {
                    let mut ys = rand_ys(r, 3);
                    ys.push(1); // decode requires non-empty
                    ys
                },
                algo,
                mode,
            };
            let back =
                decode_request_from_json(id, &decode_request_to_json(&req))
                    .unwrap();
            assert_eq!(back.model, req.model);
            assert_eq!(back.ys, req.ys);
            assert_eq!(back.algo, req.algo);
            assert_eq!(back.mode, req.mode);

            // Stream request, every verb.
            let session = r.next_u64() % (1 << 50);
            let verbs = [
                StreamVerb::Open {
                    model: "m".to_string(),
                    options: SessionOptions {
                        block: if r.next_u64() % 2 == 0 {
                            None
                        } else {
                            Some(1 + (r.next_u64() % 512) as usize)
                        },
                        track_map: r.next_u64() % 2 == 0,
                        kind: match r.next_u64() % 3 {
                            0 => SessionKind::SumProduct,
                            1 => SessionKind::Bayes,
                            _ => SessionKind::Kalman,
                        },
                    },
                    lag: (r.next_u64() % 128) as usize,
                },
                StreamVerb::Append { session, ys: rand_ys(r, 5) },
                StreamVerb::Stat { session },
                StreamVerb::Close { session },
                StreamVerb::OpenAt {
                    session,
                    model: "m".to_string(),
                    options: SessionOptions {
                        block: Some(1 + (r.next_u64() % 512) as usize),
                        track_map: r.next_u64() % 2 == 0,
                        kind: if r.next_u64() % 2 == 0 {
                            SessionKind::SumProduct
                        } else {
                            SessionKind::Kalman
                        },
                    },
                    lag: (r.next_u64() % 128) as usize,
                },
                StreamVerb::Export { session },
                StreamVerb::Import {
                    session,
                    meta: SessionMeta {
                        model: "m".to_string(),
                        options: SessionOptions::default(),
                        lag: (r.next_u64() % 64) as usize,
                        fingerprint: Some(r.next_u64()),
                    },
                    snapshot: Json::parse(r#"{"ys": "0101", "k": 3}"#)
                        .unwrap(),
                },
                StreamVerb::Release { session },
            ];
            for verb in verbs {
                let req = StreamRequest { id, verb };
                let back =
                    stream_request_from_json(id, &stream_request_to_json(&req))
                        .unwrap();
                match (&req.verb, &back.verb) {
                    (
                        StreamVerb::Open { model: m1, options: o1, lag: l1 },
                        StreamVerb::Open { model: m2, options: o2, lag: l2 },
                    ) => {
                        assert_eq!((m1, o1, l1), (m2, o2, l2));
                    }
                    (
                        StreamVerb::Append { session: s1, ys: y1 },
                        StreamVerb::Append { session: s2, ys: y2 },
                    ) => assert_eq!((s1, y1), (s2, y2)),
                    (
                        StreamVerb::Stat { session: s1 },
                        StreamVerb::Stat { session: s2 },
                    ) => assert_eq!(s1, s2),
                    (
                        StreamVerb::Close { session: s1 },
                        StreamVerb::Close { session: s2 },
                    ) => assert_eq!(s1, s2),
                    (
                        StreamVerb::OpenAt {
                            session: s1, model: m1, options: o1, lag: l1,
                        },
                        StreamVerb::OpenAt {
                            session: s2, model: m2, options: o2, lag: l2,
                        },
                    ) => assert_eq!((s1, m1, o1, l1), (s2, m2, o2, l2)),
                    (
                        StreamVerb::Export { session: s1 },
                        StreamVerb::Export { session: s2 },
                    ) => assert_eq!(s1, s2),
                    (
                        StreamVerb::Import {
                            session: s1, meta: m1, snapshot: n1,
                        },
                        StreamVerb::Import {
                            session: s2, meta: m2, snapshot: n2,
                        },
                    ) => assert_eq!((s1, m1, n1), (s2, m2, n2)),
                    (
                        StreamVerb::Release { session: s1 },
                        StreamVerb::Release { session: s2 },
                    ) => assert_eq!(s1, s2),
                    (a, b) => panic!("verb changed shape: {a:?} -> {b:?}"),
                }
            }

            // Decode responses: posterior and map payloads, exact f64s.
            let d = 2 + (r.next_u64() % 4) as usize;
            let t = 1 + (r.next_u64() % 20) as usize;
            let gamma = rand_f64s(r, d * t);
            let loglik = rand_f64s(r, 1)[0];
            let resp = DecodeResponse {
                id,
                result: DecodeResult::Posterior(Posterior::new(
                    d,
                    gamma.clone(),
                    loglik,
                )),
                plan: "native".to_string(),
                elapsed: Duration::from_micros(r.next_u64() % 1_000_000),
            };
            let back =
                decode_response_from_json(id, &decode_response_to_json(&resp))
                    .unwrap();
            assert_eq!(back.plan, resp.plan);
            assert_eq!(back.elapsed, resp.elapsed);
            let p = back.result.as_posterior().unwrap();
            assert_eq!(p.gamma_flat(), &gamma[..], "gamma must be bit-exact");
            assert_eq!(p.log_likelihood().to_bits(), loglik.to_bits());

            let map = MapEstimate { path: rand_ys(r, 3), log_prob: loglik };
            let resp = DecodeResponse {
                id,
                result: DecodeResult::Map(map.clone()),
                plan: "pjrt:mp".to_string(),
                elapsed: Duration::from_micros(3),
            };
            let back =
                decode_response_from_json(id, &decode_response_to_json(&resp))
                    .unwrap();
            assert_eq!(back.result.as_map().unwrap(), &map);

            // Stream responses: every reply variant.
            let filtered = Filtered {
                probs: rand_f64s(r, d),
                log_likelihood: loglik,
                step: t,
            };
            let window = LagSmoothed {
                start: (r.next_u64() % 100) as usize,
                posterior: Posterior::new(d, gamma.clone(), loglik),
                rescan_width: (r.next_u64() % 300) as usize,
            };
            let replies = [
                StreamReply::Opened { session },
                StreamReply::Appended {
                    session,
                    len: t,
                    filtered: filtered.clone(),
                    window: if r.next_u64() % 2 == 0 {
                        Some(window)
                    } else {
                        None
                    },
                    plan_hint: if r.next_u64() % 2 == 0 {
                        Some("sp_par_T1024_D4_M2".to_string())
                    } else {
                        None
                    },
                },
                StreamReply::Stats {
                    session,
                    len: t,
                    resident: r.next_u64() % 2 == 0,
                    model: "ge".to_string(),
                    open_sessions: 5,
                    resident_sessions: 3,
                },
                StreamReply::Closed {
                    session,
                    posterior: Posterior::new(d, gamma.clone(), loglik),
                },
                StreamReply::Exported {
                    session,
                    len: t,
                    meta: SessionMeta {
                        model: "ge".to_string(),
                        options: SessionOptions::default(),
                        lag: 4,
                        fingerprint: Some(r.next_u64()),
                    },
                    snapshot: Json::parse(r#"{"ys": "00", "chain": [1, 2]}"#)
                        .unwrap(),
                },
                StreamReply::Imported { session, len: t },
                StreamReply::Released { session },
            ];
            for reply in replies {
                let resp = StreamResponse {
                    id,
                    reply,
                    elapsed: Duration::from_micros(r.next_u64() % 10_000),
                };
                let back = stream_response_from_json(
                    id,
                    &stream_response_to_json(&resp),
                )
                .unwrap();
                assert_eq!(back.elapsed, resp.elapsed);
                match (&resp.reply, &back.reply) {
                    (
                        StreamReply::Opened { session: a },
                        StreamReply::Opened { session: b },
                    ) => assert_eq!(a, b),
                    (
                        StreamReply::Appended {
                            session: s1,
                            len: l1,
                            filtered: f1,
                            window: w1,
                            plan_hint: h1,
                        },
                        StreamReply::Appended {
                            session: s2,
                            len: l2,
                            filtered: f2,
                            window: w2,
                            plan_hint: h2,
                        },
                    ) => {
                        assert_eq!((s1, l1, h1), (s2, l2, h2));
                        assert_eq!(f1, f2, "filtered must be bit-exact");
                        assert_eq!(w1.is_some(), w2.is_some());
                        if let (Some(a), Some(b)) = (w1, w2) {
                            assert_eq!(a.start, b.start);
                            assert_eq!(a.rescan_width, b.rescan_width);
                            assert_eq!(a.posterior, b.posterior);
                        }
                    }
                    (
                        StreamReply::Stats {
                            session: s1, len: l1, resident: r1, model: m1, ..
                        },
                        StreamReply::Stats {
                            session: s2, len: l2, resident: r2, model: m2, ..
                        },
                    ) => assert_eq!((s1, l1, r1, m1), (s2, l2, r2, m2)),
                    (
                        StreamReply::Closed { session: s1, posterior: p1 },
                        StreamReply::Closed { session: s2, posterior: p2 },
                    ) => {
                        assert_eq!(s1, s2);
                        assert_eq!(p1, p2, "posterior must be bit-exact");
                    }
                    (
                        StreamReply::Exported {
                            session: s1, len: l1, meta: m1, snapshot: n1,
                        },
                        StreamReply::Exported {
                            session: s2, len: l2, meta: m2, snapshot: n2,
                        },
                    ) => {
                        assert_eq!((s1, l1, m1), (s2, l2, m2));
                        assert_eq!(n1, n2, "snapshot must round-trip exactly");
                    }
                    (
                        StreamReply::Imported { session: s1, len: l1 },
                        StreamReply::Imported { session: s2, len: l2 },
                    ) => assert_eq!((s1, l1), (s2, l2)),
                    (
                        StreamReply::Released { session: s1 },
                        StreamReply::Released { session: s2 },
                    ) => assert_eq!(s1, s2),
                    (a, b) => panic!("reply changed shape: {a:?} -> {b:?}"),
                }
            }
        });
    }

    /// Property: malformed *payloads* (well-framed, wrong JSON shape)
    /// are typed errors on every parser — never panics.
    #[test]
    fn malformed_payloads_are_typed_errors() {
        let bads = [
            Json::Null,
            Json::Num(1.0),
            Json::Str("x".to_string()),
            Json::parse(r#"{"verb": "nope"}"#).unwrap(),
            Json::parse(r#"{"verb": "append"}"#).unwrap(),
            Json::parse(r#"{"reply": "opened"}"#).unwrap(),
            Json::parse(r#"{"model": 3}"#).unwrap(),
            Json::parse(r#"{"d": 2, "loglik": 1, "gamma": "zz"}"#).unwrap(),
            Json::parse(r#"{"d": 3, "loglik": 1, "gamma": 5}"#).unwrap(),
            Json::parse(r#"{"d": 0, "loglik": 1, "gamma": ""}"#).unwrap(),
        ];
        for bad in &bads {
            assert!(decode_request_from_json(1, bad).is_err(), "{bad:?}");
            assert!(stream_request_from_json(1, bad).is_err(), "{bad:?}");
            assert!(decode_response_from_json(1, bad).is_err(), "{bad:?}");
            assert!(stream_response_from_json(1, bad).is_err(), "{bad:?}");
            assert!(posterior_from_json(bad).is_err(), "{bad:?}");
        }
        // d=3 with 2 gamma values: shape mismatch is typed.
        let bad_shape = Json::parse(
            r#"{"d": 3, "loglik": 1,
                "gamma": "00000000000000000000000000000000"}"#,
        )
        .unwrap();
        assert!(posterior_from_json(&bad_shape).is_err());
        // Errors round-trip with their codes.
        let e = Error::invalid_request("nope");
        let back = error_from_json(&error_to_json(&e));
        assert!(matches!(back, Error::InvalidRequest(_)));
        assert!(back.to_string().contains("nope"));
        let e = Error::coordinator("queue closed");
        let back = error_from_json(&error_to_json(&e));
        assert!(back.to_string().contains("queue closed"));
        // Busy round-trips its retry hint through the error encoding…
        let e = Error::busy(250, "server draining");
        let back = error_from_json(&error_to_json(&e));
        let Error::Busy { retry_after_ms, msg } = back else {
            panic!("busy did not round-trip: {back:?}");
        };
        assert_eq!(retry_after_ms, 250);
        assert!(msg.contains("server draining"));
        // …and through the dedicated reject payload.
        let back = busy_from_reject(&reject_to_json(50, "worker pool full"));
        let Error::Busy { retry_after_ms, msg } = back else {
            panic!("reject payload did not surface as busy");
        };
        assert_eq!(retry_after_ms, 50);
        assert_eq!(msg, "worker pool full");
    }

    #[test]
    fn reject_frame_round_trips() {
        let f = round_frame(9, FrameKind::Reject, reject_to_json(100, "busy"));
        assert_eq!(f.kind, FrameKind::Reject);
        assert!(f.kind.is_response());
        assert_eq!(FrameKind::from_code(0x84), Some(FrameKind::Reject));
        let e = busy_from_reject(&f.payload);
        assert!(e.is_busy());
    }

    #[test]
    fn scrape_frames_round_trip() {
        let req = round_frame(11, FrameKind::ScrapeRequest, Json::Null);
        assert_eq!(req.kind, FrameKind::ScrapeRequest);
        assert!(!req.kind.is_response());
        assert_eq!(FrameKind::from_code(0x04), Some(FrameKind::ScrapeRequest));
        let text = "requests 3\nwire_inflight 0\n";
        let resp =
            round_frame(11, FrameKind::ScrapeResponse, scrape_to_json(text));
        assert_eq!(resp.kind, FrameKind::ScrapeResponse);
        assert!(resp.kind.is_response());
        assert_eq!(FrameKind::from_code(0x85), Some(FrameKind::ScrapeResponse));
        assert_eq!(scrape_text_from_json(&resp.payload).unwrap(), text);
        assert!(scrape_text_from_json(&Json::Null).is_err());
    }

    #[test]
    fn deadline_field_is_additive_and_optional() {
        let req = DecodeRequest::new(3, "ge", vec![1, 0, 1], Algo::Smooth);
        let bare = decode_request_to_json(&req);
        assert_eq!(deadline_ms_from_json(&bare), None);
        let stamped = with_deadline_ms(bare.clone(), 250);
        assert_eq!(deadline_ms_from_json(&stamped), Some(250));
        // The extra key is invisible to the request parser (additive
        // within the version rules: unknown keys are ignored).
        let back = decode_request_from_json(3, &stamped).unwrap();
        assert_eq!(back.ys, req.ys);
        assert_eq!(back.model, req.model);
        // Zero is a real (already expired) deadline, not "none".
        assert_eq!(deadline_ms_from_json(&with_deadline_ms(bare, 0)), Some(0));
        // Non-object payloads pass through untouched.
        assert_eq!(with_deadline_ms(Json::Null, 9), Json::Null);
        // Stream requests carry it the same way.
        let sreq = StreamRequest::stat(4, 77);
        let stamped = with_deadline_ms(stream_request_to_json(&sreq), 10);
        assert_eq!(deadline_ms_from_json(&stamped), Some(10));
        let back = stream_request_from_json(4, &stamped).unwrap();
        assert!(matches!(back.verb, StreamVerb::Stat { session: 77 }));
    }

    #[test]
    fn trace_field_is_additive_and_optional() {
        let req = DecodeRequest::new(3, "ge", vec![1, 0, 1], Algo::Smooth);
        let bare = decode_request_to_json(&req);
        assert_eq!(trace_from_json(&bare), None);
        // Ids beyond f64's 53 integer bits survive the hex encoding.
        let ctx = TraceContext {
            trace_id: (1u64 << 53) + 7,
            parent_span: u64::MAX,
        };
        let stamped = with_trace(bare.clone(), ctx);
        assert_eq!(trace_from_json(&stamped), Some(ctx));
        // The extra key is invisible to the request parser, and it
        // composes with the v3 deadline field.
        let both = with_deadline_ms(stamped, 250);
        assert_eq!(trace_from_json(&both), Some(ctx));
        assert_eq!(deadline_ms_from_json(&both), Some(250));
        let back = decode_request_from_json(3, &both).unwrap();
        assert_eq!(back.ys, req.ys);
        // A root request carries parent_span 0; trace_id 0 means
        // untraced even if a buggy writer encodes it.
        let root = TraceContext { trace_id: 9, parent_span: 0 };
        assert_eq!(
            trace_from_json(&with_trace(bare.clone(), root)),
            Some(root)
        );
        let zero = TraceContext { trace_id: 0, parent_span: 4 };
        assert_eq!(trace_from_json(&with_trace(bare.clone(), zero)), None);
        // Malformed ids (numbers, bad hex) read as untraced.
        let bad = Json::parse(
            r#"{"trace": {"trace_id": 12, "parent_span": "00"}}"#,
        )
        .unwrap();
        assert_eq!(trace_from_json(&bad), None);
        // Non-object payloads pass through untouched.
        assert_eq!(with_trace(Json::Null, ctx), Json::Null);
        // Stream requests carry it the same way.
        let sreq = StreamRequest::stat(4, 77);
        let stamped = with_trace(stream_request_to_json(&sreq), ctx);
        assert_eq!(trace_from_json(&stamped), Some(ctx));
        let back = stream_request_from_json(4, &stamped).unwrap();
        assert!(matches!(back.verb, StreamVerb::Stat { session: 77 }));
    }
}

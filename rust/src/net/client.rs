//! Blocking Rust client for the TCP serving layer.
//!
//! [`NetClient`] speaks the `net::wire` protocol over one persistent
//! connection: `decode`, the streaming verbs `open` / `append` /
//! `stat` / `close`, and the cluster-tier verbs `open_at` / `export` /
//! `import` / `release` the session router drives placement and live
//! migration with. Sessions are **coordinator-scoped, not
//! connection-scoped** — a session id stays valid across reconnects —
//! so the client auto-reconnects on connection failure and re-`Stat`s
//! every session it has opened to re-validate them against the server
//! (ROADMAP: "auto-reconnect with session re-Stat").
//!
//! Retry safety: verbs other than `append` are idempotent and are
//! retried once after a reconnect. A lost `append` is ambiguous — the
//! chunk may or may not have been applied — so the client compares the
//! session's server-side length (from the re-`Stat`) against its own
//! acked ledger: if the chunk landed, it polls the post-append state
//! with an empty append instead of double-applying; if it did not, it
//! re-sends; anything else is a typed error, never a silent
//! double-apply.
//!
//! The pipelined half ([`send_decode`](NetClient::send_decode) /
//! [`recv_decode`](NetClient::recv_decode)) is what the throughput
//! bench drives: many requests in flight on one connection, responses
//! matched by id in whatever order the server completes them. Don't mix
//! pipelined sends with the blocking calls on one client.
//!
//! v3 additions: [`scrape`](NetClient::scrape) fetches the server's
//! metrics snapshot as stable `key value` text, and
//! [`set_deadline_ms`](NetClient::set_deadline_ms) stamps a per-request
//! `deadline_ms` budget onto outgoing requests — a server that cannot
//! start a request within the budget sheds it with a retryable
//! [`Error::Busy`] instead of serving an answer the caller has stopped
//! waiting for.
//!
//! v4 addition: every outgoing decode / stream / pipelined request is
//! stamped with a `trace` context. When the calling thread already
//! holds an ambient span (the cluster router fanning a request out
//! under its own execute span), that context is *propagated* — which is
//! what links a worker's spans under the router's in the merged cluster
//! timeline; otherwise the client *originates* a fresh trace id with
//! parent 0. Internal traffic (ping, reconnect re-`Stat`s) stays
//! unstamped.

use std::collections::BTreeMap;
use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::coordinator::{
    DecodeRequest, DecodeResponse, StreamReply, StreamRequest, StreamResponse,
    StreamVerb,
};
use crate::engine::SessionOptions;
use crate::error::{Error, Result};
use crate::inference::Posterior;
use crate::jsonx::Json;
use crate::store::SessionMeta;

use super::wire::{self, Frame, FrameKind};

/// Blocking wire-protocol client (see the module docs).
pub struct NetClient {
    addr: String,
    stream: Option<TcpStream>,
    next_id: u64,
    /// Sessions opened through this client: id → observations acked by
    /// the server (the ledger the append-retry logic compares against).
    sessions: BTreeMap<u64, usize>,
    read_timeout: Duration,
    write_timeout: Duration,
    max_frame_payload: usize,
    /// When set, stamped as `deadline_ms` onto every outgoing decode,
    /// stream, and pipelined request (overload control, wire v3).
    deadline_ms: Option<u64>,
}

impl NetClient {
    /// Connect and handshake (a ping round trip — which also surfaces a
    /// draining/busy server's refusal frame as a typed error).
    pub fn connect(addr: impl AsRef<str>) -> Result<NetClient> {
        let mut client = NetClient {
            addr: addr.as_ref().to_string(),
            stream: None,
            next_id: 0,
            sessions: BTreeMap::new(),
            read_timeout: Duration::from_secs(60),
            write_timeout: Duration::from_secs(10),
            max_frame_payload: wire::DEFAULT_MAX_PAYLOAD,
            deadline_ms: None,
        };
        client.reconnect()?;
        Ok(client)
    }

    /// Replace the response-read timeout (builder-style; default 60 s —
    /// a decode of a long sequence is slow on purpose).
    pub fn with_read_timeout(mut self, timeout: Duration) -> NetClient {
        self.read_timeout = timeout;
        if let Some(s) = &self.stream {
            let _ = s.set_read_timeout(Some(timeout));
        }
        self
    }

    /// The server address this client targets.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Set (or clear) the per-request `deadline_ms` budget stamped onto
    /// every subsequent decode, streaming, and pipelined request. A
    /// request the server cannot *start* within the budget is shed with
    /// a retryable [`Error::Busy`]; `0` means "shed unless immediate".
    /// Internal traffic (ping, reconnect re-`Stat`s) is never stamped.
    pub fn set_deadline_ms(&mut self, deadline_ms: Option<u64>) {
        self.deadline_ms = deadline_ms;
    }

    /// Stamp the configured deadline and the trace context onto an
    /// outgoing request payload. The trace is the ambient span when the
    /// calling thread has one (propagation — the router's fan-out path)
    /// and a freshly originated root otherwise.
    fn stamp(&self, payload: Json) -> Json {
        let payload = match self.deadline_ms {
            Some(ms) => wire::with_deadline_ms(payload, ms),
            None => payload,
        };
        let (trace, span) = crate::obs::span::current();
        let ctx = if trace != 0 {
            wire::TraceContext { trace_id: trace, parent_span: span }
        } else {
            wire::TraceContext {
                trace_id: crate::obs::span::fresh_id(),
                parent_span: 0,
            }
        };
        wire::with_trace(payload, ctx)
    }

    /// Sessions this client has opened and not yet closed, with their
    /// acked observation counts.
    pub fn tracked_sessions(&self) -> &BTreeMap<u64, usize> {
        &self.sessions
    }

    /// (Re-)establish the connection and handshake with a ping.
    fn reconnect(&mut self) -> Result<()> {
        self.stream = None;
        let stream = TcpStream::connect(&self.addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(self.read_timeout))?;
        stream.set_write_timeout(Some(self.write_timeout))?;
        self.stream = Some(stream);
        let frame = self.roundtrip(FrameKind::Ping, &Json::Null)?;
        if frame.kind != FrameKind::Pong {
            self.stream = None;
            return Err(Error::coordinator(format!(
                "handshake: expected pong, got {:?}",
                frame.kind
            )));
        }
        Ok(())
    }

    fn stream_mut(&mut self) -> Result<&mut TcpStream> {
        self.stream
            .as_mut()
            .ok_or_else(|| Error::coordinator("client not connected"))
    }

    fn next_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    /// One blocking request/response exchange. Error frames become
    /// typed errors, reject frames become retryable [`Error::Busy`]
    /// values; a non-matching response id is a protocol error (the
    /// blocking API keeps exactly one request outstanding).
    fn roundtrip(&mut self, kind: FrameKind, payload: &Json) -> Result<Frame> {
        let id = self.next_id();
        let max = self.max_frame_payload;
        let stream = self.stream_mut()?;
        stream.write_all(&wire::encode_frame(id, kind, payload))?;
        stream.flush()?;
        let frame = wire::read_frame(stream, max)?;
        if frame.kind == FrameKind::Error {
            return Err(wire::error_from_json(&frame.payload));
        }
        // A reject (id 0 when refused at admission, the request id when
        // refused per-request) carries a back-off hint, not a result.
        if frame.kind == FrameKind::Reject {
            return Err(wire::busy_from_reject(&frame.payload));
        }
        if frame.id != id {
            return Err(Error::coordinator(format!(
                "wire: response id {} for request {id} (blocking clients \
                 keep one request in flight)",
                frame.id
            )));
        }
        Ok(frame)
    }

    /// `roundtrip` with one transparent reconnect + session
    /// re-validation on a connection-level failure. Only for verbs that
    /// are safe to re-send (everything but a non-empty append).
    fn call(&mut self, kind: FrameKind, payload: &Json) -> Result<Frame> {
        match self.roundtrip(kind, payload) {
            Err(Error::Io(_)) => {
                self.reconnect()?;
                self.revalidate_sessions();
                self.roundtrip(kind, payload)
            }
            other => other,
        }
    }

    /// Re-`Stat` every tracked session after a reconnect: refresh acked
    /// lengths from the server; sessions the server no longer knows are
    /// dropped from tracking (their next use errors with the server's
    /// own message).
    fn revalidate_sessions(&mut self) {
        let ids: Vec<u64> = self.sessions.keys().copied().collect();
        for id in ids {
            let payload =
                wire::stream_request_to_json(&StreamRequest::stat(0, id));
            match self.roundtrip(FrameKind::StreamRequest, &payload) {
                Ok(frame) => {
                    if let Ok(resp) =
                        wire::stream_response_from_json(frame.id, &frame.payload)
                    {
                        if let StreamReply::Stats { len, .. } = resp.reply {
                            self.sessions.insert(id, len);
                        }
                    }
                }
                Err(Error::Io(_)) => return, // connection died again
                Err(_) => {
                    self.sessions.remove(&id);
                }
            }
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        self.call(FrameKind::Ping, &Json::Null).map(|_| ())
    }

    /// Fetch the server's full metrics snapshot rendered as stable
    /// `key value` scrape text (one metric per line — the wire verb
    /// behind `hmm-scan stat --connect ADDR`). Works against any
    /// [`WireService`](crate::net::WireService): a coordinator's server
    /// reports worker-local metrics, a cluster router's reports the
    /// routing tier's.
    pub fn scrape(&mut self) -> Result<String> {
        let frame = self.call(FrameKind::ScrapeRequest, &Json::Null)?;
        if frame.kind != FrameKind::ScrapeResponse {
            return Err(Error::coordinator(format!(
                "wire: expected a scrape response, got {:?}",
                frame.kind
            )));
        }
        wire::scrape_text_from_json(&frame.payload)
    }

    /// Serve one decode request remotely. The response's `id` echoes
    /// the wire request id the client assigned (not `req.id`).
    pub fn decode(&mut self, req: &DecodeRequest) -> Result<DecodeResponse> {
        let payload = self.stamp(wire::decode_request_to_json(req));
        let frame = self.call(FrameKind::DecodeRequest, &payload)?;
        if frame.kind != FrameKind::DecodeResponse {
            return Err(Error::coordinator(format!(
                "wire: expected a decode response, got {:?}",
                frame.kind
            )));
        }
        wire::decode_response_from_json(frame.id, &frame.payload)
    }

    fn stream_call(&mut self, req: &StreamRequest) -> Result<StreamResponse> {
        let payload = self.stamp(wire::stream_request_to_json(req));
        let frame = self.call(FrameKind::StreamRequest, &payload)?;
        parse_stream_response(frame)
    }

    /// Open a streaming session; returns the server-assigned id (valid
    /// across reconnects — sessions live in the coordinator).
    pub fn open(
        &mut self,
        model: &str,
        options: SessionOptions,
        lag: usize,
    ) -> Result<u64> {
        let req = StreamRequest {
            id: 0,
            verb: StreamVerb::Open { model: model.to_string(), options, lag },
        };
        let resp = self.stream_call(&req)?;
        match resp.reply {
            StreamReply::Opened { session } => {
                self.sessions.insert(session, 0);
                Ok(session)
            }
            other => Err(Error::coordinator(format!(
                "stream open: unexpected reply {other:?}"
            ))),
        }
    }

    /// Open a streaming session under a **caller-chosen** id — the
    /// cluster router's placement verb, which lets the router keep one
    /// id space across all workers. Errors if the id is already in use
    /// on the server.
    pub fn open_at(
        &mut self,
        session: u64,
        model: &str,
        options: SessionOptions,
        lag: usize,
    ) -> Result<u64> {
        let req = StreamRequest::open_at(0, session, model, options, lag);
        let resp = self.stream_call(&req)?;
        match resp.reply {
            StreamReply::Opened { session } => {
                self.sessions.insert(session, 0);
                Ok(session)
            }
            other => Err(Error::coordinator(format!(
                "stream open_at: unexpected reply {other:?}"
            ))),
        }
    }

    /// Export a session's compacted migration image: its metadata, a
    /// self-contained engine snapshot, and the observation count the
    /// snapshot covers. The session stays open and serving on this
    /// server — export is a read.
    pub fn export(
        &mut self,
        session: u64,
    ) -> Result<(SessionMeta, Json, usize)> {
        let resp = self.stream_call(&StreamRequest::export(0, session))?;
        match resp.reply {
            StreamReply::Exported { meta, snapshot, len, .. } => {
                Ok((meta, snapshot, len))
            }
            other => Err(Error::coordinator(format!(
                "stream export: unexpected reply {other:?}"
            ))),
        }
    }

    /// Restore an exported migration image under the same session id on
    /// this server (the migration target's half of the handoff).
    /// Returns the restored observation count — the router compares it
    /// against the source's before cutting traffic over.
    pub fn import(
        &mut self,
        session: u64,
        meta: SessionMeta,
        snapshot: Json,
    ) -> Result<usize> {
        let req = StreamRequest::import(0, session, meta, snapshot);
        let resp = self.stream_call(&req)?;
        match resp.reply {
            StreamReply::Imported { len, .. } => {
                self.sessions.insert(session, len);
                Ok(len)
            }
            other => Err(Error::coordinator(format!(
                "stream import: unexpected reply {other:?}"
            ))),
        }
    }

    /// Drop a session and its durable record **without** computing a
    /// final posterior — the migration source's cleanup once the target
    /// has verified its copy.
    pub fn release(&mut self, session: u64) -> Result<()> {
        let resp = self.stream_call(&StreamRequest::release(0, session))?;
        match resp.reply {
            StreamReply::Released { .. } => {
                self.sessions.remove(&session);
                Ok(())
            }
            other => Err(Error::coordinator(format!(
                "stream release: unexpected reply {other:?}"
            ))),
        }
    }

    /// Append observations; returns the [`StreamReply::Appended`]
    /// payload (filtering marginal + optional fixed-lag window).
    ///
    /// On a connection failure mid-append the client reconnects and
    /// resolves the ambiguity through the session's re-`Stat`ed length
    /// before deciding to re-send (see the module docs); a session this
    /// client does not track cannot be resolved and returns a typed
    /// error instead of risking a double-apply.
    pub fn append(&mut self, session: u64, ys: &[u32]) -> Result<StreamReply> {
        let req = StreamRequest::append(0, session, ys.to_vec());
        let payload = self.stamp(wire::stream_request_to_json(&req));
        let outcome = self.roundtrip(FrameKind::StreamRequest, &payload);
        let resp = match outcome {
            Ok(frame) => parse_stream_response(frame)?,
            Err(Error::Io(_)) => {
                let acked = self.sessions.get(&session).copied();
                self.reconnect()?;
                self.revalidate_sessions();
                let (Some(before), Some(&now)) =
                    (acked, self.sessions.get(&session))
                else {
                    return Err(Error::coordinator(format!(
                        "connection lost mid-append to untracked session \
                         {session}; cannot prove whether the chunk applied — \
                         stat the session and retry explicitly"
                    )));
                };
                if now == before + ys.len() {
                    // The lost append landed; poll the resulting state
                    // with an empty (idempotent) append.
                    let poll = StreamRequest::append(0, session, Vec::new());
                    self.stream_call(&poll)?
                } else if now == before {
                    // Re-send exactly once, WITHOUT the auto-reconnect
                    // wrapper: if this attempt also dies mid-flight the
                    // ambiguity is back, and blindly re-sending again
                    // could double-apply — surface the error instead
                    // (the caller's retry re-enters this Stat-ledger
                    // resolution, which stays safe).
                    parse_stream_response(
                        self.roundtrip(FrameKind::StreamRequest, &payload)?,
                    )?
                } else {
                    return Err(Error::coordinator(format!(
                        "session {session} is at {now} observations after \
                         reconnect (expected {before} or {}); refusing to \
                         re-append",
                        before + ys.len()
                    )));
                }
            }
            Err(e) => return Err(e),
        };
        match resp.reply {
            reply @ StreamReply::Appended { .. } => {
                if let StreamReply::Appended { len, .. } = &reply {
                    self.sessions.insert(session, *len);
                }
                Ok(reply)
            }
            other => Err(Error::coordinator(format!(
                "stream append: unexpected reply {other:?}"
            ))),
        }
    }

    /// Residency/length probe for one session.
    pub fn stat(&mut self, session: u64) -> Result<StreamReply> {
        let resp = self.stream_call(&StreamRequest::stat(0, session))?;
        match resp.reply {
            reply @ StreamReply::Stats { .. } => {
                if let StreamReply::Stats { len, .. } = &reply {
                    if self.sessions.contains_key(&session) {
                        self.sessions.insert(session, *len);
                    }
                }
                Ok(reply)
            }
            other => Err(Error::coordinator(format!(
                "stream stat: unexpected reply {other:?}"
            ))),
        }
    }

    /// Close a session for its exact full-sequence posterior.
    pub fn close(&mut self, session: u64) -> Result<Posterior> {
        let resp = self.stream_call(&StreamRequest::close(0, session))?;
        match resp.reply {
            StreamReply::Closed { posterior, .. } => {
                self.sessions.remove(&session);
                Ok(posterior)
            }
            other => Err(Error::coordinator(format!(
                "stream close: unexpected reply {other:?}"
            ))),
        }
    }

    // -- pipelined half (benches) ------------------------------------------

    /// Fire one decode request without waiting; returns the wire id to
    /// match against [`recv_decode`](Self::recv_decode). No
    /// auto-reconnect — a pipeline's in-flight set dies with the
    /// connection.
    pub fn send_decode(&mut self, req: &DecodeRequest) -> Result<u64> {
        let id = self.next_id();
        let payload = self.stamp(wire::decode_request_to_json(req));
        let stream = self.stream_mut()?;
        stream.write_all(&wire::encode_frame(
            id,
            FrameKind::DecodeRequest,
            &payload,
        ))?;
        Ok(id)
    }

    /// Flush buffered pipelined sends to the server.
    pub fn flush(&mut self) -> Result<()> {
        self.stream_mut()?.flush()?;
        Ok(())
    }

    /// Drive a batch of decode requests through the pipelined half,
    /// keeping at most `pipeline` in flight; returns one send→response
    /// latency per request (in completion order). The single harness
    /// behind `hmm-scan bench-net` and `benches/net.rs`. Any
    /// request-level failure aborts with its error.
    pub fn pipeline_decodes(
        &mut self,
        reqs: impl IntoIterator<Item = DecodeRequest>,
        pipeline: usize,
    ) -> Result<Vec<Duration>> {
        let pipeline = pipeline.max(1);
        let mut inflight: BTreeMap<u64, Instant> = BTreeMap::new();
        let mut lat = Vec::new();
        for req in reqs {
            while inflight.len() >= pipeline {
                self.drain_one(&mut inflight, &mut lat)?;
            }
            let id = self.send_decode(&req)?;
            self.flush()?;
            inflight.insert(id, Instant::now());
        }
        while !inflight.is_empty() {
            self.drain_one(&mut inflight, &mut lat)?;
        }
        Ok(lat)
    }

    /// Receive one pipelined response and record its latency.
    fn drain_one(
        &mut self,
        inflight: &mut BTreeMap<u64, Instant>,
        lat: &mut Vec<Duration>,
    ) -> Result<()> {
        let (id, resp) = self.recv_decode()?;
        resp?;
        if let Some(sent) = inflight.remove(&id) {
            lat.push(sent.elapsed());
        }
        Ok(())
    }

    /// Receive the next pipelined response (any order): the wire id and
    /// the per-request outcome.
    pub fn recv_decode(&mut self) -> Result<(u64, Result<DecodeResponse>)> {
        let max = self.max_frame_payload;
        let stream = self.stream_mut()?;
        let frame = wire::read_frame(stream, max)?;
        match frame.kind {
            FrameKind::DecodeResponse => {
                let resp =
                    wire::decode_response_from_json(frame.id, &frame.payload);
                Ok((frame.id, resp))
            }
            FrameKind::Error => {
                Ok((frame.id, Err(wire::error_from_json(&frame.payload))))
            }
            FrameKind::Reject => {
                Ok((frame.id, Err(wire::busy_from_reject(&frame.payload))))
            }
            other => Err(Error::coordinator(format!(
                "wire: unexpected {other:?} frame in a decode pipeline"
            ))),
        }
    }
}

fn parse_stream_response(frame: Frame) -> Result<StreamResponse> {
    if frame.kind != FrameKind::StreamResponse {
        return Err(Error::coordinator(format!(
            "wire: expected a stream response, got {:?}",
            frame.kind
        )));
    }
    wire::stream_response_from_json(frame.id, &frame.payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, CoordinatorConfig};
    use crate::hmm::{gilbert_elliott, GeParams};
    use crate::net::{NetServer, NetServerConfig};
    use std::net::Shutdown;
    use std::sync::Arc;

    fn native_coord() -> Arc<Coordinator> {
        let c = Coordinator::new(CoordinatorConfig::native_only()).unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        Arc::new(c)
    }

    fn server_config() -> NetServerConfig {
        NetServerConfig {
            max_connections: 8,
            read_timeout: Duration::from_millis(50),
            ..NetServerConfig::default()
        }
    }

    /// Sever the client's TCP connection out from under it, simulating
    /// a connection loss the client only discovers on its next verb.
    fn sever(client: &NetClient) {
        let s = client.stream.as_ref().expect("client is connected");
        let _ = s.shutdown(Shutdown::Both);
    }

    /// An append racing a server drain: when the reconnect is refused
    /// (typed reject), the append surfaces a retryable [`Error::Busy`]
    /// and the server-side session is untouched — never a double-apply,
    /// never a silent loss.
    #[test]
    fn append_racing_drain_surfaces_retryable_busy() {
        let coord = native_coord();
        let server =
            NetServer::start(Arc::clone(&coord), "127.0.0.1:0", server_config())
                .unwrap();
        let mut client =
            NetClient::connect(server.local_addr().to_string()).unwrap();
        let sid = client.open("ge", SessionOptions::default(), 0).unwrap();
        client.append(sid, &[0, 1, 1]).unwrap();

        server.drain();
        sever(&client);
        let err = client
            .append(sid, &[1, 0])
            .expect_err("append through a refused reconnect succeeded");
        assert!(err.is_busy(), "expected a retryable Busy, got: {err}");
        // The chunk never reached the server: its length is unchanged,
        // so a later retry (once capacity returns) re-sends safely.
        let stat = coord
            .stream(StreamRequest::stat(0, sid))
            .unwrap();
        let StreamReply::Stats { len, .. } = stat.reply else {
            panic!("expected Stats")
        };
        assert_eq!(len, 3, "draining server must not have applied the chunk");
        server.shutdown(Duration::from_secs(5));
    }

    /// The append-retry ledger across a reconnect, both ambiguous
    /// outcomes: a chunk that never applied is re-sent exactly once; a
    /// chunk that applied but whose ack was lost is **not** re-applied.
    /// Either way the session converges to the same observations a
    /// never-interrupted control session holds.
    #[test]
    fn reconnect_ledger_never_double_applies() {
        let coord = native_coord();
        let server =
            NetServer::start(Arc::clone(&coord), "127.0.0.1:0", server_config())
                .unwrap();
        let mut client =
            NetClient::connect(server.local_addr().to_string()).unwrap();
        let sid = client.open("ge", SessionOptions::default(), 0).unwrap();
        client.append(sid, &[0, 1, 1, 0]).unwrap();

        // Case 1: the connection dies before the chunk reaches the
        // server — after reconnect the ledger sees the length unchanged
        // and re-sends exactly once.
        sever(&client);
        let reply = client.append(sid, &[1, 1]).unwrap();
        let StreamReply::Appended { len, .. } = reply else {
            panic!("expected Appended")
        };
        assert_eq!(len, 6);

        // Case 2: the chunk applied but the ack was lost. Stage it by
        // severing the socket, then applying the same chunk server-side
        // (as the in-flight append would have): the reconnect ledger
        // sees length == acked + chunk and must poll, not re-append.
        sever(&client);
        let chunk = vec![0u32, 0, 1];
        coord
            .stream(StreamRequest::append(0, sid, chunk.clone()))
            .unwrap();
        let reply = client.append(sid, &chunk).unwrap();
        let StreamReply::Appended { len, .. } = reply else {
            panic!("expected Appended")
        };
        assert_eq!(len, 9, "ack-lost chunk was applied twice");

        // The posterior is bit-identical to a control session that saw
        // every chunk exactly once with no interruptions.
        let opened = coord.stream(StreamRequest::open(0, "ge", 0)).unwrap();
        let StreamReply::Opened { session: ctl } = opened.reply else {
            panic!("expected Opened")
        };
        coord
            .stream(StreamRequest::append(
                0,
                ctl,
                vec![0, 1, 1, 0, 1, 1, 0, 0, 1],
            ))
            .unwrap();
        let remote = client.close(sid).unwrap();
        let closed = coord.stream(StreamRequest::close(0, ctl)).unwrap();
        let StreamReply::Closed { posterior: control, .. } = closed.reply
        else {
            panic!("expected Closed")
        };
        assert_eq!(
            remote, control,
            "interrupted session diverged from the uninterrupted control"
        );
        drop(client);
        server.shutdown(Duration::from_secs(5));
    }

    /// v3 client surface: `scrape` returns the server's metrics as
    /// parseable `key value` text, and a zero `deadline_ms` budget sheds
    /// both decode and stream requests with a retryable Busy — then
    /// clearing the budget restores normal service on the same
    /// connection.
    #[test]
    fn scrape_and_deadline_budget_through_the_client() {
        use crate::coordinator::{Algo, DecodeRequest};

        let coord = native_coord();
        let server =
            NetServer::start(Arc::clone(&coord), "127.0.0.1:0", server_config())
                .unwrap();
        let mut client =
            NetClient::connect(server.local_addr().to_string()).unwrap();
        client
            .decode(&DecodeRequest::new(1, "ge", vec![0, 1, 1], Algo::Smooth))
            .unwrap();

        let text = client.scrape().unwrap();
        let mut keys = std::collections::BTreeMap::new();
        for line in text.lines() {
            let (k, v) = line.split_once(' ').expect("scrape line is `key value`");
            assert!(v.parse::<f64>().is_ok(), "unparseable value in: {line}");
            keys.insert(k.to_string(), v.to_string());
        }
        assert_eq!(keys.get("requests").map(String::as_str), Some("1"));
        assert!(keys.contains_key("wire_verb_decode_count"));
        assert!(keys.contains_key("deadline_sheds"));

        // An already-expired budget sheds every request kind with a
        // retryable Busy.
        client.set_deadline_ms(Some(0));
        let err = client
            .decode(&DecodeRequest::new(2, "ge", vec![0, 1], Algo::Smooth))
            .expect_err("expired-deadline decode was served");
        assert!(err.is_busy(), "expected Busy, got: {err}");
        let err = client
            .open("ge", SessionOptions::default(), 0)
            .expect_err("expired-deadline open was served");
        assert!(err.is_busy(), "expected Busy, got: {err}");

        // Clearing the budget restores service on the same connection.
        client.set_deadline_ms(None);
        client
            .decode(&DecodeRequest::new(3, "ge", vec![1, 0, 0], Algo::Smooth))
            .unwrap();
        let snap = coord.metrics().snapshot();
        assert!(snap.deadline_sheds >= 2, "sheds: {}", snap.deadline_sheds);
        assert!(snap.rejects_sent >= 2);
        drop(client);
        server.shutdown(Duration::from_secs(5));
    }
}

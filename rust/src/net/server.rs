//! The TCP front-end: persistent connections, pipelined requests,
//! backpressure, and graceful drain over any [`WireService`] — a local
//! [`Coordinator`] or the cluster tier's router.
//!
//! ## Architecture
//!
//! ```text
//!                    accept thread ──▶ refuse (busy / draining)
//!                         │
//!                         ▼ hands the connection to
//!                 conn pool (exec::ThreadPool, one slot per connection)
//!                         │
//!        ┌────────────────┴─────────────────┐
//!        │ reader (pool worker)             │ writer thread
//!        │  frame → parse → dispatch        │  response frames, in
//!        │  · stream verbs: inline,         │  completion order
//!        │    arrival order                 ▲
//!        │  · decode: work pool ────────────┘ (mpsc, out-of-order)
//!        └──────────────────────────────────┘
//! ```
//!
//! * **Pipelining / out-of-order completion.** A client may write many
//!   request frames before reading responses. Decode requests are
//!   executed concurrently on the shared work pool and complete out of
//!   order — responses are matched by the echoed request id. Streaming
//!   verbs are executed inline on the connection's reader in arrival
//!   order (an append stream is order-sensitive), so per-connection
//!   stream semantics match a local `Coordinator::stream` call sequence
//!   while decodes overlap freely around them.
//! * **Backpressure.** `max_connections` bounds accepted connections
//!   (beyond it the accept loop replies with a typed reject frame
//!   carrying a retry-after hint, and closes); `max_inflight_per_conn`
//!   bounds dispatched-but-unanswered
//!   requests per connection — the reader stops reading until a slot
//!   frees, which backpressures the client through TCP. Read and write
//!   timeouts bound how long a stalled peer can pin a worker mid-frame.
//! * **Drain / shutdown.** [`NetServer::drain`] refuses *new*
//!   connections while existing ones keep being served — in-flight
//!   streaming sessions run to completion and their final acks are
//!   written. [`NetServer::shutdown`] drains, waits up to a grace
//!   period for clients to finish and disconnect, then force-closes
//!   stragglers and joins every thread. See DESIGN.md §6 for the state
//!   machine.
//! * **Observability / overload control (v3).** A scrape request
//!   ([`FrameKind::ScrapeRequest`]) renders the full metrics snapshot
//!   as stable `key value` text; when a [`Timeline`] is configured,
//!   connection opens/closes/refusals, drains, and request sheds are
//!   appended to it. Requests may carry a `deadline_ms` budget — one
//!   that expires before execution starts is shed with the typed
//!   reject frame instead of burning a worker on an answer the client
//!   has stopped waiting for — and `inflight_quota` converts the
//!   per-connection backpressure gate into a load-shedding quota. See
//!   docs/OBSERVABILITY.md.
//! * **Request tracing (v4).** A request frame may carry a wire `trace`
//!   context (`{trace_id, parent_span}`). When a timeline is configured
//!   the server attributes the request's latency to stages under that
//!   context — `admission` (arrival → in-flight slot), `queue` (work
//!   pool dispatch → job start) and `execute` (the service call, with
//!   kernel-dispatch counter deltas in the detail) — as
//!   `span-begin`/`span-end` pairs, and makes the execute span the
//!   ambient context so downstream layers (router pool checkout, store
//!   append, group-commit sync wait) and any [`NetClient`] hop made on
//!   this thread nest under it, linking spans across processes. A
//!   request that outlives the `slow_ms` budget is flagged on its
//!   execute span so `hmm-scan trace --merge --slow-only` can surface
//!   outliers. Untraced (v1..=v3) requests emit nothing.
//!
//! [`NetClient`]: crate::net::NetClient

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{
    Coordinator, DecodeRequest, DecodeResponse, Metrics, StreamRequest,
    StreamResponse,
};
use crate::error::{Error, Result};
use crate::exec::ThreadPool;
use crate::jsonx::Json;
use crate::obs::span::{self, StageSpan};
use crate::obs::{Timeline, TimelineEvent};

use super::wire::{self, Frame, FrameKind};

/// The request-serving surface a [`NetServer`] fronts: anything that
/// can answer decode and streaming requests and owns a [`Metrics`]
/// registry for the connection and wire counters.
///
/// Implemented by [`Coordinator`] (a single-process worker) and by
/// [`ClusterRouter`](crate::cluster::ClusterRouter) (the distributed
/// tier's session router), so the identical TCP front-end, wire
/// protocol, drain state machine, and client code serve both.
pub trait WireService: Send + Sync {
    /// Answer one decode request.
    fn decode(&self, req: DecodeRequest) -> Result<DecodeResponse>;
    /// Answer one streaming verb (open / append / stat / close and the
    /// cluster migration verbs).
    fn stream(&self, req: StreamRequest) -> Result<StreamResponse>;
    /// The metrics registry wire-serving counters are recorded in.
    fn metrics(&self) -> &Metrics;
}

impl WireService for Coordinator {
    fn decode(&self, req: DecodeRequest) -> Result<DecodeResponse> {
        Coordinator::decode(self, req)
    }
    fn stream(&self, req: StreamRequest) -> Result<StreamResponse> {
        Coordinator::stream(self, req)
    }
    fn metrics(&self) -> &Metrics {
        Coordinator::metrics(self)
    }
}

/// Server lifecycle states (the drain state machine, DESIGN.md §6).
const RUNNING: u8 = 0;
const DRAINING: u8 = 1;
const CLOSED: u8 = 2;

/// Retry-after hint on a drain refusal: the peer should look for
/// another server (a router fails over immediately; a bare client
/// backs off this long before reconnecting).
const DRAIN_RETRY_MS: u64 = 250;
/// Retry-after hint when the connection limit is hit: transient — a
/// short back-off usually finds a freed slot.
const BUSY_RETRY_MS: u64 = 50;

/// Tuning knobs for [`NetServer::start`].
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Concurrent connections accepted; beyond this the accept loop
    /// replies with a reject frame (retry-after hint) and closes the
    /// socket.
    pub max_connections: usize,
    /// Dispatched-but-unanswered requests one connection may have in
    /// flight. The reader stops pulling frames at the cap, so a client
    /// pipelining harder than the server completes is backpressured by
    /// TCP rather than ballooning server memory.
    pub max_inflight_per_conn: usize,
    /// Reader poll tick: an idle connection wakes this often to check
    /// for shutdown; a peer stalling *mid-frame* for this long is
    /// dropped (slow-loris guard).
    pub read_timeout: Duration,
    /// Cap on a blocked response write before the connection is
    /// declared dead.
    pub write_timeout: Duration,
    /// Worker threads of the shared decode-execution pool.
    pub exec_threads: usize,
    /// Per-frame payload cap handed to the wire decoder.
    pub max_frame_payload: usize,
    /// Per-connection decode quota for overload *shedding* (as opposed
    /// to the blocking backpressure of `max_inflight_per_conn`): with a
    /// non-zero quota, a decode arriving while that many are already in
    /// flight on the connection is answered immediately with a typed
    /// reject frame instead of stalling the reader. `0` (the default)
    /// disables shedding and keeps the pure-backpressure behaviour.
    pub inflight_quota: usize,
    /// Event timeline connection opens/closes/refusals, drains, and
    /// request sheds are recorded to. `None` (the default) disables
    /// emission entirely; recording is non-blocking either way.
    pub timeline: Option<Arc<Timeline>>,
    /// Slow-request capture threshold: a traced request whose total
    /// residence (frame arrival → service call returned) reaches this
    /// many milliseconds has its `execute` span flagged slow, so the
    /// merged-timeline tool can print only the outliers. `0` (the
    /// default) disables the flag.
    pub slow_ms: u64,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        Self {
            max_connections: 64,
            max_inflight_per_conn: 32,
            read_timeout: Duration::from_millis(250),
            write_timeout: Duration::from_secs(10),
            exec_threads: 4,
            max_frame_payload: wire::DEFAULT_MAX_PAYLOAD,
            inflight_quota: 0,
            timeline: None,
            slow_ms: 0,
        }
    }
}

/// Per-connection in-flight request counter (the
/// `max_inflight_per_conn` backpressure gate).
struct Inflight {
    count: Mutex<usize>,
    freed: Condvar,
}

impl Inflight {
    fn new() -> Arc<Inflight> {
        Arc::new(Inflight { count: Mutex::new(0), freed: Condvar::new() })
    }

    /// Block until a slot frees, then take it.
    fn acquire(&self, cap: usize) {
        let mut n = self.count.lock().unwrap();
        while *n >= cap.max(1) {
            n = self.freed.wait(n).unwrap();
        }
        *n += 1;
    }

    /// Admission with overload shedding: with `quota == 0` this is the
    /// blocking [`acquire`](Self::acquire); with a non-zero quota the
    /// slot is taken only if fewer than `min(quota, cap)` requests are
    /// in flight, and `false` (shed) is returned otherwise — the reader
    /// never stalls, the caller answers with a reject frame.
    fn acquire_within_quota(&self, cap: usize, quota: usize) -> bool {
        if quota == 0 {
            self.acquire(cap);
            return true;
        }
        let mut n = self.count.lock().unwrap();
        if *n >= quota.min(cap.max(1)) {
            return false;
        }
        *n += 1;
        true
    }

    fn release(&self) {
        let mut n = self.count.lock().unwrap();
        *n = n.saturating_sub(1);
        self.freed.notify_one();
    }
}

/// State shared by the accept loop and every connection handler.
struct Shared {
    service: Arc<dyn WireService>,
    config: NetServerConfig,
    state: AtomicU8,
    /// Active connection count; the condvar wakes drain/shutdown waits.
    conns: Mutex<usize>,
    conns_cv: Condvar,
    /// Clones of live connection streams, for force-close at shutdown.
    live: Mutex<BTreeMap<u64, TcpStream>>,
    conn_seq: AtomicU64,
}

impl Shared {
    fn state(&self) -> u8 {
        self.state.load(Ordering::Acquire)
    }

    /// Append an event to the configured timeline (no-op without one;
    /// non-blocking with one).
    fn record(&self, event: TimelineEvent) {
        if let Some(timeline) = &self.config.timeline {
            timeline.record(event);
        }
    }

    fn conn_done(&self, id: u64) {
        self.live.lock().unwrap().remove(&id);
        let mut n = self.conns.lock().unwrap();
        *n = n.saturating_sub(1);
        self.conns_cv.notify_all();
        self.service.metrics().on_conn_close();
        self.record(TimelineEvent::ConnClose { conn: id });
    }
}

/// A running TCP front-end. Dropping it shuts down with no grace
/// period; call [`shutdown`](Self::shutdown) for a graceful drain.
pub struct NetServer {
    shared: Arc<Shared>,
    /// Connection handlers run here — the accept loop hands each
    /// accepted connection to this pool, sized exactly
    /// `max_connections` so a handler never queues behind another.
    conn_pool: Option<Arc<ThreadPool>>,
    /// Decode execution pool (shared across connections).
    work: Option<Arc<ThreadPool>>,
    accept: Option<thread::JoinHandle<()>>,
    local: SocketAddr,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `service` — a [`Coordinator`] or any other
    /// [`WireService`] — over it. Returns once the listener is bound;
    /// [`local_addr`](Self::local_addr) reports the actual address.
    pub fn start<S: WireService + 'static>(
        service: Arc<S>,
        listen: &str,
        config: NetServerConfig,
    ) -> Result<NetServer> {
        let service: Arc<dyn WireService> = service;
        let listener = TcpListener::bind(listen)?;
        let local = listener.local_addr()?;
        let conn_pool = Arc::new(ThreadPool::new(config.max_connections.max(1)));
        let work = Arc::new(ThreadPool::new(config.exec_threads.max(1)));
        let shared = Arc::new(Shared {
            service,
            config,
            state: AtomicU8::new(RUNNING),
            conns: Mutex::new(0),
            conns_cv: Condvar::new(),
            live: Mutex::new(BTreeMap::new()),
            conn_seq: AtomicU64::new(0),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            let conn_pool = Arc::clone(&conn_pool);
            let work = Arc::clone(&work);
            thread::Builder::new()
                .name("hmm-scan-net-accept".into())
                .spawn(move || accept_loop(shared, listener, conn_pool, work))
                .expect("spawn accept thread")
        };
        Ok(NetServer {
            shared,
            conn_pool: Some(conn_pool),
            work: Some(work),
            accept: Some(accept),
            local,
        })
    }

    /// The bound listen address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Number of currently-connected clients.
    pub fn active_connections(&self) -> usize {
        *self.shared.conns.lock().unwrap()
    }

    /// Enter the draining state: new connections are refused with a
    /// typed reject frame; existing connections keep being served until
    /// their clients disconnect — in-flight streaming sessions complete
    /// and their final responses are acked. Idempotent; a no-op after
    /// shutdown begins.
    pub fn drain(&self) {
        let entered = self
            .shared
            .state
            .compare_exchange(
                RUNNING,
                DRAINING,
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_ok();
        if entered {
            self.shared
                .record(TimelineEvent::Drain { target: self.local.to_string() });
        }
    }

    /// Whether the server is refusing new connections.
    pub fn is_draining(&self) -> bool {
        self.shared.state() != RUNNING
    }

    /// Graceful shutdown: drain, wait up to `grace` for every client to
    /// finish and disconnect, then close the listener, force-close any
    /// straggler connections, and join all threads. Returns `true` when
    /// every connection drained within the grace period (no client was
    /// cut off mid-stream).
    pub fn shutdown(mut self, grace: Duration) -> bool {
        self.drain();
        let graceful = {
            let deadline = Instant::now() + grace;
            let mut n = self.shared.conns.lock().unwrap();
            while *n > 0 {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                let (guard, _) =
                    self.shared.conns_cv.wait_timeout(n, left).unwrap();
                n = guard;
            }
            *n == 0
        };
        self.close_and_join();
        graceful
    }

    /// Stop accepting, force-close connections, join every thread.
    fn close_and_join(&mut self) {
        self.shared.state.store(CLOSED, Ordering::Release);
        // Wake the accept loop out of its blocking accept().
        let _ = TcpStream::connect(self.local);
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        // Force-close stragglers; their readers exit on the socket
        // error (or at the next idle tick, which also checks CLOSED).
        for (_, stream) in self.shared.live.lock().unwrap().iter() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        {
            let mut n = self.shared.conns.lock().unwrap();
            while *n > 0 {
                let (guard, timeout) = self
                    .shared
                    .conns_cv
                    .wait_timeout(n, Duration::from_secs(5))
                    .unwrap();
                n = guard;
                if timeout.timed_out() {
                    break; // leak rather than hang — readers are stuck in IO
                }
            }
        }
        // Join the pools on this thread (never from one of their own
        // workers): connection handlers have exited, so both drains are
        // immediate.
        self.work.take();
        self.conn_pool.take();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        if self.accept.is_some() || self.conn_pool.is_some() {
            self.close_and_join();
        }
    }
}

/// Best-effort refusal: a reject frame with id 0 carrying a
/// retry-after hint, then close. Clients map it to [`Error::Busy`] and
/// can back off and retry (a cluster router retries on another worker)
/// instead of treating the refusal as fatal.
fn refuse(
    mut stream: TcpStream,
    retry_after_ms: u64,
    why: &str,
    write_timeout: Duration,
) {
    let _ = stream.set_write_timeout(Some(write_timeout));
    let _ = stream.write_all(&wire::encode_frame(
        0,
        FrameKind::Reject,
        &wire::reject_to_json(retry_after_ms, why),
    ));
    let _ = stream.shutdown(Shutdown::Both);
}

fn accept_loop(
    shared: Arc<Shared>,
    listener: TcpListener,
    conn_pool: Arc<ThreadPool>,
    work: Arc<ThreadPool>,
) {
    loop {
        let (stream, _peer) = match listener.accept() {
            Ok(conn) => conn,
            Err(_) => {
                if shared.state() == CLOSED {
                    break;
                }
                continue;
            }
        };
        match shared.state() {
            CLOSED => break, // the shutdown wake-up connection
            DRAINING => {
                shared.service.metrics().on_conn_refused();
                shared.service.metrics().on_reject();
                shared.record(TimelineEvent::ConnRefuse);
                refuse(
                    stream,
                    DRAIN_RETRY_MS,
                    "server draining: connection refused",
                    shared.config.write_timeout,
                );
                continue;
            }
            _ => {}
        }
        {
            let mut conns = shared.conns.lock().unwrap();
            if *conns >= shared.config.max_connections.max(1) {
                drop(conns);
                shared.service.metrics().on_conn_refused();
                shared.service.metrics().on_reject();
                shared.record(TimelineEvent::ConnRefuse);
                refuse(
                    stream,
                    BUSY_RETRY_MS,
                    "server busy: connection limit reached",
                    shared.config.write_timeout,
                );
                continue;
            }
            *conns += 1;
        }
        let id = shared.conn_seq.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.live.lock().unwrap().insert(id, clone);
        }
        shared.service.metrics().on_conn_open();
        shared.record(TimelineEvent::ConnOpen { conn: id });
        let shared2 = Arc::clone(&shared);
        let work2 = Arc::clone(&work);
        conn_pool.submit(move || {
            serve_connection(&shared2, &work2, id, stream);
            shared2.conn_done(id);
        });
    }
}

/// Outcome of one reader poll.
enum Poll {
    Frame(Frame),
    Idle,
    Closed,
}

/// Read one frame, distinguishing a clean peer close and an idle
/// timeout (no bytes yet) from hard errors. Once the first byte of a
/// frame has arrived the rest must follow within the read timeout —
/// a mid-frame stall is an error (slow-loris guard).
fn poll_frame(stream: &mut TcpStream, max_payload: usize) -> Result<Poll> {
    let mut first = [0u8; 1];
    match stream.read(&mut first) {
        Ok(0) => return Ok(Poll::Closed),
        Ok(_) => {}
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) =>
        {
            return Ok(Poll::Idle)
        }
        Err(e) => return Err(Error::Io(e)),
    }
    let mut r = (&first[..]).chain(stream);
    wire::read_frame(&mut r, max_payload).map(Poll::Frame)
}

/// Serve one connection until the peer closes, a framing violation
/// occurs, or the server shuts down. Runs on a connection-pool worker.
fn serve_connection(
    shared: &Arc<Shared>,
    work: &Arc<ThreadPool>,
    _conn_id: u64,
    mut stream: TcpStream,
) {
    let cfg = &shared.config;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let Ok(write_half) = stream.try_clone() else { return };
    let _ = write_half.set_write_timeout(Some(cfg.write_timeout));

    // Writer thread: serializes response frames in completion order.
    // Senders: this reader plus one clone per in-flight decode job; the
    // writer exits when all of them are gone (or on a write error).
    let (tx, rx) = mpsc::channel::<(u64, FrameKind, Json)>();
    let writer = thread::Builder::new()
        .name("hmm-scan-net-writer".into())
        .spawn(move || writer_loop(write_half, rx))
        .expect("spawn connection writer");

    let inflight = Inflight::new();
    loop {
        if shared.state() == CLOSED {
            break;
        }
        let frame = match poll_frame(&mut stream, cfg.max_frame_payload) {
            Ok(Poll::Frame(f)) => f,
            Ok(Poll::Idle) => continue,
            Ok(Poll::Closed) => break,
            Err(e) => {
                // Framing is unrecoverable: report once (best effort)
                // and drop the connection.
                let _ =
                    tx.send((0, FrameKind::Error, wire::error_to_json(&e)));
                break;
            }
        };
        // Deadline budgets are measured from frame arrival: a request
        // whose `deadline_ms` elapses before execution begins is shed.
        let arrival = Instant::now();
        match frame.kind {
            FrameKind::Ping => {
                let _ = tx.send((frame.id, FrameKind::Pong, Json::Null));
            }
            FrameKind::DecodeRequest => {
                let req = match wire::decode_request_from_json(
                    frame.id,
                    &frame.payload,
                ) {
                    Ok(req) => req,
                    Err(e) => {
                        shared.service.metrics().on_failure();
                        let _ = tx.send((
                            frame.id,
                            FrameKind::Error,
                            wire::error_to_json(&e),
                        ));
                        continue;
                    }
                };
                let deadline = wire::deadline_ms_from_json(&frame.payload);
                let ctx = wire::trace_from_json(&frame.payload)
                    .unwrap_or(wire::TraceContext {
                        trace_id: 0,
                        parent_span: 0,
                    });
                // Admission: the wait for an in-flight slot (inert for
                // untraced requests and without a timeline).
                let admission = StageSpan::begin_under(
                    cfg.timeline.as_ref(),
                    ctx.trace_id,
                    ctx.parent_span,
                    "admission",
                );
                // Take an in-flight slot *before* spawning: at the cap
                // this blocks the reader (the backpressure) — unless an
                // overload quota is set, in which case the request is
                // shed right here with a typed reject frame.
                if !inflight.acquire_within_quota(
                    cfg.max_inflight_per_conn,
                    cfg.inflight_quota,
                ) {
                    admission.finish_with(false, "quota-shed".to_string());
                    shared.service.metrics().on_quota_shed();
                    shared.service.metrics().on_reject();
                    let msg = "server overloaded: in-flight quota reached";
                    shared
                        .record(TimelineEvent::Reject { msg: msg.to_string() });
                    let _ = tx.send((
                        frame.id,
                        FrameKind::Reject,
                        wire::reject_to_json(BUSY_RETRY_MS, msg),
                    ));
                    continue;
                }
                // A deadline that lapsed while the reader was blocked on
                // the slot: shed before touching the wire gauge.
                if deadline_expired(arrival, deadline) {
                    admission.finish_with(false, "deadline-shed".to_string());
                    inflight.release();
                    shared.service.metrics().on_deadline_shed();
                    shared.service.metrics().on_reject();
                    let msg = "deadline_ms exceeded before dispatch";
                    shared
                        .record(TimelineEvent::Reject { msg: msg.to_string() });
                    let _ = tx.send((
                        frame.id,
                        FrameKind::Reject,
                        wire::reject_to_json(0, msg),
                    ));
                    continue;
                }
                admission.finish();
                shared.service.metrics().on_wire_start();
                let job_shared = Arc::clone(shared);
                let job_tx = tx.clone();
                let job_inflight = Arc::clone(&inflight);
                let slow_ms = cfg.slow_ms;
                let queued = Instant::now();
                work.submit(move || {
                    let t0 = Instant::now();
                    let tl = job_shared.config.timeline.clone();
                    span::with_span(ctx.trace_id, ctx.parent_span, || {
                        span::annotate(tl.as_ref(), "queue", queued.elapsed());
                        // Re-check the budget: the job may have queued
                        // behind other decodes in the work pool.
                        let outcome = if deadline_expired(arrival, deadline) {
                            job_shared.service.metrics().on_deadline_shed();
                            Err(Error::busy(
                                0,
                                "deadline_ms exceeded before execution",
                            ))
                        } else {
                            let exec = StageSpan::begin(tl.as_ref(), "execute");
                            let k0 = crate::linalg::kernels::kernel_stats();
                            let out =
                                exec.enter(|| job_shared.service.decode(req));
                            exec.finish_with(
                                is_slow(arrival, slow_ms),
                                kernel_delta(&k0),
                            );
                            out.map(|resp| {
                                (
                                    FrameKind::DecodeResponse,
                                    wire::decode_response_to_json(&resp),
                                )
                            })
                        };
                        let (kind, payload) =
                            response_parts(&job_shared, outcome);
                        job_shared
                            .service
                            .metrics()
                            .on_wire_done("decode", t0.elapsed());
                        let _ = job_tx.send((frame.id, kind, payload));
                        job_inflight.release();
                    });
                });
            }
            FrameKind::StreamRequest => {
                // Stream verbs execute inline, in arrival order — an
                // append sequence must apply in the order the client
                // sent it. Decodes already dispatched keep completing
                // concurrently around this.
                let t0 = Instant::now();
                shared.service.metrics().on_wire_start();
                let deadline = wire::deadline_ms_from_json(&frame.payload);
                let ctx = wire::trace_from_json(&frame.payload)
                    .unwrap_or(wire::TraceContext {
                        trace_id: 0,
                        parent_span: 0,
                    });
                let (verb_name, outcome) = if deadline_expired(arrival, deadline)
                {
                    shared.service.metrics().on_deadline_shed();
                    (
                        "stream",
                        Err(Error::busy(
                            0,
                            "deadline_ms exceeded before execution",
                        )),
                    )
                } else {
                    match wire::stream_request_from_json(
                        frame.id,
                        &frame.payload,
                    ) {
                        Ok(req) => {
                            let verb = stream_verb_name(&req);
                            let exec = StageSpan::begin_under(
                                cfg.timeline.as_ref(),
                                ctx.trace_id,
                                ctx.parent_span,
                                "execute",
                            );
                            let out =
                                exec.enter(|| shared.service.stream(req));
                            exec.finish_with(
                                is_slow(arrival, cfg.slow_ms),
                                verb.to_string(),
                            );
                            (verb, out)
                        }
                        Err(e) => ("stream", Err(e)),
                    }
                };
                let outcome = outcome.map(|resp| {
                    (
                        FrameKind::StreamResponse,
                        wire::stream_response_to_json(&resp),
                    )
                });
                let (kind, payload) = response_parts(shared, outcome);
                shared.service.metrics().on_wire_done(verb_name, t0.elapsed());
                let _ = tx.send((frame.id, kind, payload));
            }
            FrameKind::ScrapeRequest => {
                // Render the full metrics snapshot as stable `key value`
                // text (the scrape includes itself in `wire_inflight`,
                // which is honest: the scrape *is* in flight).
                let t0 = Instant::now();
                shared.service.metrics().on_wire_start();
                let text = shared.service.metrics().snapshot().render_text();
                shared.service.metrics().on_wire_done("scrape", t0.elapsed());
                let _ = tx.send((
                    frame.id,
                    FrameKind::ScrapeResponse,
                    wire::scrape_to_json(&text),
                ));
            }
            // A client must never send response kinds; protocol error.
            kind if kind.is_response() => {
                let e = Error::invalid_request(format!(
                    "wire: client sent a response frame (0x{:02x})",
                    kind.code()
                ));
                let _ =
                    tx.send((frame.id, FrameKind::Error, wire::error_to_json(&e)));
                break;
            }
            _ => unreachable!("request kinds are handled above"),
        }
    }
    // Drop our sender; in-flight decode jobs hold clones, so the writer
    // stays up exactly until the last pending response is written.
    drop(tx);
    let _ = writer.join();
}

/// Whether a request's `deadline_ms` budget (measured from frame
/// arrival) has lapsed. No deadline never expires; a zero budget is
/// already expired.
fn deadline_expired(arrival: Instant, deadline_ms: Option<u64>) -> bool {
    match deadline_ms {
        Some(ms) => arrival.elapsed() >= Duration::from_millis(ms),
        None => false,
    }
}

/// Whether a request's total residence has reached the slow-request
/// capture threshold (`0` disables the flag).
fn is_slow(arrival: Instant, slow_ms: u64) -> bool {
    slow_ms > 0 && arrival.elapsed() >= Duration::from_millis(slow_ms)
}

/// Render the kernel-dispatch counters that advanced during an execute
/// span as a compact `kernel_<k>=<delta>` list (empty when nothing
/// moved). The counters are process-wide, so concurrent decodes may
/// attribute each other's hits — the annotation is a profile hint, not
/// an exact ledger.
fn kernel_delta(before: &crate::linalg::kernels::KernelStatsSnapshot) -> String {
    let after = crate::linalg::kernels::kernel_stats();
    let mut out = String::new();
    for (key, b, a) in [
        ("spec_d2", before.spec_d2, after.spec_d2),
        ("spec_d4", before.spec_d4, after.spec_d4),
        ("spec_d8", before.spec_d8, after.spec_d8),
        ("spec_d16", before.spec_d16, after.spec_d16),
        ("generic", before.generic, after.generic),
        ("batched_calls", before.batched_calls, after.batched_calls),
        ("batched_lanes", before.batched_lanes, after.batched_lanes),
    ] {
        let delta = a.saturating_sub(b);
        if delta > 0 {
            if !out.is_empty() {
                out.push(' ');
            }
            out.push_str(&format!("kernel_{key}={delta}"));
        }
    }
    out
}

/// Map a verb outcome to response frame parts: success passes through;
/// a transient [`Error::Busy`] becomes a reject frame with the carried
/// retry-after hint (counted, and landed in the timeline); any other
/// error becomes a typed error frame.
fn response_parts(
    shared: &Shared,
    outcome: Result<(FrameKind, Json)>,
) -> (FrameKind, Json) {
    match outcome {
        Ok(parts) => parts,
        Err(Error::Busy { retry_after_ms, msg }) => {
            shared.service.metrics().on_reject();
            shared.record(TimelineEvent::Reject { msg: msg.clone() });
            (FrameKind::Reject, wire::reject_to_json(retry_after_ms, &msg))
        }
        Err(e) => (FrameKind::Error, wire::error_to_json(&e)),
    }
}

fn stream_verb_name(req: &crate::coordinator::StreamRequest) -> &'static str {
    match req.verb {
        crate::coordinator::StreamVerb::Open { .. } => "open",
        crate::coordinator::StreamVerb::OpenAt { .. } => "open_at",
        crate::coordinator::StreamVerb::Append { .. } => "append",
        crate::coordinator::StreamVerb::Stat { .. } => "stat",
        crate::coordinator::StreamVerb::Close { .. } => "close",
        crate::coordinator::StreamVerb::Export { .. } => "export",
        crate::coordinator::StreamVerb::Import { .. } => "import",
        crate::coordinator::StreamVerb::Release { .. } => "release",
    }
}

/// Drain the response channel onto the socket. Batches whatever is
/// immediately available between flushes; exits when every sender is
/// gone (connection finished) or on a write error (peer vanished — the
/// socket is shut down so the reader unblocks promptly).
fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<(u64, FrameKind, Json)>) {
    let mut w = std::io::BufWriter::new(&stream);
    'outer: while let Ok((id, kind, payload)) = rx.recv() {
        if wire::write_frame(&mut w, id, kind, &payload).is_err() {
            break;
        }
        // Opportunistic batch: coalesce already-completed responses
        // into one flush.
        while let Ok((id, kind, payload)) = rx.try_recv() {
            if wire::write_frame(&mut w, id, kind, &payload).is_err() {
                break 'outer;
            }
        }
        if w.flush().is_err() {
            break;
        }
    }
    let _ = w.flush();
    drop(w);
    let _ = stream.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        Algo, CoordinatorConfig, DecodeRequest, DecodeResult, StreamReply,
        StreamRequest,
    };
    use crate::engine::SessionOptions;
    use crate::hmm::{gilbert_elliott, GeParams};
    use crate::net::NetClient;
    use crate::rng::Xoshiro256StarStar;
    use crate::store::testutil::tempdir;

    fn test_config() -> NetServerConfig {
        NetServerConfig {
            max_connections: 8,
            max_inflight_per_conn: 8,
            read_timeout: Duration::from_millis(50),
            ..NetServerConfig::default()
        }
    }

    fn coord_with_store(dir: &std::path::Path) -> Arc<Coordinator> {
        let c = Coordinator::new(CoordinatorConfig {
            session_store: Some(dir.to_path_buf()),
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        Arc::new(c)
    }

    fn native_coord() -> Arc<Coordinator> {
        let c = Coordinator::new(CoordinatorConfig::native_only()).unwrap();
        c.register_model("ge", gilbert_elliott(GeParams::default()));
        Arc::new(c)
    }

    /// A [`WireService`] whose decodes block on a gate until released —
    /// deterministic in-flight pressure for the quota and gauge tests.
    struct GatedService {
        inner: Arc<Coordinator>,
        gate: Mutex<bool>,
        cv: Condvar,
    }

    impl GatedService {
        fn new(inner: Arc<Coordinator>) -> Arc<GatedService> {
            Arc::new(GatedService {
                inner,
                gate: Mutex::new(false),
                cv: Condvar::new(),
            })
        }

        /// Open the gate permanently: blocked and future decodes pass.
        fn release(&self) {
            *self.gate.lock().unwrap() = true;
            self.cv.notify_all();
        }
    }

    impl WireService for GatedService {
        fn decode(&self, req: DecodeRequest) -> Result<DecodeResponse> {
            let mut open = self.gate.lock().unwrap();
            while !*open {
                open = self.cv.wait(open).unwrap();
            }
            drop(open);
            self.inner.decode(req)
        }
        fn stream(&self, req: StreamRequest) -> Result<StreamResponse> {
            self.inner.stream(req)
        }
        fn metrics(&self) -> &Metrics {
            self.inner.metrics()
        }
    }

    /// Poll until `cond` holds (5 s deadline) — for assertions about
    /// state another thread settles asynchronously.
    fn wait_for(cond: impl Fn() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while !cond() {
            assert!(Instant::now() < deadline, "condition not reached in 5s");
            thread::sleep(Duration::from_millis(5));
        }
    }

    /// The loopback acceptance bar: a `NetClient` driving decode and
    /// open → append* → stat → close over TCP returns responses
    /// **bit-identical** to the same requests issued in-process via
    /// `Coordinator::decode`/`stream` — including after a server
    /// crash/restart + `recover_sessions`.
    #[test]
    fn loopback_bit_identical_to_in_process() {
        let dir = tempdir("net-loopback");
        let hmm = gilbert_elliott(GeParams::default());
        let mut rng = Xoshiro256StarStar::seed_from_u64(0xE77);
        let ys = crate::hmm::sample(&hmm, 300, &mut rng).observations;

        let coord = coord_with_store(&dir);
        let server =
            NetServer::start(Arc::clone(&coord), "127.0.0.1:0", test_config())
                .unwrap();
        let addr = server.local_addr().to_string();
        let mut client = NetClient::connect(&addr).unwrap();
        client.ping().unwrap();

        // Every decode task, remote vs in-process on the same
        // coordinator: results and plans must match exactly.
        for algo in Algo::ALL {
            let remote = client
                .decode(&DecodeRequest::new(1, "ge", ys.clone(), algo))
                .unwrap();
            let local = coord
                .decode(DecodeRequest::new(1, "ge", ys.clone(), algo))
                .unwrap();
            assert_eq!(remote.plan, local.plan);
            match (&remote.result, &local.result) {
                (DecodeResult::Posterior(a), DecodeResult::Posterior(b)) => {
                    assert_eq!(a, b, "{algo:?} posterior diverged over the wire")
                }
                (DecodeResult::Map(a), DecodeResult::Map(b)) => {
                    assert_eq!(a, b, "MAP path diverged over the wire")
                }
                (a, b) => panic!("result shape diverged: {a:?} vs {b:?}"),
            }
        }
        // Errors surface as typed failures, not hangs or garbage.
        assert!(client
            .decode(&DecodeRequest::new(1, "nope", vec![0], Algo::Smooth))
            .is_err());
        assert!(client
            .decode(&DecodeRequest::new(1, "ge", vec![9], Algo::Map))
            .is_err());

        // Streaming: one remote and one in-process session on the same
        // coordinator, fed identical chunks.
        let remote_sid =
            client.open("ge", SessionOptions::default(), 8).unwrap();
        let opened = coord.stream(StreamRequest::open(0, "ge", 8)).unwrap();
        let StreamReply::Opened { session: local_sid } = opened.reply else {
            panic!("expected Opened")
        };
        for chunk in ys.chunks(64) {
            let remote = client.append(remote_sid, chunk).unwrap();
            let local = coord
                .stream(StreamRequest::append(0, local_sid, chunk.to_vec()))
                .unwrap();
            let StreamReply::Appended {
                len: rl, filtered: rf, window: rw, ..
            } = remote
            else {
                panic!("expected Appended")
            };
            let StreamReply::Appended {
                len: ll, filtered: lf, window: lw, ..
            } = local.reply
            else {
                panic!("expected Appended")
            };
            assert_eq!(rl, ll);
            assert_eq!(rf, lf, "filtered marginal diverged over the wire");
            let (rw, lw) = (rw.unwrap(), lw.unwrap());
            assert_eq!(rw.start, lw.start);
            assert_eq!(rw.posterior, lw.posterior, "lag window diverged");
        }
        let StreamReply::Stats { len, model, .. } =
            client.stat(remote_sid).unwrap()
        else {
            panic!("expected Stats")
        };
        assert_eq!(len, 300);
        assert_eq!(model, "ge");

        // "Crash": stop the server and coordinator with both sessions
        // open, then recover from the durable store.
        drop(client);
        assert!(server.shutdown(Duration::from_secs(5)));
        drop(coord);

        let coord = coord_with_store(&dir);
        let recovered = coord.recover_sessions().unwrap();
        assert!(recovered >= 2, "recovered only {recovered} sessions");
        let server =
            NetServer::start(Arc::clone(&coord), "127.0.0.1:0", test_config())
                .unwrap();
        let mut client =
            NetClient::connect(server.local_addr().to_string()).unwrap();

        let extra = crate::hmm::sample(&hmm, 40, &mut rng).observations;
        let remote = client.append(remote_sid, &extra).unwrap();
        let local = coord
            .stream(StreamRequest::append(0, local_sid, extra.clone()))
            .unwrap();
        let StreamReply::Appended { filtered: rf, .. } = remote else {
            panic!("expected Appended")
        };
        let StreamReply::Appended { filtered: lf, .. } = local.reply else {
            panic!("expected Appended")
        };
        assert_eq!(rf, lf, "filtered diverged after crash recovery");

        let remote_posterior = client.close(remote_sid).unwrap();
        let closed =
            coord.stream(StreamRequest::close(0, local_sid)).unwrap();
        let StreamReply::Closed { posterior: local_posterior, .. } =
            closed.reply
        else {
            panic!("expected Closed")
        };
        assert_eq!(
            remote_posterior, local_posterior,
            "posterior diverged over the wire after restart + recovery"
        );
        let snap = coord.metrics().snapshot();
        assert!(snap.conns_opened >= 1);
        assert!(snap.wire_verbs.iter().any(|v| v.verb == "append"));
        drop(client);
        assert!(server.shutdown(Duration::from_secs(5)));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The drain satellite: in-flight streaming sessions complete and
    /// ack before the listener closes; new connects are refused while
    /// draining.
    #[test]
    fn drain_completes_inflight_sessions_and_refuses_new_connects() {
        let coord = native_coord();
        let server =
            NetServer::start(Arc::clone(&coord), "127.0.0.1:0", test_config())
                .unwrap();
        let addr = server.local_addr().to_string();

        let mut client = NetClient::connect(&addr).unwrap();
        let sid = client.open("ge", SessionOptions::default(), 0).unwrap();
        client.append(sid, &[0, 1, 1, 0]).unwrap();
        assert_eq!(server.active_connections(), 1);

        server.drain();
        assert!(server.is_draining());
        // New connections are refused during drain…
        assert!(
            NetClient::connect(&addr).is_err(),
            "draining server accepted a new client"
        );
        // …while the in-flight session keeps being served to
        // completion, including its final close ack.
        client.append(sid, &[1, 0]).unwrap();
        let posterior = client.close(sid).unwrap();
        assert_eq!(posterior.len(), 6);
        assert_eq!(coord.open_sessions(), 0, "close must have been served");

        drop(client);
        let graceful = server.shutdown(Duration::from_secs(5));
        assert!(graceful, "all clients were gone; drain must be graceful");
        // The listener is closed: nothing accepts on the address now.
        assert!(std::net::TcpStream::connect(&addr).is_err());
        let snap = coord.metrics().snapshot();
        assert!(snap.conns_refused >= 1);
        assert_eq!(snap.open_conns, 0);
    }

    /// Admission control is a typed reject frame, not a silent TCP
    /// refusal: over the connection cap the client observes a retryable
    /// [`Error::Busy`] carrying a back-off hint, and the reject is
    /// counted in the metrics registry.
    #[test]
    fn connection_cap_rejects_with_retry_hint() {
        let coord = native_coord();
        let server = NetServer::start(
            Arc::clone(&coord),
            "127.0.0.1:0",
            NetServerConfig { max_connections: 1, ..test_config() },
        )
        .unwrap();
        let addr = server.local_addr().to_string();
        let mut first = NetClient::connect(&addr).unwrap();
        // The ping response proves the accept loop has counted this
        // connection, so the next connect deterministically hits the cap.
        first.ping().unwrap();
        let err =
            NetClient::connect(&addr).expect_err("over-cap connect succeeded");
        match err {
            Error::Busy { retry_after_ms, .. } => {
                assert!(retry_after_ms > 0, "reject must carry a retry hint")
            }
            other => panic!("expected Busy, got: {other}"),
        }
        let snap = coord.metrics().snapshot();
        assert!(snap.rejects_sent >= 1);
        assert!(snap.conns_refused >= 1);
        // The admitted client keeps being served.
        first.ping().unwrap();
        drop(first);
        server.shutdown(Duration::from_secs(5));
    }

    /// Pipelining: many requests written ahead on one connection, all
    /// responses arrive (possibly out of order) and match by id,
    /// bit-identical to in-process decodes.
    #[test]
    fn pipelined_decodes_match_by_id() {
        let coord = native_coord();
        let hmm = gilbert_elliott(GeParams::default());
        let server =
            NetServer::start(Arc::clone(&coord), "127.0.0.1:0", test_config())
                .unwrap();
        let mut client =
            NetClient::connect(server.local_addr().to_string()).unwrap();
        let mut rng = Xoshiro256StarStar::seed_from_u64(0x91f);

        let n = 12usize;
        let mut by_id = std::collections::BTreeMap::new();
        for i in 0..n {
            let t = 40 + (i % 5) * 30;
            let ys = crate::hmm::sample(&hmm, t, &mut rng).observations;
            let algo = if i % 2 == 0 { Algo::Smooth } else { Algo::Map };
            let req = DecodeRequest::new(i as u64, "ge", ys, algo);
            let id = client.send_decode(&req).unwrap();
            by_id.insert(id, req);
        }
        client.flush().unwrap();
        for _ in 0..n {
            let (id, resp) = client.recv_decode().unwrap();
            let req = by_id.remove(&id).expect("unknown or duplicate id");
            let remote = resp.unwrap();
            let local = coord.decode(req).unwrap();
            match (&remote.result, &local.result) {
                (DecodeResult::Posterior(a), DecodeResult::Posterior(b)) => {
                    assert_eq!(a, b)
                }
                (DecodeResult::Map(a), DecodeResult::Map(b)) => {
                    assert_eq!(a, b)
                }
                (a, b) => panic!("shape diverged: {a:?} vs {b:?}"),
            }
        }
        assert!(by_id.is_empty(), "a response never arrived");
        drop(client);
        server.shutdown(Duration::from_secs(5));
    }

    /// Framing violations (garbage bytes, oversized declared length)
    /// kill only the offending connection; the server keeps serving
    /// fresh clients.
    #[test]
    fn garbage_frames_kill_only_that_connection() {
        let coord = native_coord();
        let server =
            NetServer::start(Arc::clone(&coord), "127.0.0.1:0", test_config())
                .unwrap();
        let addr = server.local_addr().to_string();

        // Garbage magic.
        {
            let mut raw = std::net::TcpStream::connect(&addr).unwrap();
            raw.write_all(b"totally not a frame, much longer than a header")
                .unwrap();
            let mut buf = [0u8; 1024];
            // The server replies with an error frame (id 0) and/or
            // closes; either way the read drains to EOF.
            loop {
                match raw.read(&mut buf) {
                    Ok(0) => break,
                    Ok(_) => continue,
                    Err(_) => break,
                }
            }
        }
        // Oversized declared payload length.
        {
            let mut raw = std::net::TcpStream::connect(&addr).unwrap();
            let mut header = Vec::new();
            header.extend_from_slice(&wire::MAGIC);
            header.push(wire::WIRE_VERSION);
            header.push(FrameKind::DecodeRequest.code());
            header.extend_from_slice(&[0u8; 2]);
            header.extend_from_slice(&7u64.to_le_bytes());
            header.extend_from_slice(&u32::MAX.to_le_bytes());
            header.extend_from_slice(&0u64.to_le_bytes());
            raw.write_all(&header).unwrap();
            let mut buf = [0u8; 1024];
            loop {
                match raw.read(&mut buf) {
                    Ok(0) => break,
                    Ok(_) => continue,
                    Err(_) => break,
                }
            }
        }
        // A well-behaved client still gets served.
        let mut client = NetClient::connect(&addr).unwrap();
        client.ping().unwrap();
        let resp = client
            .decode(&DecodeRequest::new(1, "ge", vec![0, 1, 1], Algo::Smooth))
            .unwrap();
        assert_eq!(resp.result.as_posterior().unwrap().len(), 3);
        drop(client);
        server.shutdown(Duration::from_secs(5));
    }

    /// The gauge-pairing audit (observability satellite): every path
    /// that can abandon a request — malformed decode payloads, failing
    /// decodes, expired deadlines, a connection dying with a decode in
    /// flight — leaves `wire_inflight` balanced back at zero.
    #[test]
    fn wire_inflight_gauge_survives_every_error_path() {
        let coord = native_coord();
        let service = GatedService::new(Arc::clone(&coord));
        let server =
            NetServer::start(Arc::clone(&service), "127.0.0.1:0", test_config())
                .unwrap();
        let addr = server.local_addr().to_string();

        // Malformed decode payload: a typed error frame, sent before the
        // gauge is ever touched.
        {
            let mut raw = std::net::TcpStream::connect(&addr).unwrap();
            raw.write_all(&wire::encode_frame(
                7,
                FrameKind::DecodeRequest,
                &Json::Str("not a decode request".to_string()),
            ))
            .unwrap();
            let frame =
                wire::read_frame(&mut raw, wire::DEFAULT_MAX_PAYLOAD).unwrap();
            assert_eq!(frame.kind, FrameKind::Error);
            assert_eq!(coord.metrics().snapshot().wire_inflight, 0);
        }

        // Connection death with a decode in flight: the job's start/done
        // pair still runs even though the response write fails.
        {
            let mut client = NetClient::connect(&addr).unwrap();
            client
                .send_decode(&DecodeRequest::new(
                    1,
                    "ge",
                    vec![0, 1],
                    Algo::Smooth,
                ))
                .unwrap();
            client.flush().unwrap();
            wait_for(|| coord.metrics().snapshot().wire_inflight == 1);
            drop(client);
            service.release();
            wait_for(|| coord.metrics().snapshot().wire_inflight == 0);
        }

        // Failing decode and expired deadlines on a live connection (the
        // gate is open now, so ordinary decodes execute).
        let mut client = NetClient::connect(&addr).unwrap();
        assert!(client
            .decode(&DecodeRequest::new(2, "nope", vec![0], Algo::Smooth))
            .is_err());
        client.set_deadline_ms(Some(0));
        let err = client
            .decode(&DecodeRequest::new(3, "ge", vec![0], Algo::Smooth))
            .expect_err("expired-deadline decode was served");
        assert!(err.is_busy());
        let err = client
            .open("ge", SessionOptions::default(), 0)
            .expect_err("expired-deadline open was served");
        assert!(err.is_busy());
        client.set_deadline_ms(None);

        let snap = coord.metrics().snapshot();
        assert_eq!(snap.wire_inflight, 0, "an error path leaked the gauge");
        assert!(snap.deadline_sheds >= 2);
        assert!(snap.rejects_sent >= 2);
        drop(client);
        server.shutdown(Duration::from_secs(5));
        assert_eq!(coord.metrics().snapshot().wire_inflight, 0);
    }

    /// With a non-zero `inflight_quota` an over-quota decode is shed
    /// with a typed reject frame instead of stalling the reader, and the
    /// connection keeps serving.
    #[test]
    fn quota_sheds_decodes_instead_of_blocking_the_reader() {
        let coord = native_coord();
        let service = GatedService::new(Arc::clone(&coord));
        let server = NetServer::start(
            Arc::clone(&service),
            "127.0.0.1:0",
            NetServerConfig { inflight_quota: 1, ..test_config() },
        )
        .unwrap();
        let mut client =
            NetClient::connect(server.local_addr().to_string()).unwrap();
        let id1 = client
            .send_decode(&DecodeRequest::new(1, "ge", vec![0, 1, 1], Algo::Smooth))
            .unwrap();
        let id2 = client
            .send_decode(&DecodeRequest::new(2, "ge", vec![1, 0], Algo::Smooth))
            .unwrap();
        client.flush().unwrap();
        // The second decode is shed while the first holds the only
        // quota slot…
        let (id, resp) = client.recv_decode().unwrap();
        assert_eq!(id, id2, "the shed must answer before the gated decode");
        let err = resp.expect_err("over-quota decode was served");
        assert!(err.is_busy(), "expected Busy, got: {err}");
        // …and the first completes untouched once the gate opens.
        service.release();
        let (id, resp) = client.recv_decode().unwrap();
        assert_eq!(id, id1);
        resp.unwrap();
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.quota_sheds, 1);
        assert!(snap.rejects_sent >= 1);
        drop(client);
        server.shutdown(Duration::from_secs(5));
        assert_eq!(coord.metrics().snapshot().wire_inflight, 0);
    }

    /// Server-level timeline: connection opens/closes/refusals, drains,
    /// and request sheds land in the configured timeline, and replay
    /// folds them back into matching counters.
    #[test]
    fn timeline_records_the_connection_lifecycle() {
        let dir = tempdir("net-timeline");
        let timeline = crate::obs::Timeline::open(&dir).unwrap();
        let coord = native_coord();
        let server = NetServer::start(
            Arc::clone(&coord),
            "127.0.0.1:0",
            NetServerConfig {
                timeline: Some(Arc::clone(&timeline)),
                max_connections: 1,
                ..test_config()
            },
        )
        .unwrap();
        let addr = server.local_addr().to_string();

        let mut client = NetClient::connect(&addr).unwrap();
        client.ping().unwrap();
        // Over the connection cap: a refusal.
        assert!(NetClient::connect(&addr).is_err());
        // An expired deadline: a request-level shed.
        client.set_deadline_ms(Some(0));
        assert!(client
            .decode(&DecodeRequest::new(1, "ge", vec![0], Algo::Smooth))
            .is_err());
        client.set_deadline_ms(None);
        server.drain();
        server.drain(); // idempotent: must not log a second drain
        drop(client);
        // Expected events: conn-open, conn-refuse, reject, drain,
        // conn-close — the close lands asynchronously after the reader
        // notices the disconnect, so poll the sequence number.
        wait_for(|| {
            timeline.flush();
            timeline.last_seq() >= 5
        });
        let records = crate::obs::read_events(&dir).unwrap();
        let state = crate::obs::replay_records(&records, None);
        assert_eq!(state.conns_opened, 1);
        assert_eq!(state.conns_closed, 1);
        assert_eq!(state.conns_refused, 1);
        assert_eq!(state.rejects, 1);
        assert_eq!(state.drains, 1);
        assert!(state.open_conns.is_empty());
        assert_eq!(timeline.dropped(), 0);
        drop(server);
        drop(timeline);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The tracing tentpole, server half: a v4 client decode produces
    /// `admission`/`queue`/`execute` spans on one trace (rooted at the
    /// client's wire context), stream verbs produce verb-annotated
    /// execute spans, and every span closes — replay sees no torn
    /// traces.
    #[test]
    fn traced_requests_emit_stage_spans() {
        let dir = tempdir("net-spans");
        let timeline = crate::obs::Timeline::open(&dir).unwrap();
        let coord = native_coord();
        let server = NetServer::start(
            Arc::clone(&coord),
            "127.0.0.1:0",
            NetServerConfig {
                timeline: Some(Arc::clone(&timeline)),
                ..test_config()
            },
        )
        .unwrap();
        let mut client =
            NetClient::connect(server.local_addr().to_string()).unwrap();
        client
            .decode(&DecodeRequest::new(1, "ge", vec![0, 1, 1, 0], Algo::Smooth))
            .unwrap();
        let sid = client.open("ge", SessionOptions::default(), 0).unwrap();
        client.append(sid, &[0, 1]).unwrap();
        client.close(sid).unwrap();
        drop(client);
        server.shutdown(Duration::from_secs(5));
        timeline.flush();

        let records = crate::obs::read_events(&dir).unwrap();
        let state = crate::obs::replay_records(&records, None);
        assert!(state.spans_begun >= 6, "begun only {}", state.spans_begun);
        assert_eq!(state.spans_begun, state.spans_closed);
        assert!(state.open_spans.is_empty());
        assert!(state.torn_traces().is_empty());

        // The decode's three stages share one trace, rooted at the
        // client's origination (parent 0), and none is flagged slow.
        let mut decode_trace = 0;
        let mut stages = Vec::new();
        for r in &records {
            if let TimelineEvent::SpanBegin { trace, parent, stage, .. } =
                &r.event
            {
                if stage == "admission" {
                    decode_trace = *trace;
                    assert_eq!(*parent, 0, "client must originate the trace");
                }
                if *trace == decode_trace && decode_trace != 0 {
                    stages.push(stage.clone());
                }
            }
        }
        assert_eq!(stages, ["admission", "queue", "execute"]);
        let mut stream_verbs = Vec::new();
        for r in &records {
            if let TimelineEvent::SpanEnd { trace, stage, slow, detail, .. } =
                &r.event
            {
                assert!(!slow, "slow_ms=0 must never flag a span");
                if stage == "execute" && *trace != decode_trace {
                    stream_verbs.push(detail.clone());
                }
            }
        }
        assert_eq!(stream_verbs, ["open", "append", "close"]);
        drop(timeline);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `slow_ms`: a decode held past the threshold is flagged on its
    /// execute span (the slow-request capture knob).
    #[test]
    fn slow_requests_are_flagged_on_the_execute_span() {
        let dir = tempdir("net-slow");
        let timeline = crate::obs::Timeline::open(&dir).unwrap();
        let coord = native_coord();
        let service = GatedService::new(Arc::clone(&coord));
        let server = NetServer::start(
            Arc::clone(&service),
            "127.0.0.1:0",
            NetServerConfig {
                timeline: Some(Arc::clone(&timeline)),
                slow_ms: 1,
                ..test_config()
            },
        )
        .unwrap();
        let mut client =
            NetClient::connect(server.local_addr().to_string()).unwrap();
        client
            .send_decode(&DecodeRequest::new(1, "ge", vec![0, 1], Algo::Smooth))
            .unwrap();
        client.flush().unwrap();
        thread::sleep(Duration::from_millis(30));
        service.release();
        let (_, resp) = client.recv_decode().unwrap();
        resp.unwrap();
        drop(client);
        server.shutdown(Duration::from_secs(5));
        timeline.flush();

        let records = crate::obs::read_events(&dir).unwrap();
        let flagged = records.iter().any(|r| {
            matches!(
                &r.event,
                TimelineEvent::SpanEnd { stage, slow: true, .. }
                    if stage == "execute"
            )
        });
        assert!(flagged, "a 30ms decode over a 1ms budget must flag slow");
        drop(timeline);
        std::fs::remove_dir_all(&dir).ok();
    }
}


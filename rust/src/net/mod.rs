//! The network serving layer (L4): a TCP front-end over the
//! [`Coordinator`](crate::coordinator::Coordinator), a versioned wire
//! protocol, and a blocking client.
//!
//! After four PRs of in-process serving (`mpsc`-fed serve loop), this
//! is what makes the coordinator a *deployable server*: remote callers
//! reach every decode and streaming verb over persistent TCP
//! connections with pipelining, backpressure and graceful drain.
//!
//! * [`wire`] — length-prefixed, checksummed, versioned frames carrying
//!   compact-JSON payloads with the packed hex encodings of
//!   `elements::serde` (bit-exact f64 round trips). Spec:
//!   `docs/WIRE_FORMAT.md`.
//! * [`server`] — [`NetServer`]: accept loop, per-connection
//!   reader/writer, decode execution on a shared `exec::ThreadPool`,
//!   `max_connections` / `max_inflight_per_conn` limits with typed
//!   reject-with-retry-after admission control, drain + graceful
//!   shutdown. Fronts any [`WireService`] — a local coordinator or the
//!   cluster tier's router ([`crate::cluster`]).
//! * [`client`] — [`NetClient`]: blocking verbs plus a pipelined decode
//!   half for benches; auto-reconnect with per-session re-`Stat`.
//!
//! CLI: `hmm-scan serve --listen ADDR` starts a server; `hmm-scan
//! bench-net --connect ADDR` verifies a remote server bit-for-bit
//! against a local coordinator and measures wire throughput; `hmm-scan
//! stat --connect ADDR` scrapes the server's metrics snapshot as
//! `key value` text (wire v3). The loopback bit-identity contract —
//! remote responses exactly equal to in-process
//! `Coordinator::decode`/`stream` results — is enforced by the tests in
//! [`server`] and by CI's loopback smoke job.
//!
//! Observability and overload control (v3, see `docs/OBSERVABILITY.md`):
//! the server records connection and shed events to an optional
//! [`obs::Timeline`](crate::obs::Timeline), sheds requests whose
//! `deadline_ms` budget lapses before execution, and converts the
//! per-connection in-flight gate into a load-shedding quota via
//! [`NetServerConfig::inflight_quota`].

pub mod client;
pub mod server;
pub mod wire;

pub use client::NetClient;
pub use server::{NetServer, NetServerConfig, WireService};
pub use wire::{Frame, FrameKind, WIRE_VERSION};
